"""AOT pipeline: lower the L2 resize model to HLO **text** for every
(kernel, src, scale, batch, tile) in the artifact matrix, write
`artifacts/manifest.json`, and self-check one artifact's numerics against
the jnp reference before declaring success.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--full]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.ref import REFS
from .model import example_input, make_resize, test_image

# ---------------------------------------------------------------------------
# The artifact matrix.
#
# Serving artifacts are deliberately small (64x64 / 128x128 sources): the
# CPU PJRT testbed plays the role of the GPU, and the serving experiments
# measure coordinator behaviour (batching, routing, backpressure), not
# kernel FLOPs. `--full` adds the paper's 800x800 source at scale 2 for
# the quickstart/e2e examples.
#
# Tiles: the portable winner 32x4 (y=4, x=32) plus an 8x8 variant so the
# router has a real choice to make.
# ---------------------------------------------------------------------------

BASE_MATRIX = [
    # (kernel, (src_h, src_w), scale, batch, (tile_h, tile_w))
    ("bilinear", (64, 64), 2, 1, (4, 32)),
    ("bilinear", (64, 64), 2, 4, (4, 32)),
    ("bilinear", (64, 64), 2, 4, (8, 8)),
    ("bilinear", (64, 64), 4, 1, (4, 32)),
    ("bilinear", (64, 64), 4, 4, (4, 32)),
    ("bilinear", (128, 128), 2, 1, (4, 32)),
    ("bilinear", (128, 128), 2, 4, (4, 32)),
    ("nearest", (64, 64), 2, 1, (4, 32)),
    ("nearest", (64, 64), 2, 4, (4, 32)),
    ("bicubic", (64, 64), 2, 1, (4, 32)),
    ("bicubic", (64, 64), 2, 4, (4, 32)),
    # CPU-tile ablation (EXPERIMENTS.md §Perf): the SAME kernel with
    # progressively larger Pallas output tiles. 32x4 is the GPU-portable
    # choice from the paper; on the CPU PJRT testbed fewer/larger grid
    # steps win — the paper's "optimum does not transfer between devices"
    # thesis, demonstrated on our own hardware pair (sim-GPU vs real CPU).
    ("bilinear", (64, 64), 2, 4, (16, 128)),
    ("bilinear", (64, 64), 2, 4, (128, 128)),
]

FULL_EXTRA = [
    ("bilinear", (800, 800), 2, 1, (4, 32)),
    ("bilinear", (800, 800), 2, 2, (4, 32)),
]


def full_matrix(base):
    """The base matrix plus a whole-output-tile variant per entry — the
    CPU-optimal tiles the router's largest-tile fallback selects
    (EXPERIMENTS.md §Perf: 5.7x over the GPU-portable 32x4 on PJRT-CPU).
    """
    out = list(base)
    seen = {(k, s, sc, b, t) for (k, s, sc, b, t) in base}
    for kernel, src, scale, batch, _tile in base:
        whole = (src[0] * scale, src[1] * scale)
        row = (kernel, src, scale, batch, whole)
        if row not in seen:
            seen.add(row)
            out.append(row)
    return out


def artifact_name(kernel, src, scale, batch, tile):
    return f"{kernel}_s{scale}_b{batch}_t{tile[1]}x{tile[0]}_{src[0]}x{src[1]}"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(kernel, src, scale, batch, tile) -> str:
    fn = make_resize(kernel, scale, tile=tile, interpret=True)
    spec = example_input(batch, src[0], src[1])
    return to_hlo_text(jax.jit(fn).lower(spec))


def self_check(kernel, src, scale, batch, tile, atol=2e-5) -> float:
    """Eager numeric check of the model (pallas interpret) vs the jnp
    reference — the same oracle pytest sweeps more broadly."""
    fn = make_resize(kernel, scale, tile=tile, interpret=True)
    imgs = jnp.stack([test_image(src[0], src[1], seed=i) for i in range(batch)])
    got = np.asarray(fn(imgs))
    ref = np.stack([np.asarray(REFS[kernel](imgs[i], scale)) for i in range(batch)])
    err = float(np.max(np.abs(got - ref)))
    if err > atol:
        raise AssertionError(
            f"self-check failed for {artifact_name(kernel, src, scale, batch, tile)}: "
            f"max |err| = {err}"
        )
    return err


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--full",
        action="store_true",
        help="also lower the 800x800 paper-sized artifacts (slower)",
    )
    ap.add_argument(
        "--skip-check", action="store_true", help="skip the numeric self-check"
    )
    args = ap.parse_args()

    matrix = full_matrix(BASE_MATRIX) + (list(FULL_EXTRA) if args.full else [])
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    for kernel, src, scale, batch, tile in matrix:
        name = artifact_name(kernel, src, scale, batch, tile)
        path = f"{name}.hlo.txt"
        print(f"[aot] lowering {name} ...", flush=True)
        text = lower_one(kernel, src, scale, batch, tile)
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "kernel": kernel,
                "src": list(src),
                "scale": scale,
                "batch": batch,
                "tile": list(tile),
                "path": path,
            }
        )

    if not args.skip_check:
        # Check one representative per kernel (pytest covers the rest).
        checked = set()
        for kernel, src, scale, batch, tile in matrix:
            if kernel in checked or src[0] > 128:
                continue
            err = self_check(kernel, src, scale, batch, tile)
            print(f"[aot] self-check {kernel}: max |err| = {err:.2e}")
            checked.add(kernel)

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {len(entries)} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    sys.exit(main())
