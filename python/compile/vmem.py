"""L1 performance estimation for TPU targets: VMEM footprint and VPU
lane utilization per (kernel, tile, source, scale) design point.

interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so the
Pallas perf story is structural: does the working set fit VMEM, are the
lanes full, how many HBM bytes move per output pixel. These estimates
feed EXPERIMENTS.md §Perf (L1) and mirror the paper's occupancy-style
reasoning on the TPU side (DESIGN.md §Hardware-Adaptation).

Run as a module for the report:  python -m compile.vmem
"""

from dataclasses import dataclass

# TPU-v4-ish constants (order-of-magnitude; the report states them).
VMEM_BYTES = 16 * 1024 * 1024
LANES = 128  # vector lane width (f32)
SUBLANES = 8  # vreg sublanes


TAPS = {"nearest": 1, "bilinear": 4, "bicubic": 16}


@dataclass
class L1Estimate:
    kernel: str
    tile: tuple  # (tile_h, tile_w) output tile
    src: tuple  # (h, w)
    scale: int
    windowed: bool

    @property
    def out_tile_bytes(self) -> int:
        return self.tile[0] * self.tile[1] * 4

    @property
    def src_resident_bytes(self) -> int:
        """Source bytes resident per program."""
        if self.windowed:
            wh = self.tile[0] // self.scale + 2
            ww = self.tile[1] // self.scale + 2
            return wh * ww * 4
        return self.src[0] * self.src[1] * 4

    @property
    def vmem_bytes(self) -> int:
        """Working set per program: source (full or window) + out tile
        (double-buffered) + index/offset vectors."""
        index_vectors = 6 * self.tile[1] * 4 + 6 * self.tile[0] * 4
        return self.src_resident_bytes + 2 * self.out_tile_bytes + index_vectors

    @property
    def fits_vmem(self) -> bool:
        return self.vmem_bytes <= VMEM_BYTES

    @property
    def lane_utilization(self) -> float:
        """Fraction of the 128-lane vector unit used by the minor (x)
        axis of the output tile — the paper's coalescing story mapped to
        lanes (DESIGN.md §Hardware-Adaptation)."""
        minor = self.tile[1]
        used = minor % LANES or LANES
        if minor >= LANES:
            # full vregs plus a possibly partial tail
            full = minor // LANES
            return (full * LANES + (minor % LANES)) / ((full + (1 if minor % LANES else 0)) * LANES)
        return used / LANES

    @property
    def hbm_bytes_per_out_px(self) -> float:
        """HBM traffic per output pixel: the out store plus the source
        window amortized over the tile (windowed) or the full source
        amortized over the whole output (resident)."""
        out_px = self.tile[0] * self.tile[1]
        if self.windowed:
            return 4.0 + self.src_resident_bytes / out_px
        total_out = self.src[0] * self.src[1] * self.scale * self.scale
        return 4.0 + (self.src[0] * self.src[1] * 4) / total_out

    def row(self):
        return [
            self.kernel,
            f"{self.tile[1]}x{self.tile[0]}",
            "window" if self.windowed else "resident",
            f"{self.vmem_bytes / 1024:.1f} KiB",
            "yes" if self.fits_vmem else "NO",
            f"{self.lane_utilization * 100:.0f}%",
            f"{self.hbm_bytes_per_out_px:.2f}",
        ]


def report(rows=None):
    """Print the L1 estimate table used in EXPERIMENTS.md §Perf."""
    rows = rows or default_design_points()
    header = ["kernel", "tile(WxH)", "source", "VMEM/prog", "fits", "lanes", "HBM B/px"]
    widths = [max(len(header[i]), max(len(r[i]) for r in rows)) for i in range(len(header))]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print(fmt.format(*header))
    print("-" * (sum(widths) + 2 * (len(widths) - 1)))
    for r in rows:
        print(fmt.format(*r))


def default_design_points():
    pts = []
    for tile in [(4, 32), (8, 8), (8, 128), (16, 256)]:
        for windowed in (False, True):
            e = L1Estimate("bilinear", tile, (800, 800), 8, windowed)
            pts.append(e.row())
    # the paper-size source, resident vs windowed at the big tile
    pts.append(L1Estimate("bilinear", (8, 128), (4096, 4096), 2, False).row())
    pts.append(L1Estimate("bilinear", (8, 128), (4096, 4096), 2, True).row())
    return pts


if __name__ == "__main__":
    report()
