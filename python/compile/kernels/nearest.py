"""L1 Pallas kernel: nearest-neighbour interpolation (1 tap).

Same output-tiling skeleton as `bilinear.py`; the cheapest kernel, used
as the baseline in the kernel-cost ablation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = (4, 32)


def _nearest_kernel(src_ref, out_ref, *, scale: int, tile: tuple):
    tile_h, tile_w = tile
    src = src_ref[...]
    h, w = src.shape
    fdtype = src.dtype

    y0 = pl.program_id(0) * tile_h
    x0 = pl.program_id(1) * tile_w
    yf = y0 + jax.lax.iota(jnp.int32, tile_h)
    xf = x0 + jax.lax.iota(jnp.int32, tile_w)

    # round-half-up of the logical coordinate (matches ref + rust)
    yp = jnp.floor(yf.astype(fdtype) / jnp.asarray(scale, fdtype) + jnp.asarray(0.5, fdtype)).astype(jnp.int32)
    xp = jnp.floor(xf.astype(fdtype) / jnp.asarray(scale, fdtype) + jnp.asarray(0.5, fdtype)).astype(jnp.int32)
    ypc = jnp.clip(yp, 0, h - 1)
    xpc = jnp.clip(xp, 0, w - 1)
    out_ref[...] = src[ypc[:, None], xpc[None, :]]


def nearest_pallas(src, scale: int, tile=DEFAULT_TILE, interpret: bool = True):
    """Nearest-neighbour upscale of a [H, W] array by integer `scale`."""
    h, w = src.shape
    oh, ow = h * scale, w * scale
    tile_h = min(tile[0], oh)
    tile_w = min(tile[1], ow)
    grid = (pl.cdiv(oh, tile_h), pl.cdiv(ow, tile_w))
    kernel = functools.partial(_nearest_kernel, scale=scale, tile=(tile_h, tile_w))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((h, w), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((oh, ow), src.dtype),
        interpret=interpret,
    )(src)
