"""L1 Pallas kernel: bicubic (Catmull-Rom, 16-tap) interpolation.

Same output-tiling skeleton as `bilinear.py`, with the 4x4 tap loop
unrolled at trace time (static Python loop -> straight-line HLO). The
most register-hungry kernel: its CUDA profile (24 regs/thread) drives the
`Registers` occupancy limiter in the simulator's ablations.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = (4, 32)
_A = -0.5  # Catmull-Rom


def _cubic_weight(t):
    t = jnp.abs(t)
    w1 = (_A + 2.0) * t**3 - (_A + 3.0) * t**2 + 1.0
    w2 = _A * t**3 - 5.0 * _A * t**2 + 8.0 * _A * t - 4.0 * _A
    return jnp.where(t <= 1.0, w1, jnp.where(t < 2.0, w2, 0.0))


def _bicubic_kernel(src_ref, out_ref, *, scale: int, tile: tuple):
    tile_h, tile_w = tile
    src = src_ref[...]
    h, w = src.shape
    fdtype = src.dtype

    y0 = pl.program_id(0) * tile_h
    x0 = pl.program_id(1) * tile_w
    yf = y0 + jax.lax.iota(jnp.int32, tile_h)
    xf = x0 + jax.lax.iota(jnp.int32, tile_w)

    yp = yf.astype(fdtype) / jnp.asarray(scale, fdtype)
    xp = xf.astype(fdtype) / jnp.asarray(scale, fdtype)
    y1 = jnp.floor(yp).astype(jnp.int32)
    x1 = jnp.floor(xp).astype(jnp.int32)
    fy = (yp - y1.astype(fdtype))[:, None]
    fx = (xp - x1.astype(fdtype))[None, :]

    acc = jnp.zeros((tile_h, tile_w), dtype=fdtype)
    wsum = jnp.zeros((tile_h, tile_w), dtype=fdtype)
    for dy in (-1, 0, 1, 2):
        wy = _cubic_weight(fy - dy)
        yc = jnp.clip(y1 + dy, 0, h - 1)
        for dx in (-1, 0, 1, 2):
            wx = _cubic_weight(fx - dx)
            xc = jnp.clip(x1 + dx, 0, w - 1)
            tap = src[yc[:, None], xc[None, :]]
            wgt = wy * wx
            acc = acc + wgt * tap
            wsum = wsum + wgt
    out_ref[...] = acc / wsum


def bicubic_pallas(src, scale: int, tile=DEFAULT_TILE, interpret: bool = True):
    """Bicubic upscale of a [H, W] array by integer `scale`."""
    h, w = src.shape
    oh, ow = h * scale, w * scale
    tile_h = min(tile[0], oh)
    tile_w = min(tile[1], ow)
    grid = (pl.cdiv(oh, tile_h), pl.cdiv(ow, tile_w))
    kernel = functools.partial(_bicubic_kernel, scale=scale, tile=(tile_h, tile_w))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((h, w), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((oh, ow), src.dtype),
        interpret=interpret,
    )(src)
