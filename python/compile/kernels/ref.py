"""Pure-jnp reference interpolators — the correctness oracle for the
Pallas kernels (L1) and, transitively, for the whole AOT path: pytest
checks kernel-vs-ref, and the rust integration tests check the compiled
artifacts against `image::interpolate` which implements the same math.

The math is the paper's §II.B, equations (1)-(5):
    x_p = x_f / scale                          (1)
    x1 = int(x_p), x2 = x1 + 1  (clamped)      (2)(3)
    offX = x_p - x1, offY = y_p - y1           (4)
    f = (1-offY)(offX·f21 + (1-offX)·f11)
      +    offY (offX·f22 + (1-offX)·f12)      (5)

Boundary convention: neighbour coordinates clamp to the image border
(identical to `Image::get_clamped` on the rust side).
"""

import jax.numpy as jnp


def _logical_coords(out_len: int, scale: int, dtype=jnp.float32):
    """Paper eq. (1): terminal -> logical coordinate along one axis."""
    return jnp.arange(out_len, dtype=dtype) / dtype(scale)


def nearest_ref(src, scale: int):
    """Nearest-neighbour upscale of a [H, W] image by integer `scale`.

    Rounds half-up (matching the rust reference's `(x_p + 0.5) as int`).
    """
    h, w = src.shape
    yp = jnp.floor(_logical_coords(h * scale, scale) + 0.5).astype(jnp.int32)
    xp = jnp.floor(_logical_coords(w * scale, scale) + 0.5).astype(jnp.int32)
    yp = jnp.clip(yp, 0, h - 1)
    xp = jnp.clip(xp, 0, w - 1)
    return src[yp[:, None], xp[None, :]]


def bilinear_ref(src, scale: int):
    """Bilinear upscale of a [H, W] image by integer `scale` — eqs (1)-(5)."""
    h, w = src.shape
    yp = _logical_coords(h * scale, scale)
    xp = _logical_coords(w * scale, scale)
    y1 = jnp.floor(yp).astype(jnp.int32)
    x1 = jnp.floor(xp).astype(jnp.int32)
    off_y = (yp - y1.astype(yp.dtype))[:, None]
    off_x = (xp - x1.astype(xp.dtype))[None, :]

    y1c = jnp.clip(y1, 0, h - 1)
    y2c = jnp.clip(y1 + 1, 0, h - 1)
    x1c = jnp.clip(x1, 0, w - 1)
    x2c = jnp.clip(x1 + 1, 0, w - 1)

    f11 = src[y1c[:, None], x1c[None, :]]
    f21 = src[y1c[:, None], x2c[None, :]]
    f12 = src[y2c[:, None], x1c[None, :]]
    f22 = src[y2c[:, None], x2c[None, :]]

    top = off_x * f21 + (1.0 - off_x) * f11
    bot = off_x * f22 + (1.0 - off_x) * f12
    return (1.0 - off_y) * top + off_y * bot


def _cubic_weight(t):
    """Catmull-Rom weight (a = -0.5), matching the rust reference."""
    a = -0.5
    t = jnp.abs(t)
    w1 = (a + 2.0) * t**3 - (a + 3.0) * t**2 + 1.0
    w2 = a * t**3 - 5.0 * a * t**2 + 8.0 * a * t - 4.0 * a
    return jnp.where(t <= 1.0, w1, jnp.where(t < 2.0, w2, 0.0))


def bicubic_ref(src, scale: int):
    """Bicubic (Catmull-Rom, 16-tap) upscale with border clamping and
    weight renormalization (identical to the rust reference)."""
    h, w = src.shape
    yp = _logical_coords(h * scale, scale)
    xp = _logical_coords(w * scale, scale)
    y1 = jnp.floor(yp).astype(jnp.int32)
    x1 = jnp.floor(xp).astype(jnp.int32)
    fy = (yp - y1.astype(yp.dtype))[:, None]
    fx = (xp - x1.astype(xp.dtype))[None, :]

    acc = jnp.zeros((h * scale, w * scale), dtype=src.dtype)
    wsum = jnp.zeros_like(acc)
    for dy in (-1, 0, 1, 2):
        wy = _cubic_weight(fy - dy)
        yc = jnp.clip(y1 + dy, 0, h - 1)
        for dx in (-1, 0, 1, 2):
            wx = _cubic_weight(fx - dx)
            xc = jnp.clip(x1 + dx, 0, w - 1)
            tap = src[yc[:, None], xc[None, :]]
            wgt = wy * wx
            acc = acc + wgt * tap
            wsum = wsum + wgt
    return acc / wsum


REFS = {
    "nearest": nearest_ref,
    "bilinear": bilinear_ref,
    "bicubic": bicubic_ref,
}
