"""L1 Pallas kernel: bilinear interpolation, tiled over the OUTPUT image.

The CUDA thread-block shape the paper tunes maps here to the Pallas
`BlockSpec` output tile (see DESIGN.md §Hardware-Adaptation): the grid has
one program per (tile_h, tile_w) output tile, exactly like the paper's
eq. (6) block/thread decomposition, and the tile shape is the tuning knob
that controls VMEM working-set and HBM transfer geometry.

The source image stays fully resident per program (an 800x800 f32 source
is 2.56 MB, well under a TPU core's ~16 MB VMEM), mirroring the paper's
read-only gather through global memory. For sources that would not fit,
the documented alternative is a per-tile input window Blockspec with a
+2 halo — not needed for any workload in this repo.

`interpret=True` is mandatory on CPU PJRT: real TPU lowering emits a
Mosaic custom-call the CPU plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile: the paper's portable winner (32 wide x 4 tall).
DEFAULT_TILE = (4, 32)


def _bilinear_kernel(src_ref, out_ref, *, scale: int, tile: tuple):
    """One grid program: compute one (tile_h, tile_w) output tile."""
    tile_h, tile_w = tile
    src = src_ref[...]
    h, w = src.shape
    fdtype = src.dtype

    # Terminal (output) coordinates of this tile — paper eq. (6).
    y0 = pl.program_id(0) * tile_h
    x0 = pl.program_id(1) * tile_w
    yf = y0 + jax.lax.iota(jnp.int32, tile_h)
    xf = x0 + jax.lax.iota(jnp.int32, tile_w)

    # Paper eq. (1): logical source coordinates.
    yp = yf.astype(fdtype) / jnp.asarray(scale, fdtype)
    xp = xf.astype(fdtype) / jnp.asarray(scale, fdtype)

    # Paper eqs. (2)-(4): neighbours and offsets, border-clamped.
    y1 = jnp.floor(yp).astype(jnp.int32)
    x1 = jnp.floor(xp).astype(jnp.int32)
    off_y = (yp - y1.astype(fdtype))[:, None]
    off_x = (xp - x1.astype(fdtype))[None, :]
    y1c = jnp.clip(y1, 0, h - 1)
    y2c = jnp.clip(y1 + 1, 0, h - 1)
    x1c = jnp.clip(x1, 0, w - 1)
    x2c = jnp.clip(x1 + 1, 0, w - 1)

    f11 = src[y1c[:, None], x1c[None, :]]
    f21 = src[y1c[:, None], x2c[None, :]]
    f12 = src[y2c[:, None], x1c[None, :]]
    f22 = src[y2c[:, None], x2c[None, :]]

    # Paper eq. (5).
    top = off_x * f21 + (1.0 - off_x) * f11
    bot = off_x * f22 + (1.0 - off_x) * f12
    out_ref[...] = (1.0 - off_y) * top + off_y * bot


def bilinear_pallas(src, scale: int, tile=DEFAULT_TILE, interpret: bool = True):
    """Bilinear upscale of a [H, W] array by integer `scale` with a
    (tile_h, tile_w) Pallas output tiling.

    Output tiles need not divide the output size; Pallas masks the
    ragged edge blocks.
    """
    h, w = src.shape
    oh, ow = h * scale, w * scale
    tile_h = min(tile[0], oh)
    tile_w = min(tile[1], ow)
    grid = (pl.cdiv(oh, tile_h), pl.cdiv(ow, tile_w))
    kernel = functools.partial(
        _bilinear_kernel, scale=scale, tile=(tile_h, tile_w)
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((h, w), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((oh, ow), src.dtype),
        interpret=interpret,
    )(src)
