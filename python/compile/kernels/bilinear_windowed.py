"""L1 Pallas kernel: bilinear interpolation with a PER-TILE SOURCE
WINDOW — the DESIGN.md §Hardware-Adaptation variant for sources too
large to keep resident in VMEM.

Instead of reading the whole source per program (`bilinear.py`), each
program loads only the `(tile_h/scale + 2) x (tile_w/scale + 2)` window
its output tile depends on, via a dynamic slice from the source ref.
On a real TPU the source would sit in HBM (`memory_space=ANY`) and the
slice becomes an async DMA into VMEM scratch; under interpret=True the
dynamic slice exercises the same indexing logic, which is what the
correctness tests pin down.

The window start is clamped so the window never leaves the image;
neighbour indices are then clamped *within* the window, preserving the
border-clamp semantics of the resident-source kernel bit-for-bit.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = (4, 32)


def _kernel(src_ref, out_ref, *, scale: int, tile: tuple, src_hw: tuple):
    tile_h, tile_w = tile
    h, w = src_hw
    fdtype = out_ref.dtype

    # Window geometry (static): the source span of one output tile plus
    # a +2 halo (floor neighbour + right/bottom neighbour).
    win_h = min(tile_h // scale + 2, h)
    win_w = min(tile_w // scale + 2, w)

    y0 = pl.program_id(0) * tile_h
    x0 = pl.program_id(1) * tile_w

    # Clamped window start in source coordinates.
    ws_y = jnp.clip(y0 // scale, 0, h - win_h)
    ws_x = jnp.clip(x0 // scale, 0, w - win_w)

    # Load ONLY the window (dynamic slice; DMA on real hardware).
    win = src_ref[pl.ds(ws_y, win_h), pl.ds(ws_x, win_w)]

    yf = y0 + jax.lax.iota(jnp.int32, tile_h)
    xf = x0 + jax.lax.iota(jnp.int32, tile_w)
    yp = yf.astype(fdtype) / jnp.asarray(scale, fdtype)
    xp = xf.astype(fdtype) / jnp.asarray(scale, fdtype)

    y1 = jnp.floor(yp).astype(jnp.int32)
    x1 = jnp.floor(xp).astype(jnp.int32)
    off_y = (yp - y1.astype(fdtype))[:, None]
    off_x = (xp - x1.astype(fdtype))[None, :]

    # Global clamp first (border semantics), then window-relative.
    y1g = jnp.clip(y1, 0, h - 1)
    y2g = jnp.clip(y1 + 1, 0, h - 1)
    x1g = jnp.clip(x1, 0, w - 1)
    x2g = jnp.clip(x1 + 1, 0, w - 1)
    y1r = jnp.clip(y1g - ws_y, 0, win_h - 1)
    y2r = jnp.clip(y2g - ws_y, 0, win_h - 1)
    x1r = jnp.clip(x1g - ws_x, 0, win_w - 1)
    x2r = jnp.clip(x2g - ws_x, 0, win_w - 1)

    f11 = win[y1r[:, None], x1r[None, :]]
    f21 = win[y1r[:, None], x2r[None, :]]
    f12 = win[y2r[:, None], x1r[None, :]]
    f22 = win[y2r[:, None], x2r[None, :]]

    top = off_x * f21 + (1.0 - off_x) * f11
    bot = off_x * f22 + (1.0 - off_x) * f12
    out_ref[...] = (1.0 - off_y) * top + off_y * bot


def bilinear_windowed_pallas(src, scale: int, tile=DEFAULT_TILE, interpret: bool = True):
    """Bilinear upscale with per-tile source windows.

    Requires the output tile dims to be multiples of `scale` (so each
    tile's source window is rectangular); falls back is the caller's
    concern — `window_supported` reports the constraint.
    """
    h, w = src.shape
    oh, ow = h * scale, w * scale
    tile_h = min(tile[0], oh)
    tile_w = min(tile[1], ow)
    if tile_h % scale != 0 and tile_h < oh:
        raise ValueError(f"tile_h {tile_h} must be a multiple of scale {scale}")
    if tile_w % scale != 0 and tile_w < ow:
        raise ValueError(f"tile_w {tile_w} must be a multiple of scale {scale}")
    grid = (pl.cdiv(oh, tile_h), pl.cdiv(ow, tile_w))
    kernel = functools.partial(
        _kernel, scale=scale, tile=(tile_h, tile_w), src_hw=(h, w)
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((h, w), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((oh, ow), src.dtype),
        interpret=interpret,
    )(src)


def window_supported(scale: int, tile=DEFAULT_TILE) -> bool:
    """Can this (scale, tile) use the windowed kernel?"""
    return tile[0] % scale == 0 and tile[1] % scale == 0
