"""L2: the JAX resize model — the compute graph the rust coordinator
executes through PJRT.

`make_resize` builds a jittable function over a static (kernel, scale,
tile, batch): input [B, H, W] f32, output [B, H*s, W*s] f32. The batch
dimension is vmapped over the L1 Pallas kernel so the whole batch lowers
into ONE fused HLO module — the unit the coordinator's dynamic batcher
schedules.

Build-time only; never imported on the request path.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.bicubic import bicubic_pallas
from .kernels.bilinear import bilinear_pallas
from .kernels.nearest import nearest_pallas

KERNELS = {
    "nearest": nearest_pallas,
    "bilinear": bilinear_pallas,
    "bicubic": bicubic_pallas,
}

DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def make_resize(kernel: str, scale: int, tile=(4, 32), interpret: bool = True):
    """A function [B, H, W] -> [B, H*scale, W*scale] for one kernel/tile.

    Returns a plain python callable (jit-compatible); `aot.py` lowers it,
    pytest calls it eagerly.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel '{kernel}' (have {sorted(KERNELS)})")
    if scale < 1:
        raise ValueError("scale must be >= 1")
    kfn = KERNELS[kernel]

    def resize_batch(batch):
        if batch.ndim != 3:
            raise ValueError(f"expected [B, H, W], got shape {batch.shape}")
        single = functools.partial(kfn, scale=scale, tile=tile, interpret=interpret)
        return jax.vmap(single)(batch)

    return resize_batch


def example_input(batch: int, h: int, w: int, dtype=jnp.float32):
    """The ShapeDtypeStruct `aot.py` lowers against."""
    return jax.ShapeDtypeStruct((batch, h, w), dtype)


def test_image(h: int, w: int, seed: int = 0, dtype=jnp.float32):
    """A deterministic synthetic test image (gradient + sinusoidal
    texture), value range [0, 1]. Used by pytest and by aot self-checks."""
    ys = jnp.linspace(0.0, 1.0, h, dtype=dtype)[:, None]
    xs = jnp.linspace(0.0, 1.0, w, dtype=dtype)[None, :]
    tex = 0.5 + 0.5 * jnp.sin(12.3 * xs + 7.1 * ys + float(seed))
    img = 0.6 * (0.5 * xs + 0.5 * ys) + 0.4 * tex
    return jnp.clip(img, 0.0, 1.0)
