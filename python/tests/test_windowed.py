"""Windowed-kernel correctness: the per-tile source-window variant must
agree exactly with the resident-source kernel and the jnp oracle, plus
the VMEM estimator's structural claims."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bilinear import bilinear_pallas
from compile.kernels.bilinear_windowed import (
    bilinear_windowed_pallas,
    window_supported,
)
from compile.kernels.ref import bilinear_ref
from compile.model import test_image as make_test_image
from compile.vmem import L1Estimate


@pytest.mark.parametrize("scale", [1, 2, 4])
@pytest.mark.parametrize("tile", [(4, 32), (8, 8), (16, 16)])
def test_windowed_matches_ref(scale, tile):
    if not window_supported(scale, tile):
        pytest.skip("tile not divisible by scale")
    img = make_test_image(32, 32, seed=1)
    got = np.asarray(bilinear_windowed_pallas(img, scale, tile=tile))
    ref = np.asarray(bilinear_ref(img, scale))
    np.testing.assert_allclose(got, ref, atol=2e-6)


def test_windowed_matches_resident_bitwise():
    img = make_test_image(48, 48, seed=2)
    a = np.asarray(bilinear_windowed_pallas(img, 4, tile=(4, 32)))
    b = np.asarray(bilinear_pallas(img, 4, tile=(4, 32)))
    np.testing.assert_array_equal(a, b)


def test_rejects_non_divisible_tile():
    img = make_test_image(16, 16)
    with pytest.raises(ValueError):
        bilinear_windowed_pallas(img, 3, tile=(4, 32))
    assert not window_supported(3, (4, 32))
    assert window_supported(4, (4, 32))


@settings(max_examples=15, deadline=None)
@given(
    hw=st.tuples(st.integers(8, 40), st.integers(8, 40)),
    scale=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 50),
)
def test_hypothesis_windowed(hw, scale, seed):
    img = make_test_image(hw[0], hw[1], seed=seed)
    got = np.asarray(bilinear_windowed_pallas(img, scale, tile=(4, 32)))
    ref = np.asarray(bilinear_ref(img, scale))
    np.testing.assert_allclose(got, ref, atol=2e-6)


# ---------------------------------------------------------------------------
# VMEM estimator structure
# ---------------------------------------------------------------------------


def test_vmem_resident_vs_windowed():
    resident = L1Estimate("bilinear", (8, 128), (4096, 4096), 2, windowed=False)
    windowed = L1Estimate("bilinear", (8, 128), (4096, 4096), 2, windowed=True)
    # A 4096^2 f32 source (64 MiB) cannot stay resident; the window can.
    assert not resident.fits_vmem
    assert windowed.fits_vmem
    assert windowed.vmem_bytes < resident.vmem_bytes


def test_vmem_paper_source_fits_resident():
    e = L1Estimate("bilinear", (4, 32), (800, 800), 8, windowed=False)
    assert e.fits_vmem  # 2.56 MB source + tiles < 16 MiB


def test_lane_utilization_favors_wide_tiles():
    narrow = L1Estimate("bilinear", (32, 8), (800, 800), 2, windowed=True)
    wide = L1Estimate("bilinear", (2, 128), (800, 800), 2, windowed=True)
    assert wide.lane_utilization == 1.0
    assert narrow.lane_utilization < 0.1


def test_hbm_bytes_per_px_decrease_with_scale_amortization():
    small_tile = L1Estimate("bilinear", (4, 32), (800, 800), 8, windowed=True)
    big_tile = L1Estimate("bilinear", (16, 256), (800, 800), 8, windowed=True)
    assert big_tile.hbm_bytes_per_out_px < small_tile.hbm_bytes_per_out_px
