"""AOT pipeline tests: lowering produces loadable HLO text, the manifest
schema matches what the rust runtime parses, and lowered modules stay
numerically faithful when re-executed.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels.ref import REFS
from compile.model import example_input, make_resize, test_image as make_test_image


def test_artifact_names_unique():
    names = [aot.artifact_name(*row) for row in aot.BASE_MATRIX + aot.FULL_EXTRA]
    assert len(names) == len(set(names))


def test_lower_one_produces_hlo_text():
    text = aot.lower_one("bilinear", (16, 16), 2, 1, (4, 32))
    assert "HloModule" in text
    assert "ENTRY" in text
    # interpret=True must not leave TPU custom-calls behind
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_lowered_module_roundtrips_numerically():
    """Compile the lowered StableHLO back through XLA and compare with the
    eager model — catches lowering bugs before rust ever sees the file."""
    fn = make_resize("bilinear", 2, tile=(4, 32))
    spec = example_input(2, 16, 16)
    lowered = jax.jit(fn).lower(spec)
    compiled = lowered.compile()
    imgs = jnp.stack([make_test_image(16, 16, seed=i) for i in range(2)])
    got = np.asarray(compiled(imgs))
    want = np.asarray(fn(imgs))
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_self_check_passes_for_base_matrix_heads():
    for kernel in ("bilinear", "nearest", "bicubic"):
        err = aot.self_check(kernel, (32, 32), 2, 2, (4, 32))
        assert err < 2e-5


def test_self_check_catches_wrong_reference(monkeypatch):
    good = REFS["bilinear"]

    def bad_ref(src, scale):
        return good(src, scale) + 1.0

    monkeypatch.setitem(aot.REFS, "bilinear", bad_ref)
    with pytest.raises(AssertionError):
        aot.self_check("bilinear", (16, 16), 2, 1, (4, 32))


def test_manifest_written_and_parseable():
    with tempfile.TemporaryDirectory() as d:
        # Tiny ad-hoc matrix to keep the test fast.
        entries = []
        for kernel, src, scale, batch, tile in [
            ("bilinear", (16, 16), 2, 1, (4, 32)),
            ("nearest", (16, 16), 2, 2, (8, 8)),
        ]:
            name = aot.artifact_name(kernel, src, scale, batch, tile)
            text = aot.lower_one(kernel, src, scale, batch, tile)
            path = f"{name}.hlo.txt"
            with open(os.path.join(d, path), "w") as f:
                f.write(text)
            entries.append(
                {
                    "name": name,
                    "kernel": kernel,
                    "src": list(src),
                    "scale": scale,
                    "batch": batch,
                    "tile": list(tile),
                    "path": path,
                }
            )
        manifest = {"version": 1, "artifacts": entries}
        mpath = os.path.join(d, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        loaded = json.load(open(mpath))
        assert loaded["version"] == 1
        assert len(loaded["artifacts"]) == 2
        for e in loaded["artifacts"]:
            assert os.path.exists(os.path.join(d, e["path"]))
            assert set(e) >= {"name", "kernel", "src", "scale", "batch", "tile", "path"}


def test_hlo_text_batch_shapes_encoded():
    text = aot.lower_one("bilinear", (16, 16), 2, 3, (4, 32))
    # input [3,16,16] and output [3,32,32] must appear in the entry sig
    assert "f32[3,16,16]" in text
    assert "f32[3,32,32]" in text
