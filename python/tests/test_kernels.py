"""Kernel-vs-reference correctness: the CORE L1 signal.

Every Pallas kernel (interpret=True) is checked against the pure-jnp
oracle in `compile.kernels.ref` across shapes, scales, tiles, and dtypes
— both with fixed paper-relevant cases and hypothesis sweeps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bicubic import bicubic_pallas
from compile.kernels.bilinear import bilinear_pallas
from compile.kernels.nearest import nearest_pallas
from compile.kernels.ref import REFS, bilinear_ref
from compile.model import test_image as make_test_image

KERNELS = {
    "nearest": nearest_pallas,
    "bilinear": bilinear_pallas,
    "bicubic": bicubic_pallas,
}

TOL = {"nearest": 0.0, "bilinear": 2e-6, "bicubic": 5e-6}


def check(kernel_name, h, w, scale, tile, seed=0, dtype=jnp.float32, tol=None):
    img = make_test_image(h, w, seed=seed).astype(dtype)
    got = np.asarray(KERNELS[kernel_name](img, scale, tile=tile))
    ref = np.asarray(REFS[kernel_name](img, scale))
    assert got.shape == (h * scale, w * scale)
    err = float(np.max(np.abs(got.astype(np.float32) - ref.astype(np.float32))))
    limit = tol if tol is not None else TOL[kernel_name]
    assert err <= limit, f"{kernel_name} {h}x{w} s{scale} t{tile}: err {err}"


# ---------------------------------------------------------------------------
# Fixed cases: the paper's named tiles on small analogues of its workload.
# ---------------------------------------------------------------------------

PAPER_TILES = [(4, 32), (8, 8), (16, 16), (8, 4), (4, 8), (16, 32)]


@pytest.mark.parametrize("kernel", sorted(KERNELS))
@pytest.mark.parametrize("tile", PAPER_TILES)
def test_paper_tiles(kernel, tile):
    check(kernel, 32, 32, 2, tile)


@pytest.mark.parametrize("kernel", sorted(KERNELS))
@pytest.mark.parametrize("scale", [1, 2, 4, 6, 8, 10])
def test_paper_scales(kernel, scale):
    # 80x80 is the 1/10-size analogue of the paper's 800x800 source.
    check(kernel, 80, 80, scale, (4, 32))


@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_ragged_tiles(kernel):
    # Output 66x66 does not divide 4x32 tiles: Pallas masks the edge.
    check(kernel, 33, 33, 2, (4, 32))


@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_tile_bigger_than_output(kernel):
    check(kernel, 8, 8, 2, (64, 64))


def test_bf16_bilinear():
    # bf16 has ~3 decimal digits; tolerance scaled accordingly.
    check("bilinear", 32, 32, 2, (4, 32), dtype=jnp.bfloat16, tol=0.02)


def test_tile_variants_bitwise_equal():
    # Tiling must not change numerics (the property the paper's timing
    # comparison implicitly relies on).
    img = make_test_image(40, 40, seed=3)
    a = np.asarray(bilinear_pallas(img, 4, tile=(4, 32)))
    b = np.asarray(bilinear_pallas(img, 4, tile=(8, 8)))
    c = np.asarray(bilinear_pallas(img, 4, tile=(16, 4)))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)


def test_scale_one_is_identity():
    img = make_test_image(24, 24)
    for k in ("nearest", "bilinear"):
        out = np.asarray(KERNELS[k](img, 1))
        np.testing.assert_allclose(out, np.asarray(img), atol=1e-6)


def test_constant_image_invariant():
    img = jnp.full((16, 16), 0.37, dtype=jnp.float32)
    for k, fn in KERNELS.items():
        out = np.asarray(fn(img, 4))
        np.testing.assert_allclose(out, 0.37, atol=1e-5, err_msg=k)


def test_bilinear_midpoint_average():
    # [0, 1] row at scale 2: x_f=1 -> x_p=0.5 -> exact average.
    img = jnp.array([[0.0, 1.0]], dtype=jnp.float32)
    out = np.asarray(bilinear_pallas(img, 2, tile=(1, 4)))
    np.testing.assert_allclose(out[0], [0.0, 0.5, 1.0, 1.0], atol=1e-7)


def test_rejects_bad_args():
    from compile.model import make_resize

    with pytest.raises(ValueError):
        make_resize("sinc", 2)
    with pytest.raises(ValueError):
        make_resize("bilinear", 0)
    fn = make_resize("bilinear", 2)
    with pytest.raises(ValueError):
        fn(jnp.zeros((4, 4)))  # missing batch dim


# ---------------------------------------------------------------------------
# Hypothesis sweeps.
# ---------------------------------------------------------------------------

shape_st = st.tuples(st.integers(2, 40), st.integers(2, 40))
scale_st = st.integers(1, 8)
tile_st = st.tuples(
    st.sampled_from([1, 2, 4, 8, 16, 32]), st.sampled_from([1, 2, 4, 8, 16, 32])
)


@settings(max_examples=30, deadline=None)
@given(shape=shape_st, scale=scale_st, tile=tile_st, seed=st.integers(0, 10))
def test_hypothesis_bilinear(shape, scale, tile, seed):
    check("bilinear", shape[0], shape[1], scale, tile, seed=seed)


@settings(max_examples=20, deadline=None)
@given(shape=shape_st, scale=scale_st, tile=tile_st)
def test_hypothesis_nearest(shape, scale, tile):
    check("nearest", shape[0], shape[1], scale, tile)


@settings(max_examples=12, deadline=None)
@given(shape=st.tuples(st.integers(4, 24), st.integers(4, 24)), scale=st.integers(1, 4))
def test_hypothesis_bicubic(shape, scale):
    check("bicubic", shape[0], shape[1], scale, (4, 32))


@settings(max_examples=20, deadline=None)
@given(
    shape=st.tuples(st.integers(2, 24), st.integers(2, 24)),
    scale=st.integers(1, 6),
    seed=st.integers(0, 100),
)
def test_hypothesis_bilinear_bounds_and_samples(shape, scale, seed):
    """Structural properties, independent of the reference: outputs stay
    in the input range and reproduce the source at sample points."""
    h, w = shape
    img = make_test_image(h, w, seed=seed)
    out = np.asarray(bilinear_pallas(img, scale, tile=(4, 32)))
    src = np.asarray(img)
    assert out.min() >= src.min() - 1e-6
    assert out.max() <= src.max() + 1e-6
    np.testing.assert_allclose(out[::scale, ::scale], src, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(scale=st.integers(1, 4), batch=st.integers(1, 5))
def test_hypothesis_vmap_batch_consistency(scale, batch):
    """The batched L2 model must equal per-image kernel calls."""
    from compile.model import make_resize

    imgs = jnp.stack([make_test_image(16, 16, seed=i) for i in range(batch)])
    fn = make_resize("bilinear", scale)
    got = np.asarray(fn(imgs))
    for i in range(batch):
        ref = np.asarray(bilinear_ref(imgs[i], scale))
        np.testing.assert_allclose(got[i], ref, atol=2e-6)
