//! OUT-OF-PROCESS FLEET DEMO — the `net` subsystem end to end, without
//! leaving one process: two fleet shards behind `NetServer`s on
//! loopback TCP, a consistent-hash `FrontTier` routing by request
//! shape, and a mid-run drain+remove of the shard that owns the demo
//! shape — with zero lost tickets.
//!
//! Phases:
//!
//! 1. **bind** — two 2-member mock fleets (GTX 260 + Fermi each, tuned
//!    per device) go on ephemeral loopback ports.
//! 2. **route** — the front tier hashes the demo shape (bilinear
//!    64x64, scale 2) onto one owner shard; every request with the
//!    same shape lands there.
//! 3. **failover** — with half the workload in flight, every member of
//!    the owner shard is drained and removed through the *remote*
//!    control plane; one health poll later the same shape routes to
//!    the survivor.
//! 4. **settle** — all tickets (including those owed by the removed
//!    members) resolve; the merged fleet-of-fleets stats count both
//!    shards.
//!
//! The multi-process version of this flow is `make -C rust net-demo`
//! (real `tilekit serve --listen` processes + `tilekit front`).
//!
//! Run: `cargo run --release --example net_fleet`

use std::sync::Arc;
use tilekit::autotuner::{SimCostModel, TuningSession};
use tilekit::config::ServingConfig;
use tilekit::coordinator::{DrainMode, Fleet, FleetBuilder, Request, RequestKey, TilePolicy};
use tilekit::device::{find_device, DeviceDescriptor};
use tilekit::image::{generate, Interpolator};
use tilekit::net::{
    BackendFactory, FrontTier, FrontTierConfig, ListenAddr, NetServer, NetServerConfig,
};
use tilekit::runtime::{Manifest, MockEngine, ResizeBackend};
use tilekit::tiling::TileDim;

fn shard_fleet() -> anyhow::Result<Arc<Fleet>> {
    let manifest = Manifest::fleet_demo();
    let gtx = find_device("gtx260").expect("builtin");
    let fermi = find_device("fermi").expect("builtin");
    let outcome = TuningSession::new(SimCostModel)
        .devices([gtx.clone(), fermi.clone()])
        .kernel(Interpolator::Bilinear)
        .scale(2)
        .src((64, 64))
        .tiles([TileDim::new(16, 8), TileDim::new(32, 16)])
        .run()?;
    let cfg = ServingConfig {
        workers: 2,
        batch_max: Some(4),
        batch_deadline_ms: 0.5,
        queue_cap: 1024,
        ..ServingConfig::default()
    };
    let fleet = FleetBuilder::new(&cfg, &manifest)
        .device(
            gtx,
            Arc::new(MockEngine::new()),
            TilePolicy::PerDevice(outcome.clone()),
        )
        .device(
            fermi,
            Arc::new(MockEngine::new()),
            TilePolicy::PerDevice(outcome),
        )
        .build()?;
    Ok(Arc::new(fleet))
}

fn main() -> anyhow::Result<()> {
    // Phase 1: two shards on ephemeral loopback ports.
    let factory: BackendFactory =
        Arc::new(|_d: &DeviceDescriptor| Arc::new(MockEngine::new()) as Arc<dyn ResizeBackend>);
    let mut servers = Vec::new();
    for _ in 0..2 {
        let fleet = shard_fleet()?;
        let server = NetServer::bind(
            &ListenAddr::Tcp("127.0.0.1:0".into()),
            fleet,
            Arc::clone(&factory),
            NetServerConfig::default(),
        )?;
        println!("shard listening on {}", server.local_addr());
        servers.push(server);
    }
    let addrs: Vec<ListenAddr> = servers.iter().map(|s| s.local_addr().clone()).collect();

    // Phase 2: the front tier routes the demo shape to one owner.
    let tier = FrontTier::connect(
        &addrs,
        FrontTierConfig {
            health_poll: None, // we drive polls by hand below
            ..FrontTierConfig::default()
        },
    )
    .map_err(|e| anyhow::anyhow!("front tier connect: {e}"))?;
    let probe = generate::test_scene(64, 64, 0);
    let key = RequestKey::of(Interpolator::Bilinear, &probe, 2);
    let owner = tier.route_for(&key).expect("two live shards");
    println!(
        "\ndemo shape bilinear 64x64 s2 hashes to shard {owner} ({})",
        addrs[owner]
    );

    const N: usize = 32;
    let mut tickets = Vec::new();
    for i in 0..N / 2 {
        let (shard, t) = tier
            .submit(&Request::new(
                Interpolator::Bilinear,
                generate::test_scene(64, 64, i as u64),
                2,
            ))
            .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
        assert_eq!(shard, owner);
        tickets.push(t);
    }
    println!("submitted {} tickets to the owner shard", N / 2);

    // Phase 3: drain + remove the owner's members over the wire.
    let victim = tier.client(owner);
    let topo = victim
        .topology()
        .map_err(|e| anyhow::anyhow!("topology: {e}"))?;
    for m in &topo.members {
        victim
            .drain(&m.label)
            .map_err(|e| anyhow::anyhow!("drain: {e}"))?;
    }
    for m in &topo.members {
        victim
            .remove_member(&m.label, DrainMode::Graceful)
            .map_err(|e| anyhow::anyhow!("remove: {e}"))?;
    }
    tier.poll_once();
    println!(
        "drained + removed shard {owner}'s members; shape now routes to shard {}",
        tier.route_for(&key).expect("survivor is live")
    );

    for i in 0..N / 2 {
        let (shard, t) = tier
            .submit(&Request::new(
                Interpolator::Bilinear,
                generate::test_scene(64, 64, 1000 + i as u64),
                2,
            ))
            .map_err(|e| anyhow::anyhow!("submit after drain: {e}"))?;
        assert_ne!(shard, owner, "post-drain traffic must reroute");
        tickets.push(t);
    }

    // Phase 4: every ticket resolves — including those the removed
    // members still owed when the drain started.
    let mut done = 0;
    for t in tickets {
        t.wait().map_err(|e| anyhow::anyhow!("wait: {e}"))?;
        done += 1;
    }
    println!("\ncompleted {done}/{N} (zero lost tickets)");
    for v in tier.shard_views() {
        println!(
            "  {} — alive {}, draining {}, epoch {}",
            v.addr, v.alive, v.draining, v.epoch
        );
    }
    println!("\nmerged stats: {}", tier.merged_stats().summary());

    tier.shutdown();
    for s in servers {
        s.shutdown();
    }
    Ok(())
}
