//! ELASTIC FLEET DEMO — the typed control plane end to end: a fleet
//! that reshapes itself while serving, no restart, no drain-the-world.
//!
//! Scenario (each phase runs under live traffic):
//!
//! 1. **add** — start a 1-member fleet (GTX 260), then
//!    `FleetController::add_member` brings a tuned Fermi in live; the
//!    cost-eta scheduler immediately exploits the cheaper tuned tile.
//! 2. **skew** — swap the scheduler to a deterministic 85% hot-spot
//!    (`set_scheduler`) and loosen the steal threshold
//!    (`set_steal_config`): idle capacity pulls queued work out of the
//!    hot member.
//! 3. **retune** — a "re-tuning run" flips the hot member's winner;
//!    `FleetController::retune` hot-swaps its router mid-flight.
//! 4. **remove** — `remove_member(DrainMode::Graceful)` retires the
//!    original member; every ticket it still owed completes first.
//!
//! Run: `cargo run --release --example elastic_fleet`
//! (or `make -C rust elastic-demo`)

use std::sync::Arc;
use std::time::Duration;
use tilekit::autotuner::{SimCostModel, TuningSession};
use tilekit::config::ServingConfig;
use tilekit::coordinator::{
    Biased, BlockWithTimeout, CostModelEta, DrainMode, FleetBuilder, FleetController, Request,
    TilePolicy,
};
use tilekit::image::Interpolator;
use tilekit::runtime::{Manifest, MockEngine};
use tilekit::tiling::TileDim;
use tilekit::util::text::Table;

fn topology_row(table: &mut Table, phase: &str, ctl: &FleetController) {
    let topo = ctl.topology();
    let members = topo
        .members
        .iter()
        .map(|m| {
            format!(
                "{}{}->{} ({} done)",
                m.label,
                if m.draining { "[draining]" } else { "" },
                m.tile_pref.map(|t| t.label()).unwrap_or_default(),
                m.stats.completed.get(),
            )
        })
        .collect::<Vec<_>>()
        .join("  ");
    table.row(vec![phase.to_string(), topo.epoch.to_string(), members]);
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::fleet_demo();
    let gtx = tilekit::device::find_device("gtx260").expect("builtin");
    let fermi = tilekit::device::find_device("fermi").expect("builtin");
    let tiles = [TileDim::new(16, 8), TileDim::new(32, 16)];
    let outcome = TuningSession::new(SimCostModel)
        .devices([gtx.clone(), fermi.clone()])
        .kernel(Interpolator::Bilinear)
        .scale(2)
        .src((64, 64))
        .tiles(tiles)
        .run()?;
    println!("tuned fleet (bilinear 64x64, scale 2):");
    for d in &outcome.per_device {
        println!("  {:<8} best tile {} at {:.4} ms/launch", d.device_id, d.best, d.best_ms);
    }

    let cfg = ServingConfig {
        workers: 1,
        batch_max: Some(2),
        batch_deadline_ms: 0.2,
        queue_cap: 1024,
        steal_threshold: 4,
        ..ServingConfig::default()
    };
    let delay = Duration::from_millis(1);
    let fleet = FleetBuilder::new(&cfg, &manifest)
        .device(
            gtx.clone(),
            Arc::new(MockEngine::with_delay(delay)),
            TilePolicy::PerDevice(outcome.clone()),
        )
        .scheduler(CostModelEta)
        .admission(BlockWithTimeout(Duration::from_secs(30)))
        .build()?;
    let ctl = fleet.controller();
    let img = tilekit::image::generate::test_scene(64, 64, 7);
    let wave = |n: usize| -> anyhow::Result<()> {
        let tickets: Vec<_> = (0..n)
            .map(|_| fleet.submit(Request::new(Interpolator::Bilinear, img.clone(), 2)))
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        for t in tickets {
            t.wait()?;
        }
        Ok(())
    };
    let mut timeline = Table::new(vec!["phase", "epoch", "members (tile, completed)"]);

    // Phase 0: the single-member baseline.
    wave(40)?;
    topology_row(&mut timeline, "solo gtx260", &ctl);

    // Phase 1: add a tuned Fermi to the LIVE fleet.
    ctl.add_member(
        fermi.clone(),
        Arc::new(MockEngine::with_delay(delay)),
        TilePolicy::PerDevice(outcome.clone()),
    )?;
    wave(40)?;
    topology_row(&mut timeline, "+ add fermi", &ctl);

    // Phase 2: skew the traffic 85% onto gtx260 and let stealing absorb
    // it (both knobs swapped live through the control plane).
    ctl.set_scheduler(Biased::new(0, 85))?;
    ctl.set_steal_config(true, 2)?;
    wave(60)?;
    topology_row(&mut timeline, "+ skew 85% hot", &ctl);

    // Phase 3: a re-tuning run flips gtx260's winner; hot-swap it.
    let flipped = outcome
        .with_flipped_winner("gtx260")
        .expect("tuned device has launchable points");
    let new_tile = ctl.retune("gtx260", &flipped)?;
    println!(
        "\nretuned gtx260 -> {} mid-flight (no drain)",
        new_tile.map(|t| t.label()).unwrap_or_default()
    );
    wave(30)?;
    topology_row(&mut timeline, "+ retune gtx260", &ctl);

    // Phase 4: retire gtx260 gracefully — queued work completes first.
    ctl.set_scheduler(CostModelEta)?;
    ctl.remove_member("gtx260", DrainMode::Graceful)?;
    wave(30)?;
    topology_row(&mut timeline, "- remove gtx260", &ctl);

    println!();
    print!("{}", timeline.render());
    let stats = fleet.shutdown();
    println!(
        "\nfleet total: completed={} failed={} shed={} steals={} retunes={} — \
         {} requests served across 4 reconfigurations with zero losses",
        stats.completed.get(),
        stats.failed.get(),
        stats.shed.get(),
        stats.steals.get(),
        stats.retunes.get(),
        stats.completed.get(),
    );
    if stats.failed.get() == 0 && stats.shed.get() == 0 {
        println!("=> elastic membership: reconfigure the fleet, not the deployment.");
    } else {
        println!("!! unexpected: the elastic fleet dropped work");
    }
    Ok(())
}
