//! Open-loop trace replay: generate Poisson/bursty workload traces,
//! replay them against the coordinator at increasing offered load, and
//! report the latency-vs-load curve — the serving-evaluation methodology
//! (closed-loop drivers saturate the queue and only measure throughput).
//!
//! Uses the PJRT backend when artifacts exist, else the mock.
//!
//! Run: `cargo run --release --example trace_replay [-- rate_rps...]`

use std::path::Path;
use std::sync::Arc;
use tilekit::config::ServingConfig;
use tilekit::coordinator::{FleetBuilder, RejectWhenFull, Request, TilePolicy};
use tilekit::image::generate;
use tilekit::runtime::executor::EngineHandle;
use tilekit::runtime::{Manifest, MockEngine, ResizeBackend};
use tilekit::util::text::Table;
use tilekit::workload::{replay, Arrival, Trace};

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (manifest, make_backend): (Manifest, Box<dyn Fn() -> Arc<dyn ResizeBackend>>) =
        match Manifest::load(&dir) {
            Ok(m) => {
                let mm = m.clone();
                (
                    m,
                    Box::new(move || Arc::new(EngineHandle::new(mm.clone())) as _),
                )
            }
            Err(e) => {
                eprintln!("NOTE: no artifacts ({e}); using the mock backend");
                let m = Manifest::parse(
                    r#"{"version":1,"artifacts":[
                        {"name":"bl_s2_b4","kernel":"bilinear","src":[64,64],
                         "scale":2,"batch":4,"tile":[4,32],"path":"x"}]}"#,
                    dir,
                )?;
                (m, Box::new(|| Arc::new(MockEngine::new()) as _))
            }
        };

    let rates: Vec<f64> = {
        let args: Vec<f64> = std::env::args()
            .skip(1)
            .filter_map(|a| a.parse().ok())
            .collect();
        if args.is_empty() {
            vec![50.0, 100.0, 200.0, 400.0]
        } else {
            args
        }
    };
    let n = 150;

    let mut table = Table::new(vec![
        "arrival", "offered rps", "completed", "rejected", "p50 us", "p99 us", "achieved rps",
    ]);
    for &rate in &rates {
        for (name, arrival) in [
            ("poisson", Arrival::Poisson { rate }),
            ("bursty(4)", Arrival::Bursty { rate: rate / 4.0, burst: 4 }),
        ] {
            let cfg = ServingConfig {
                workers: 2,
                batch_max: Some(4),
                batch_deadline_ms: 1.0,
                queue_cap: 64,
                artifacts_dir: "artifacts".into(),
                ..ServingConfig::default()
            };
            // Open-loop driver: backpressure must be recorded, not
            // absorbed, so admission is strictly non-blocking (largest-
            // tile variants per EXPERIMENTS.md §Perf).
            let svc = FleetBuilder::new(&cfg, &manifest)
                .backend(make_backend(), TilePolicy::PortableFallback)
                .admission(RejectWhenFull)
                .build()?;
            let keys = svc.keys();
            // warm every worker/shape outside the measured replay
            let warm: Vec<_> = (0..2 * cfg.workers)
                .flat_map(|_| {
                    keys.iter().map(|k| {
                        let img =
                            generate::test_scene(k.src.1 as usize, k.src.0 as usize, 0);
                        svc.submit(Request::new(k.kernel, img, k.scale)).unwrap()
                    })
                })
                .collect();
            for t in warm {
                t.wait()?;
            }
            svc.reset_stats();

            let trace = Trace::generate(&keys, n, arrival, 42);
            let out = replay(&svc, &trace);
            table.row(vec![
                name.to_string(),
                format!("{rate:.0}"),
                out.completed.to_string(),
                out.rejected.to_string(),
                format!("{:.0}", out.latency.percentile_us(50.0)),
                format!("{:.0}", out.latency.percentile_us(99.0)),
                format!("{:.0}", out.achieved_rps()),
            ]);
            svc.shutdown();
        }
    }
    println!("\nopen-loop latency vs offered load ({n} requests per cell):\n");
    print!("{}", table.render());
    println!("\n(rejected > 0 marks the saturation knee — backpressure is working)");
    Ok(())
}
