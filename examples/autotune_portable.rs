//! Portable tiling selection across the whole device registry — the
//! paper's §V conclusion ("optimize for the worst-case GPU") extended to
//! seven real GPU models + the two synthetic ones.
//!
//! For each scale, prints each device's own best tile and the min-max
//! regret (portable) tile, then shows how much each device loses by
//! adopting the portable tile instead of its personal best.
//!
//! Run: `cargo run --release --example autotune_portable`

use tilekit::autotuner::{portable_tile, sweep};
use tilekit::device::builtin_devices;
use tilekit::image::Interpolator;
use tilekit::tiling::paper_sweep_tiles;
use tilekit::util::text::Table;

fn main() {
    let devices = builtin_devices();
    let tiles = paper_sweep_tiles();

    for scale in [2u32, 6, 10] {
        println!("=== scale {scale} ===\n");
        let sweeps: Vec<_> = devices
            .iter()
            .map(|d| sweep(d, Interpolator::Bilinear, &tiles, scale, (800, 800)))
            .collect();
        let choice = portable_tile(&sweeps).expect("non-empty registry");
        let mut t = Table::new(vec![
            "device",
            "own best",
            "own best ms",
            "portable ms",
            "regret",
        ]);
        for s in &sweeps {
            let best = s.best().unwrap();
            let portable_ms = s.time_of(choice.tile).unwrap();
            t.row(vec![
                s.device_id.clone(),
                best.tile.label(),
                format!("{:.3}", best.report.ms),
                format!("{portable_ms:.3}"),
                format!("{:.3}x", portable_ms / best.report.ms),
            ]);
        }
        print!("{}", t.render());
        println!(
            "\nportable tile: {} (worst-case regret {:.3}x)\n",
            choice.tile, choice.worst_regret
        );
    }
    println!(
        "Paper §V: \"the tiling dimensions 32x4 seems to be a better choice which can\n\
         offer better performance in general when performing in different situations,\n\
         especially for larger final images.\""
    );
}
