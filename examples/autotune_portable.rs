//! Portable tiling selection across the whole device registry — the
//! paper's §V conclusion ("optimize for the worst-case GPU") extended to
//! seven real GPU models + the two synthetic ones, driven through the
//! `TuningSession` API.
//!
//! For each scale, one session tunes every registry device and reports
//! each device's own best tile, the min-max regret (portable) tile, and
//! how much each device loses by adopting the portable tile instead of
//! its personal best.
//!
//! Run: `cargo run --release --example autotune_portable`

use tilekit::autotuner::{SimCostModel, TuningSession};
use tilekit::device::builtin_devices;
use tilekit::util::text::Table;

fn main() {
    for scale in [2u32, 6, 10] {
        println!("=== scale {scale} ===\n");
        let outcome = TuningSession::new(SimCostModel)
            .devices(builtin_devices())
            .scale(scale)
            .run()
            .expect("every registry device launches some paper tile");
        let choice = outcome
            .portable
            .as_ref()
            .expect("some tile is launchable on every device");
        let mut t = Table::new(vec![
            "device",
            "own best",
            "own best ms",
            "portable ms",
            "regret",
        ]);
        for dt in &outcome.per_device {
            let portable_ms = dt
                .time_of(choice.tile)
                .expect("portable tile was evaluated everywhere");
            t.row(vec![
                dt.device_id.clone(),
                dt.best.label(),
                format!("{:.3}", dt.best_ms),
                format!("{portable_ms:.3}"),
                format!("{:.3}x", portable_ms / dt.best_ms),
            ]);
        }
        print!("{}", t.render());
        println!(
            "\nportable tile: {} (worst-case regret {:.3}x, {} evaluations)\n",
            choice.tile, choice.worst_regret, outcome.evaluations
        );
    }
    println!(
        "Paper §V: \"the tiling dimensions 32x4 seems to be a better choice which can\n\
         offer better performance in general when performing in different situations,\n\
         especially for larger final images.\""
    );
}
