//! ADAPTIVE FLEET DEMO — what per-device tuning buys once the runtime
//! adapts to load. A 2-device simulated fleet (GTX 260 / cc1.3 vs
//! Fermi / cc2.0) serves the same **skewed** trace (85% of submissions
//! pinned to one member) twice:
//!
//! 1. static (PR 2): each member keeps whatever the scheduler gave it —
//!    the hot member's queue grows while the other idles;
//! 2. adaptive: work-stealing on — the idle member's batcher pulls
//!    compatible pending requests out of the hot queue and serves them
//!    through its *own* tuned tile.
//!
//! The adaptive fleet wins on BOTH aggregate sim cost (stolen overflow
//! executes on the device whose tuned tile simulates cheaper) and
//! interactive p99 (the hot queue stops being the only way through) —
//! asserted for real in `rust/tests/fleet_serving.rs`. Each member's
//! `batch_max` is derived from its compute capability, so the Fermi
//! part also batches bigger while it helps out.
//!
//! Run: `cargo run --release --example adaptive_fleet`

use std::sync::Arc;
use std::time::Duration;
use tilekit::autotuner::{SimCostModel, TuningSession};
use tilekit::config::ServingConfig;
use tilekit::coordinator::{
    Biased, BlockWithTimeout, FleetBuilder, Priority, RequestKey, TilePolicy,
};
use tilekit::image::Interpolator;
use tilekit::runtime::{Manifest, MockEngine};
use tilekit::tiling::TileDim;
use tilekit::util::text::Table;
use tilekit::workload::{replay, Arrival, Trace};

struct RunSummary {
    sim_cost_ms: f64,
    p99_us: f64,
    steals: u64,
    per_member: Vec<(String, String, u64, u64)>,
}

fn serve_skewed(
    manifest: &Manifest,
    outcome: &tilekit::autotuner::TuningOutcome,
    hot: usize,
    stealing: bool,
    trace: &Trace,
) -> anyhow::Result<RunSummary> {
    let cfg = ServingConfig {
        workers: 1,
        batch_max: None, // derived per member from compute capability
        batch_deadline_ms: 0.2,
        queue_cap: 1024,
        work_stealing: stealing,
        steal_threshold: 2,
        ..ServingConfig::default()
    };
    let delay = Duration::from_millis(2);
    let svc = FleetBuilder::new(&cfg, manifest)
        .device(
            tilekit::device::find_device("gtx260").expect("builtin"),
            Arc::new(MockEngine::with_delay(delay)),
            TilePolicy::PerDevice(outcome.clone()),
        )
        .device(
            tilekit::device::find_device("fermi").expect("builtin"),
            Arc::new(MockEngine::with_delay(delay)),
            TilePolicy::PerDevice(outcome.clone()),
        )
        .scheduler(Biased::new(hot, 85))
        .admission(BlockWithTimeout(Duration::from_secs(30)))
        .build()?;
    let out = replay(&svc, trace);
    anyhow::ensure!(
        out.completed == trace.events.len(),
        "replay must complete everything: {}",
        out.summary()
    );
    let per_member: Vec<(String, String, u64, u64)> = svc
        .members()
        .iter()
        .map(|v| {
            (
                format!("{} (batch_max {})", v.label, v.batch_max),
                v.tile_pref.map(|t| t.label()).unwrap_or_default(),
                v.stats.completed.get(),
                v.stats.steals.get(),
            )
        })
        .collect();
    let stats = svc.shutdown();
    Ok(RunSummary {
        sim_cost_ms: stats.sim_cost_ms(),
        p99_us: stats.latency_by_class[Priority::Interactive.index()].percentile_us(99.0),
        steals: stats.steals.get(),
        per_member,
    })
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::fleet_demo();
    let tiles = [TileDim::new(16, 8), TileDim::new(32, 16)];
    let outcome = TuningSession::new(SimCostModel)
        .devices([
            tilekit::device::find_device("gtx260").expect("builtin"),
            tilekit::device::find_device("fermi").expect("builtin"),
        ])
        .kernel(Interpolator::Bilinear)
        .scale(2)
        .src((64, 64))
        .tiles(tiles)
        .run()?;
    println!("tuned fleet (bilinear 64x64, scale 2):");
    for d in &outcome.per_device {
        println!(
            "  {:<8} best tile {} at {:.4} ms/launch",
            d.device_id, d.best, d.best_ms
        );
    }
    // Hot-spot the device whose tuned tile simulates more expensive, so
    // stolen overflow lands on the cheaper one.
    let ms_of = |id: &str| outcome.device(id).map(|d| d.best_ms).unwrap_or(f64::MAX);
    let hot = if ms_of("gtx260") >= ms_of("fermi") { 0 } else { 1 };
    println!(
        "\nskew: 85% of submissions pinned to member {hot} ({})\n",
        if hot == 0 { "gtx260" } else { "fermi" }
    );

    let trace = Trace::generate(
        &[RequestKey {
            kernel: Interpolator::Bilinear,
            src: (64, 64),
            scale: 2,
        }],
        160,
        Arrival::Immediate,
        2010,
    );

    let mut table = Table::new(vec![
        "fleet",
        "per-member (completed/steals)",
        "steals",
        "sim cost ms",
        "interactive p99 us",
    ]);
    let mut results = Vec::new();
    for (name, stealing) in [("static (PR 2)", false), ("adaptive", true)] {
        let r = serve_skewed(&manifest, &outcome, hot, stealing, &trace)?;
        let members = r
            .per_member
            .iter()
            .map(|(id, tile, done, steals)| format!("{id}->{tile}: {done}/{steals}"))
            .collect::<Vec<_>>()
            .join("  ");
        table.row(vec![
            name.to_string(),
            members,
            r.steals.to_string(),
            format!("{:.3}", r.sim_cost_ms),
            format!("{:.0}", r.p99_us),
        ]);
        results.push((name, r));
    }
    print!("{}", table.render());

    let (static_run, adaptive_run) = (&results[0].1, &results[1].1);
    println!(
        "\nadaptive vs static: sim cost {:.3} -> {:.3} ms ({:.1}% cheaper), \
         interactive p99 {:.0} -> {:.0} us ({:.1}% faster), {} steals",
        static_run.sim_cost_ms,
        adaptive_run.sim_cost_ms,
        (1.0 - adaptive_run.sim_cost_ms / static_run.sim_cost_ms) * 100.0,
        static_run.p99_us,
        adaptive_run.p99_us,
        (1.0 - adaptive_run.p99_us / static_run.p99_us) * 100.0,
        adaptive_run.steals,
    );
    if adaptive_run.sim_cost_ms < static_run.sim_cost_ms && adaptive_run.p99_us < static_run.p99_us
    {
        println!("=> idle capacity + per-device tiles absorb the hot spot.");
    } else {
        println!("!! unexpected: the adaptive fleet did not win on both axes");
    }
    Ok(())
}
