//! END-TO-END SYSTEM DRIVER (the repo's full-stack validation, recorded
//! in EXPERIMENTS.md): loads the AOT Pallas artifacts built by `make
//! artifacts`, starts the L3 coordinator with the PJRT backend, replays
//! a mixed batched workload, verifies every response against the rust
//! CPU reference, and reports latency percentiles + throughput — all
//! three layers composing with Python nowhere on the request path.
//!
//! Run: `make artifacts && cargo run --release --example serving_e2e`

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};
use tilekit::config::ServingConfig;
use tilekit::coordinator::{
    BlockWithTimeout, FleetBuilder, Priority, Request, TilePolicy,
};
use tilekit::image::{generate, Image};
use tilekit::runtime::executor::EngineHandle;
use tilekit::runtime::{Manifest, ResizeBackend};
use tilekit::util::text::Table;
use tilekit::util::Pcg32;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Manifest::load(&dir)
        .map_err(|e| anyhow::anyhow!("{e:#}\nrun `make artifacts` first"))?;
    println!(
        "loaded manifest: {} artifacts, shapes: {:?}",
        manifest.entries.len(),
        manifest.shapes().len()
    );

    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);

    let cfg = ServingConfig {
        workers: 2,
        batch_max: Some(4),
        batch_deadline_ms: 1.5,
        queue_cap: 256,
        artifacts_dir: "artifacts".into(),
        ..ServingConfig::default()
    };
    let backend: Arc<dyn ResizeBackend> = Arc::new(EngineHandle::new(manifest.clone()));
    // Single-backend deployment: largest-tile (CPU-optimal) variants
    // (EXPERIMENTS.md §Perf); closed loop, so block on backpressure.
    let svc = FleetBuilder::new(&cfg, &manifest)
        .backend(backend, TilePolicy::PortableFallback)
        .admission(BlockWithTimeout(Duration::from_secs(60)))
        .build()?;
    let keys = svc.keys();

    // Warmup: each worker thread compiles artifacts on first use (the
    // PJRT client is thread-local); warm every shape through every
    // worker before the timed region so the numbers measure serving,
    // not compilation.
    let warm: Vec<_> = (0..2 * cfg.workers.max(1))
        .flat_map(|_| {
            keys.iter().map(|key| {
                let img = generate::test_scene(key.src.1 as usize, key.src.0 as usize, 0);
                svc.submit(Request::new(key.kernel, img, key.scale))
                    .expect("warm")
            })
        })
        .collect();
    for t in warm {
        t.wait()?;
    }

    svc.reset_stats();

    // Mixed workload: random artifact shapes, deterministic images, a
    // quarter of the traffic batch-class for the QoS histograms.
    let mut rng = Pcg32::seeded(2010);
    let workload: Vec<_> = (0..n_requests)
        .map(|i| {
            let key = *rng.pick(&keys);
            let img = generate::test_scene(key.src.1 as usize, key.src.0 as usize, rng.next_u64());
            let priority = if i % 4 == 3 {
                Priority::Batch
            } else {
                Priority::Interactive
            };
            (key, img, priority)
        })
        .collect();

    println!(
        "replaying {} requests over {} shapes (workers={}, batch_max={}) ...",
        n_requests,
        keys.len(),
        cfg.workers,
        cfg.batch_max.expect("pinned above")
    );
    let t0 = Instant::now();
    let tickets: Vec<_> = workload
        .iter()
        .map(|(key, img, priority)| {
            (
                *key,
                img.clone(),
                svc.submit(
                    Request::new(key.kernel, img.clone(), key.scale).priority(*priority),
                )
                .expect("admitted"),
            )
        })
        .collect();

    let mut verified = 0usize;
    let mut max_err = 0f32;
    for (key, img, ticket) in tickets {
        let out: Image<f32> = ticket.wait()?;
        // Verify against the rust CPU reference.
        let want = key.kernel.run(&img, key.scale);
        let err = out.max_abs_diff(&want);
        max_err = max_err.max(err);
        assert!(err < 2e-5, "response numerics drifted: {err}");
        verified += 1;
    }
    let wall = t0.elapsed();
    let stats = svc.shutdown();

    println!("\nall {verified} responses verified against the CPU reference (max|err| {max_err:.2e})\n");
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec![
        "wall time".to_string(),
        format!("{:.1} ms", wall.as_secs_f64() * 1e3),
    ]);
    t.row(vec![
        "throughput".to_string(),
        format!("{:.1} req/s", n_requests as f64 / wall.as_secs_f64()),
    ]);
    t.row(vec!["batches".to_string(), stats.batches.get().to_string()]);
    t.row(vec![
        "mean batch size".to_string(),
        format!("{:.2}", stats.mean_batch()),
    ]);
    t.row(vec![
        "latency p50".to_string(),
        format!("{:.0} us", stats.latency.percentile_us(50.0)),
    ]);
    t.row(vec![
        "latency p90".to_string(),
        format!("{:.0} us", stats.latency.percentile_us(90.0)),
    ]);
    t.row(vec![
        "latency p99".to_string(),
        format!("{:.0} us", stats.latency.percentile_us(99.0)),
    ]);
    t.row(vec![
        "queue wait p50".to_string(),
        format!("{:.0} us", stats.queue_wait.percentile_us(50.0)),
    ]);
    print!("{}", t.render());
    println!("\n{}", stats.summary());
    println!("\nper-priority latency:\n{}", stats.class_summary());
    Ok(())
}
