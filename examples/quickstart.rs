//! Quickstart: the 60-second tour of tilekit's public API.
//!
//! 1. Look up the paper's two GPUs in the device registry.
//! 2. Ask the occupancy calculator about the §III.B 32×16 cliff.
//! 3. Simulate one kernel launch on each device.
//! 4. Run a `TuningSession` over both devices: per-device best tiles
//!    plus the portable (min-max regret) pick — the paper's 32×4.
//!    Swap in `CoordinateDescent` or a `Cached` strategy to tune with
//!    fewer simulator evaluations or a persistent `tuning_cache.json`.
//! 5. If artifacts are built (`make artifacts`), resize a real image
//!    through the AOT Pallas kernel via PJRT.
//!
//! Run: `cargo run --release --example quickstart`

use std::path::Path;
use tilekit::autotuner::{SimCostModel, TuningSession};
use tilekit::device::paper_pair;
use tilekit::image::{generate, pnm, Interpolator};
use tilekit::runtime::{Engine, Manifest};
use tilekit::sim::{simulate, Launch};
use tilekit::tiling::occupancy::{occupancy, KernelResources};

fn main() -> anyhow::Result<()> {
    // 1. The paper's testbed.
    let (gtx, gts) = paper_pair();
    println!("devices: {gtx}\n         {gts}\n");

    // 2. The §III.B occupancy cliff.
    let tile = "32x16".parse().unwrap();
    for dev in [&gtx, &gts] {
        let o = occupancy(tile, &KernelResources::BILINEAR, &dev.cc);
        println!(
            "occupancy of 32x16 on {}: {} blocks/SM, {} threads, {:.0}%",
            dev.id,
            o.blocks_per_sm,
            o.threads_per_sm,
            o.ratio * 100.0
        );
    }

    // 3. Simulate the paper's workload: 800x800 at scale 8, tile 32x4.
    let launch = Launch::paper(Interpolator::Bilinear, "32x4".parse().unwrap(), 8);
    println!();
    for dev in [&gtx, &gts] {
        let r = simulate(&launch, dev, None);
        println!(
            "simulate 800x800 x8 bilinear @32x4 on {:>8}: {:8.3} ms ({:.0} Mpix/s)",
            dev.id,
            r.ms,
            r.mpix_per_s(&launch)
        );
    }

    // 4. A tuning session over both devices (the paper's §V conclusion).
    //    Defaults are the paper's setup: paper tile set, 800x800 source.
    let outcome = TuningSession::new(SimCostModel)
        .devices([gtx.clone(), gts.clone()])
        .scale(8)
        .run()?;
    println!();
    for dt in &outcome.per_device {
        println!(
            "tuned best on {:>8}: {} ({:.3} ms, {} evaluations)",
            dt.device_id, dt.best, dt.best_ms, dt.evaluations
        );
    }
    let choice = outcome
        .portable
        .as_ref()
        .expect("paper tiles are launchable on both devices");
    println!(
        "portable tile over {{gtx260, 8800gts}}: {} (worst-case regret {:.3}x)",
        choice.tile, choice.worst_regret
    );

    // 5. Run a REAL resize through the AOT Pallas artifact, if present.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(manifest) => {
            let entry = manifest
                .select(Interpolator::Bilinear, (64, 64), 2, 1, None)
                .expect("64x64 s2 artifact");
            let engine = Engine::cpu(manifest.clone())?;
            let exe = engine.load(entry)?;
            let img = generate::test_scene(64, 64, 42);
            let out = exe.run(&[img.clone()])?.remove(0);
            let want = tilekit::image::bilinear(&img, 2);
            println!(
                "\nAOT artifact '{}' on {}: out {}x{}, max|err| vs CPU ref = {:.2e}",
                entry.name,
                engine.platform(),
                out.width(),
                out.height(),
                out.max_abs_diff(&want)
            );
            let out_path = std::env::temp_dir().join("tilekit_quickstart.pgm");
            pnm::write_pgm(&out_path, &out)?;
            println!("wrote {}", out_path.display());
        }
        Err(_) => println!("\n(no artifacts yet — run `make artifacts` for the AOT demo)"),
    }
    Ok(())
}
