//! AUTOSCALED FLEET DEMO — the capacity loop closed end to end: a
//! watermark policy over live serving stats engages expensive standby
//! capacity for each rush hour and parks it again in the lulls.
//!
//! Scenario: a day-shaped burst trace (two short rush hours over a long
//! quiet baseline — [`Trace::phased`]; [`Trace::diurnal`] builds the
//! symmetric variant). The base member is a tuned Fermi; the standby
//! pool holds one "surge spare" — the same architecture with its clocks
//! cut 20x, so every launch it prices through the paper's simulator
//! costs ~20x more. Exactly the trade the autoscaler is for: the spare
//! is worth renting only while the queue says so.
//!
//! Three fleets serve the identical trace:
//!
//! * **fixed-1** — the base member alone: cheapest, but each rush hour
//!   buries it (the burst offers more than its peak throughput).
//! * **fixed-2** — base + spare, always on: absorbs the rush, but pays
//!   the 20x launch premium on half the quiet traffic too.
//! * **autoscaled** — fixed-1 plus the spare parked in the standby
//!   pool; the control loop engages it when queue pressure crosses the
//!   high watermark and retires it (graceful drain, zero loss) when the
//!   fleet idles below the low watermark.
//!
//! The verdict metric is aggregate sim cost x interactive p99 — capacity
//! you keep (cost) against capacity you lacked (tail latency). The
//! autoscaled fleet beats both fixed sizes on the product.
//!
//! Run: `cargo run --release --example autoscaled_fleet`
//! (or `make -C rust autoscale-demo`)

use std::sync::Arc;
use std::time::Duration;
use tilekit::autotuner::{SimCostModel, TuningSession};
use tilekit::config::ServingConfig;
use tilekit::coordinator::{
    Autoscaler, AutoscalerOpts, FleetBuilder, RejectWhenFull, RoundRobin, StandbyMember,
    TilePolicy,
};
use tilekit::device::DeviceDescriptor;
use tilekit::image::Interpolator;
use tilekit::runtime::{Manifest, MockEngine};
use tilekit::tiling::TileDim;
use tilekit::util::text::Table;
use tilekit::workload::{replay, LoadPhase, Trace};

/// The standby device: same architecture as `base`, clocks cut by
/// `factor` — the simulator prices each launch ~`factor`x higher while
/// occupancy and tuning behave identically.
fn surge_spare(base: &DeviceDescriptor, factor: f64) -> DeviceDescriptor {
    let mut d = base.clone();
    d.id = "spare".into();
    d.name = "Surge Spare".into();
    d.sp_clock_mhz /= factor;
    d.mem_clock_mhz /= factor;
    d
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::fleet_demo();
    let base = tilekit::device::find_device("fermi").expect("builtin");
    let spare = surge_spare(&base, 20.0);
    let outcome = TuningSession::new(SimCostModel)
        .devices([base.clone(), spare.clone()])
        .kernel(Interpolator::Bilinear)
        .scale(2)
        .src((64, 64))
        .tiles([TileDim::new(16, 8), TileDim::new(32, 16)])
        .run()?;
    println!("tuned members (bilinear 64x64, scale 2):");
    for d in &outcome.per_device {
        println!(
            "  {:<8} best tile {} at {:.4} ms/launch",
            d.device_id, d.best, d.best_ms
        );
    }

    // A day in 3.3 seconds: long quiet phases at 600 rps, two 150 ms
    // rush hours at 4400 rps. One member sustains ~2000 rps (1 ms mock
    // batches of 2), two sustain ~4000 — the rush briefly exceeds even
    // that, so every fleet queues during it and the tail is measured on
    // equal terms.
    let keys = vec![tilekit::coordinator::RequestKey {
        kernel: Interpolator::Bilinear,
        src: (64, 64),
        scale: 2,
    }];
    let day = [
        LoadPhase { rate: 600.0, dur_us: 1_000_000 },
        LoadPhase { rate: 4400.0, dur_us: 150_000 },
        LoadPhase { rate: 600.0, dur_us: 1_000_000 },
        LoadPhase { rate: 4400.0, dur_us: 150_000 },
        LoadPhase { rate: 600.0, dur_us: 1_000_000 },
    ];
    let trace = Trace::phased(&keys, &day, 42);
    println!(
        "\ntrace: {} requests over {:.1}s ({:.0} rps mean, rush hours at 4400 rps)",
        trace.events.len(),
        trace.span_us() as f64 / 1e6,
        trace.offered_rps()
    );

    let cfg = ServingConfig {
        workers: 1,
        batch_max: Some(2),
        batch_deadline_ms: 0.2,
        queue_cap: 8192,
        work_stealing: true,
        steal_threshold: 2,
        ..ServingConfig::default()
    };
    let delay = Duration::from_millis(1);

    // Serve the identical trace on a fresh fleet; `standby` parks the
    // spare behind the autoscaler instead of building it in.
    let run = |members: &[&DeviceDescriptor],
               standby: bool|
     -> anyhow::Result<(f64, f64, u64, u64, usize)> {
        let mut builder = FleetBuilder::new(&cfg, &manifest)
            .scheduler(RoundRobin::default())
            .admission(RejectWhenFull);
        for d in members {
            builder = builder.device(
                (*d).clone(),
                Arc::new(MockEngine::with_delay(delay)),
                TilePolicy::PerDevice(outcome.clone()),
            );
        }
        let svc = builder.build()?;
        let scaler = if standby {
            let pool = vec![StandbyMember {
                device: spare.clone(),
                backend: Arc::new(MockEngine::with_delay(delay)),
                policy: TilePolicy::PerDevice(outcome.clone()),
            }];
            let opts = AutoscalerOpts {
                poll: Duration::from_millis(2),
                low_queue: 0.5,
                high_queue: 6.0,
                high_p99_us: 0,
                cooldown_ticks: 50,
                start_disabled: false,
            };
            let a = Autoscaler::spawn(svc.controller(), pool, opts)?;
            println!("  {}", a.handle().view().summary());
            Some(a)
        } else {
            None
        };
        let out = replay(&svc, &trace);
        let (ups, downs) = scaler
            .map(|a| {
                let v = a.handle().view();
                a.stop();
                (v.scale_ups, v.scale_downs)
            })
            .unwrap_or((0, 0));
        let stats = svc.shutdown();
        anyhow::ensure!(
            out.completed == out.offered && out.failed == 0 && out.rejected == 0,
            "lost work: {}",
            out.summary()
        );
        anyhow::ensure!(stats.unpriced.get() == 0, "unpriced launches");
        Ok((
            stats.sim_cost_ms(),
            out.latency.percentile_us(99.0) / 1e3,
            ups,
            downs,
            out.completed,
        ))
    };

    println!("\nfixed-1 (base only):");
    let (c1, p1, _, _, n1) = run(&[&base], false)?;
    println!("  done: {n1} served, sim cost {c1:.0} ms, p99 {p1:.1} ms");
    println!("fixed-2 (base + spare, always on):");
    let (c2, p2, _, _, n2) = run(&[&base, &spare], false)?;
    println!("  done: {n2} served, sim cost {c2:.0} ms, p99 {p2:.1} ms");
    println!("autoscaled (base + spare parked):");
    let (ca, pa, ups, downs, na) = run(&[&base], true)?;
    println!(
        "  done: {na} served, sim cost {ca:.0} ms, p99 {pa:.1} ms, \
         {ups} scale-up(s) / {downs} scale-down(s)"
    );

    let mut table = Table::new(vec![
        "fleet",
        "sim cost (ms)",
        "p99 (ms)",
        "cost x p99",
        "scale events",
    ]);
    let row = |t: &mut Table, name: &str, c: f64, p: f64, ev: String| {
        t.row(vec![
            name.to_string(),
            format!("{c:.0}"),
            format!("{p:.1}"),
            format!("{:.0}", c * p),
            ev,
        ]);
    };
    row(&mut table, "fixed-1", c1, p1, "-".into());
    row(&mut table, "fixed-2", c2, p2, "-".into());
    row(&mut table, "autoscaled", ca, pa, format!("{ups} up / {downs} down"));
    println!();
    print!("{}", table.render());

    if ca * pa < c1 * p1 && ca * pa < c2 * p2 && ups > 0 && downs > 0 {
        println!(
            "\n=> rent the expensive capacity only while the queue says so: \
             the closed loop beats every fixed size on cost x p99."
        );
    } else {
        println!("\n!! unexpected: a fixed-size fleet matched the autoscaler");
    }
    Ok(())
}
