//! Reproduce the paper's Fig. 3 from the library API and dump CSV files
//! for plotting: one file per inset (scale), rows = tiles, columns = the
//! two simulated devices. The figure layer runs exhaustive
//! `TuningSession`s over the paper pair under the hood; see
//! `examples/autotune_portable.rs` for driving sessions directly.
//!
//! Run: `cargo run --release --example tiling_sweep [-- out_dir]`

use std::fs;
use std::path::PathBuf;
use tilekit::bench::figures::{fig3_inset, fig3_summary, FIG3_SCALES};
use tilekit::image::Interpolator;

fn main() -> anyhow::Result<()> {
    let out_dir = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| std::env::temp_dir().join("tilekit_fig3"));
    fs::create_dir_all(&out_dir)?;

    for scale in FIG3_SCALES {
        let table = fig3_inset(Interpolator::Bilinear, scale, (800, 800));
        println!("Fig. 3 inset — scale {scale}:");
        print!("{}", table.render());
        println!();
        let csv_path = out_dir.join(format!("fig3_scale{scale}.csv"));
        fs::write(&csv_path, table.to_csv())?;
        println!("  -> {}\n", csv_path.display());
    }

    let (_insets, summary) = fig3_summary(Interpolator::Bilinear, (800, 800));
    println!("Findings summary:");
    print!("{}", summary.render());
    fs::write(out_dir.join("fig3_summary.csv"), summary.to_csv())?;
    println!("\nCSV written to {}", out_dir.display());
    Ok(())
}
