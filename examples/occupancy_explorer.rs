//! Occupancy explorer: a full occupancy map (every paper-sweep tile ×
//! every registry device × every kernel resource profile), highlighting
//! where each compute capability's limiter bites — the data behind the
//! paper's §III.B reasoning, generalized.
//!
//! Run: `cargo run --release --example occupancy_explorer`

use tilekit::device::builtin_devices;
use tilekit::tiling::occupancy::{occupancy, KernelResources, Limiter};
use tilekit::tiling::paper_sweep_tiles;
use tilekit::util::text::Table;

fn main() {
    let kernels = [
        ("nearest", KernelResources::NEAREST),
        ("bilinear", KernelResources::BILINEAR),
        ("bicubic", KernelResources::BICUBIC),
    ];
    for (kname, res) in kernels {
        println!("=== kernel: {kname} ({} regs/thread) ===\n", res.regs_per_thread);
        let devices = builtin_devices();
        let mut header = vec!["tile".to_string()];
        header.extend(devices.iter().map(|d| d.id.clone()));
        let mut t = Table::new(header);
        for tile in paper_sweep_tiles() {
            let mut row = vec![tile.label()];
            for d in &devices {
                let o = occupancy(tile, &res, &d.cc);
                let cell = if o.limiter == Limiter::Invalid {
                    "-".to_string()
                } else {
                    format!("{:.0}%{}", o.ratio * 100.0, limiter_mark(o.limiter))
                };
                row.push(cell);
            }
            t.row(row);
        }
        print!("{}", t.render());
        println!("  (* = register-limited, # = block-slot-limited, blank = threads/warps)\n");
    }

    // The §III.B cliff, called out explicitly.
    println!("§III.B focus — 32x16 bilinear across capabilities:");
    let tile = "32x16".parse().unwrap();
    let mut t = Table::new(vec!["device", "cc", "blocks/SM", "threads/SM", "occupancy"]);
    for d in builtin_devices() {
        let o = occupancy(tile, &KernelResources::BILINEAR, &d.cc);
        t.row(vec![
            d.id.clone(),
            d.cc.version(),
            o.blocks_per_sm.to_string(),
            o.threads_per_sm.to_string(),
            format!("{:.0}%", o.ratio * 100.0),
        ]);
    }
    print!("{}", t.render());
}

fn limiter_mark(l: Limiter) -> &'static str {
    match l {
        Limiter::Registers => "*",
        Limiter::BlockSlots => "#",
        _ => "",
    }
}
