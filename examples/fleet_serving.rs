//! FLEET SERVING DEMO — the paper's cross-device tiling claim, end to
//! end: "an optimized tiling strategy on one GPU model is not always a
//! good solution when executed on other GPU models".
//!
//! A 2-device simulated fleet (GTX 260 / cc1.3 vs Fermi / cc2.0) serves
//! the same replay trace three ways:
//!
//! 1. `TilePolicy::PerDevice` — each device routes through its own tuned
//!    tile from one `TuningSession` outcome;
//! 2. `TilePolicy::Fixed(16x8)` — the GTX 260's best, forced everywhere;
//! 3. `TilePolicy::Fixed(32x16)` — the Fermi's best, forced everywhere.
//!
//! Each executed request is metered at the sim cost of the tile variant
//! its device actually routed to; per-device tiles must win on aggregate
//! sim cost against EVERY single fixed tile (asserted for real in
//! `rust/tests/fleet_serving.rs`).
//!
//! Run: `cargo run --release --example fleet_serving`

use std::sync::Arc;
use std::time::Duration;
use tilekit::autotuner::{SimCostModel, TuningSession};
use tilekit::config::ServingConfig;
use tilekit::coordinator::{
    BlockWithTimeout, FleetBuilder, RoundRobin, TilePolicy,
};
use tilekit::device::{find_device, DeviceDescriptor};
use tilekit::runtime::{Manifest, MockEngine};
use tilekit::tiling::TileDim;
use tilekit::util::text::Table;
use tilekit::workload::{replay, Arrival, Trace};

fn serve_once(
    manifest: &Manifest,
    devices: &[DeviceDescriptor; 2],
    policy: TilePolicy,
    trace: &Trace,
) -> anyhow::Result<(f64, Vec<(String, String, u64, f64)>)> {
    let cfg = ServingConfig {
        workers: 2,
        batch_max: Some(4),
        batch_deadline_ms: 0.5,
        queue_cap: 512,
        // This is the STATIC per-device-tile demo: keep the policy
        // comparison free of work-stealing (see examples/adaptive_fleet.rs
        // for the adaptive win).
        work_stealing: false,
        ..ServingConfig::default()
    };
    let svc = FleetBuilder::new(&cfg, manifest)
        .device(devices[0].clone(), Arc::new(MockEngine::new()), policy.clone())
        .device(devices[1].clone(), Arc::new(MockEngine::new()), policy)
        .scheduler(RoundRobin::default())
        .admission(BlockWithTimeout(Duration::from_secs(30)))
        .build()?;
    let out = replay(&svc, trace);
    anyhow::ensure!(
        out.completed == trace.events.len(),
        "replay must complete everything: {}",
        out.summary()
    );
    let per_member: Vec<(String, String, u64, f64)> = svc
        .members()
        .iter()
        .map(|v| {
            (
                v.label.to_string(),
                v.tile_pref.map(|t| t.label()).unwrap_or_default(),
                v.stats.completed.get(),
                v.stats.sim_cost_ms(),
            )
        })
        .collect();
    // The lock-free submit fast path at work: after the first submit
    // per thread pins the plan, the rest ride the cached snapshot.
    let plan = svc.plan_metrics();
    let stats = svc.shutdown();
    println!(
        "  plan v{}: {} fast-path submits, {} refreshes, {} rebuilds",
        plan.version, plan.fast_hits, plan.refreshes, plan.rebuilds
    );
    if let Some(line) = stats.submit_breakdown() {
        println!("  {line}");
    }
    Ok((stats.sim_cost_ms(), per_member))
}

fn main() -> anyhow::Result<()> {
    // One bilinear 64x64/s2 shape at the two tile variants whose
    // preference flips between the device models (shared fixture).
    let manifest = Manifest::fleet_demo();
    let devices = [
        find_device("gtx260").expect("builtin"),
        find_device("fermi").expect("builtin"),
    ];
    let tiles = [TileDim::new(16, 8), TileDim::new(32, 16)];

    // Tune once over the fleet at the served shape.
    let outcome = TuningSession::new(SimCostModel)
        .devices(devices.clone())
        .scale(2)
        .src((64, 64))
        .tiles(tiles)
        .run()?;
    println!("tuned fleet (bilinear 64x64, scale 2):");
    for d in &outcome.per_device {
        println!("  {:<8} best tile {} at {:.4} ms/launch", d.device_id, d.best, d.best_ms);
    }
    println!();

    let trace = Trace::generate(
        &[tilekit::coordinator::RequestKey {
            kernel: tilekit::image::Interpolator::Bilinear,
            src: (64, 64),
            scale: 2,
        }],
        120,
        Arrival::Uniform { rate: 4000.0 },
        2010,
    );

    let mut table = Table::new(vec!["policy", "per-device routing", "aggregate sim cost ms"]);
    let mut results: Vec<(String, f64)> = Vec::new();
    let per_device_policy = TilePolicy::PerDevice(outcome);
    let mut runs: Vec<(String, TilePolicy)> = vec![(
        "per-device (tuned)".to_string(),
        per_device_policy,
    )];
    for t in tiles {
        runs.push((format!("fixed {t}"), TilePolicy::Fixed(t)));
    }
    for (name, policy) in runs {
        let (cost, members) = serve_once(&manifest, &devices, policy, &trace)?;
        let routing = members
            .iter()
            .map(|(id, tile, n, ms)| format!("{id}->{tile} ({n} reqs, {ms:.3} ms)"))
            .collect::<Vec<_>>()
            .join("  ");
        table.row(vec![name.clone(), routing, format!("{cost:.3}")]);
        results.push((name, cost));
    }
    print!("{}", table.render());

    let per_dev = results[0].1;
    let best_fixed = results[1..]
        .iter()
        .map(|(_, c)| *c)
        .fold(f64::INFINITY, f64::min);
    println!(
        "\nper-device tuned tiles: {per_dev:.3} ms vs best single fixed tile: {best_fixed:.3} ms \
         ({:.1}% cheaper)",
        (1.0 - per_dev / best_fixed) * 100.0
    );
    if per_dev < best_fixed {
        println!("=> the paper's claim, served: no single tile matches per-device tuning.");
    } else {
        println!("!! unexpected: per-device tiles did not beat the best fixed tile");
    }
    Ok(())
}
