//! A small, offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so the parts of anyhow
//! this repository actually uses are implemented here and wired in as a
//! path dependency: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and the [`Context`] extension trait for `Result`
//! and `Option`.
//!
//! Semantics mirror anyhow where it matters to callers:
//!
//! * `{}` formatting prints the outermost message only;
//! * `{:#}` joins the whole context chain with `": "`;
//! * `{:?}` prints the message plus a `Caused by:` list;
//! * any `E: std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`, capturing its `source()` chain.

use std::fmt;

/// A dynamic error: an ordered context chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn wrap(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error`; exactly
// like anyhow, that is what makes this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.wrap("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros() {
        let id = "gtx260";
        let e = anyhow!("unknown device '{id}'");
        assert_eq!(e.to_string(), "unknown device 'gtx260'");
        let e = anyhow!("{} of {}", 1, 2);
        assert_eq!(e.to_string(), "1 of 2");
        let s = String::from("plain");
        assert_eq!(anyhow!(s).to_string(), "plain");

        fn fails(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert!(fails(11).is_err());
        assert!(fails(3).is_err());
        assert_eq!(fails(5).unwrap(), 5);
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening artifact").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening artifact: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| "no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");

        // context chains through anyhow::Result too
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn question_mark_conversion() {
        fn parse(s: &str) -> Result<u32> {
            let n: u32 = s.parse()?; // ParseIntError -> Error
            Ok(n)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }
}
