//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps the native XLA runtime, which is not present in
//! this build environment. This stub provides the exact API surface
//! `tilekit::runtime::executor` compiles against; every operation that
//! would need the native runtime returns a descriptive error instead.
//!
//! All call sites are already defensive: the AOT tests, benches, and
//! examples check for `artifacts/manifest.json` first and skip loudly
//! when artifacts are absent, and the serving CLI offers `--mock`. The
//! in-tree [`MockEngine`](../../src/runtime/mock.rs) covers the
//! coordinator tests. Swapping this stub for the real bindings is a
//! one-line change in the workspace manifest.

use std::fmt;
use std::marker::PhantomData;
use std::path::Path;
use std::rc::Rc;

/// Error type returned by every stubbed operation.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: the native XLA/PJRT runtime is not available in this offline \
         build (vendored stub); use --mock or the MockEngine backend"
    )))
}

/// A host-side literal (stub).
pub struct Literal {
    _p: (),
}

impl Literal {
    /// Build a rank-1 literal from a slice (stub: shape is not retained).
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal { _p: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    /// Read the literal out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// A device buffer handle (stub).
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled executable (stub). `!Send` like the real binding.
pub struct PjRtLoadedExecutable {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtLoadedExecutable {
    /// Execute over a set of input literals.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A PJRT client (stub). `!Send` like the real binding, which is why the
/// engine layer keeps one client per worker thread.
pub struct PjRtClient {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtClient {
    /// Create the CPU client. Always errors in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto {
    _p: (),
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation (stub).
pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    /// Wrap a module proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_descriptive() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("not available"));
        assert!(Literal::vec1(&[0f32]).reshape(&[1]).is_err());
    }
}
