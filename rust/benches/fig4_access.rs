//! Bench: regenerate the paper's **Fig. 4** — the 4×8 vs 8×4 access
//! pattern comparison (row crossings per block, transactions, the
//! resulting simulated times) across all five scales.
//!
//! Run: `cargo bench --bench fig4_access`.

use tilekit::bench::figures::fig4_access;
use tilekit::bench::Bench;
use tilekit::device::paper_pair;
use tilekit::sim::block_traffic;
use tilekit::sim::Launch;
use tilekit::image::Interpolator;

fn main() {
    println!("=== Fig. 4: 4x8 vs 8x4 (same 32 threads, different shape) ===");
    for scale in [2, 4, 6, 8, 10] {
        println!("\n--- scale {scale} ---");
        print!("{}", fig4_access(scale).render());
    }

    println!("\n=== harness: memory-model throughput ===");
    let b = Bench::from_env();
    let (gtx, _) = paper_pair();
    let l = Launch::paper(Interpolator::Bilinear, "8x4".parse().unwrap(), 6);
    b.report("block_traffic(8x4, scale 6, gtx260)", || {
        block_traffic(&l, &gtx)
    });
}
