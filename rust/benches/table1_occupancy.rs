//! Bench: regenerate **Table I** from the device registry and the
//! **§III.B occupancy-cliff** scenario (32×16: 100% on GTX 260, 66% on
//! 8800 GTS), plus an occupancy table across the full paper sweep.
//!
//! Run: `cargo bench --bench table1_occupancy`.

use tilekit::bench::figures::{occupancy_cliff, table1_figure};
use tilekit::bench::Bench;
use tilekit::device::paper_pair;
use tilekit::tiling::occupancy::{occupancy, KernelResources};
use tilekit::tiling::paper_sweep_tiles;
use tilekit::util::text::Table;

fn main() {
    println!("=== TABLE I. COMPUTE CAPABILITY OF GTX260 AND GEFORCE 8800 ===\n");
    print!("{}", table1_figure().render());

    println!("\n=== §III.B: the 32x16 occupancy cliff ===\n");
    print!("{}", occupancy_cliff("32x16".parse().unwrap()).render());

    println!("\n=== occupancy across the full paper sweep ===\n");
    let (gtx, gts) = paper_pair();
    let mut t = Table::new(vec!["tile", "gtx260 occ", "gtx260 blocks", "8800gts occ", "8800gts blocks"]);
    for tile in paper_sweep_tiles() {
        let a = occupancy(tile, &KernelResources::BILINEAR, &gtx.cc);
        let b = occupancy(tile, &KernelResources::BILINEAR, &gts.cc);
        t.row(vec![
            tile.label(),
            format!("{:.0}%", a.ratio * 100.0),
            a.blocks_per_sm.to_string(),
            format!("{:.0}%", b.ratio * 100.0),
            b.blocks_per_sm.to_string(),
        ]);
    }
    print!("{}", t.render());

    println!("\n=== harness: occupancy calculator throughput ===");
    let b = Bench::from_env();
    let tile = "32x16".parse().unwrap();
    b.report("occupancy(32x16, bilinear, cc1.3)", || {
        occupancy(tile, &KernelResources::BILINEAR, &gtx.cc)
    });
}
