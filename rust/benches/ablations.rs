//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//! 1. Coalescing model: run the 8800 GTS with cc1.3-style segmented
//!    coalescing ("what if G80 had GT200's memory system?") — isolates
//!    how much of the device gap is coalescing vs clocks/SM count.
//! 2. Row-penalty term: disable it and watch the 32×4-vs-taller ordering
//!    collapse — shows the Fig. 4 mechanism carries the large-scale
//!    findings.
//! 3. Kernel cost: nearest vs bilinear vs bicubic across the sweep (tile
//!    sensitivity grows with taps).
//! 4. Smoothness metric: relative spread vs absolute range per device
//!    (the §IV.B reading; see DESIGN.md).
//!
//! Run: `cargo bench --bench ablations`.

use tilekit::autotuner::sweep;
use tilekit::device::{paper_pair, CoalescingModel};
use tilekit::image::Interpolator;
use tilekit::sim::{simulate, Launch};
use tilekit::tiling::paper_sweep_tiles;
use tilekit::util::text::{fmt_ms, Table};

fn main() {
    let (gtx, gts) = paper_pair();
    let tiles = paper_sweep_tiles();

    // ---- 1. coalescing ablation ---------------------------------------
    println!("=== ablation 1: give the 8800 GTS segmented (cc1.3) coalescing ===\n");
    let mut gts_seg = gts.clone();
    gts_seg.cc.coalescing = CoalescingModel::SegmentedHalfWarp;
    gts_seg.id = "8800gts+seg".into();
    let mut t = Table::new(vec!["tile", "8800gts ms", "8800gts+seg ms", "speedup"]);
    for &tile in &tiles {
        let l = Launch::paper(Interpolator::Bilinear, tile, 4);
        let a = simulate(&l, &gts, None).ms;
        let b = simulate(&l, &gts_seg, None).ms;
        t.row(vec![
            tile.label(),
            fmt_ms(a),
            fmt_ms(b),
            format!("{:.2}x", a / b),
        ]);
    }
    print!("{}", t.render());

    // ---- 2. row-penalty ablation ---------------------------------------
    println!("\n=== ablation 2: disable the row-switch penalty (Fig. 4 mechanism) ===\n");
    let mut gtx_norow = gtx.clone();
    gtx_norow.row_switch_cycles = 0.0;
    gtx_norow.id = "gtx260-norow".into();
    for scale in [2, 10] {
        let with_pen = sweep(&gtx, Interpolator::Bilinear, &tiles, scale, (800, 800));
        let without = sweep(&gtx_norow, Interpolator::Bilinear, &tiles, scale, (800, 800));
        println!(
            "scale {scale}: best with penalty = {}, without = {}",
            with_pen.best().unwrap().tile,
            without.best().unwrap().tile
        );
    }

    // ---- 3. kernel cost ablation ----------------------------------------
    println!("\n=== ablation 3: kernel tap count vs tile sensitivity (gtx260, s6) ===\n");
    let mut t = Table::new(vec!["kernel", "best tile", "best ms", "range ms"]);
    for kernel in [
        Interpolator::Nearest,
        Interpolator::Bilinear,
        Interpolator::Bicubic,
    ] {
        let r = sweep(&gtx, kernel, &tiles, 6, (800, 800));
        let best = r.best().unwrap();
        t.row(vec![
            kernel.label().to_string(),
            best.tile.label(),
            fmt_ms(best.report.ms),
            format!("{:.3}", r.range_ms()),
        ]);
    }
    print!("{}", t.render());

    // ---- 4. smoothness metrics -------------------------------------------
    println!("\n=== ablation 4: relative spread vs absolute range (§IV.B reading) ===\n");
    let mut t = Table::new(vec![
        "scale",
        "gtx260 rel",
        "8800gts rel",
        "gtx260 range ms",
        "8800gts range ms",
    ]);
    for scale in [2u32, 4, 6, 8, 10] {
        let a = sweep(&gtx, Interpolator::Bilinear, &tiles, scale, (800, 800));
        let b = sweep(&gts, Interpolator::Bilinear, &tiles, scale, (800, 800));
        t.row(vec![
            scale.to_string(),
            format!("{:.3}", a.spread_ratio()),
            format!("{:.3}", b.spread_ratio()),
            format!("{:.3}", a.range_ms()),
            format!("{:.3}", b.range_ms()),
        ]);
    }
    print!("{}", t.render());
}
