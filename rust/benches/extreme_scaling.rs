//! Bench: the paper's **§IV.C extreme example** — G1 (2 SMs / 16 cores)
//! vs G2 (20 SMs / 160 cores) with a tile that halves one SM's
//! efficiency; the total efficiency loss should dilute ~1/4 → ~1/40.
//! Extended with a straggler-speed sweep and an SM-count scaling curve.
//!
//! Run: `cargo bench --bench extreme_scaling`.

use tilekit::bench::figures::extreme_example;
use tilekit::bench::Bench;
use tilekit::device::find_device;
use tilekit::image::Interpolator;
use tilekit::sim::{simulate, Launch, Straggler};
use tilekit::util::text::Table;

fn main() {
    println!("=== §IV.C extreme example (paper: 1/4 vs 1/40) ===\n");
    print!("{}", extreme_example().render());

    // Extension: the dilution curve across SM counts.
    println!("\n=== dilution vs SM count (extension) ===\n");
    let base = find_device("g2").unwrap();
    let l = Launch::paper(Interpolator::Bilinear, "32x4".parse().unwrap(), 4);
    let mut t = Table::new(vec!["SMs", "efficiency lost", "theory 0.5/N"]);
    for sms in [1u32, 2, 4, 8, 12, 16, 20, 24, 30] {
        let mut dev = base.clone();
        dev.sm_count = sms;
        let clean = simulate(&l, &dev, None).ms;
        let hurt = simulate(&l, &dev, Some(Straggler { sm: 0, speed: 0.5 })).ms;
        let lost = (hurt - clean) / hurt;
        t.row(vec![
            sms.to_string(),
            format!("{:.4}", lost),
            format!("{:.4}", 0.5 / sms as f64),
        ]);
    }
    print!("{}", t.render());

    // Extension: straggler severity sweep on the paper pair of SM counts.
    println!("\n=== straggler severity sweep ===\n");
    let mut t = Table::new(vec!["speed", "G1 (2 SM) lost", "G2 (20 SM) lost"]);
    let g1 = find_device("g1").unwrap();
    let g2 = find_device("g2").unwrap();
    for speed in [0.9, 0.75, 0.5, 0.25, 0.1] {
        let loss = |dev: &tilekit::device::DeviceDescriptor| {
            let clean = simulate(&l, dev, None).ms;
            let hurt = simulate(&l, dev, Some(Straggler { sm: 0, speed })).ms;
            (hurt - clean) / hurt
        };
        t.row(vec![
            format!("{speed}"),
            format!("{:.4}", loss(&g1)),
            format!("{:.4}", loss(&g2)),
        ]);
    }
    print!("{}", t.render());

    println!("\n=== harness: straggler dispatch (heap path) ===");
    let b = Bench::from_env();
    b.report("simulate with straggler (g2)", || {
        simulate(&l, &g2, Some(Straggler { sm: 0, speed: 0.5 }))
    });
}
