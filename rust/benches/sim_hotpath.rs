//! Bench: micro-benchmarks of the L3 hot paths — the simulator inner
//! loop (the Fig-3 sweep calls it thousands of times), the occupancy
//! calculator, the memory model, the channel, and the batcher state
//! machine. This is the before/after instrument for EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench sim_hotpath`.

use std::time::Duration;
use tilekit::bench::Bench;
use tilekit::coordinator::batcher::BatcherState;
use tilekit::coordinator::request::{RequestKey, ResizeRequest, Ticket};
use tilekit::device::paper_pair;
use tilekit::exec::bounded;
use tilekit::image::{generate, Interpolator};
use tilekit::sim::{block_traffic, simulate, Launch};
use tilekit::tiling::occupancy::{occupancy, KernelResources};
use tilekit::tiling::paper_sweep_tiles;

fn main() {
    let b = Bench::from_env();
    let (gtx, gts) = paper_pair();

    println!("=== simulator hot path ===");
    let tiles = paper_sweep_tiles();
    let l = Launch::paper(Interpolator::Bilinear, "32x4".parse().unwrap(), 8);
    b.report("simulate: one launch (gtx260, s8)", || {
        simulate(&l, &gtx, None)
    });
    b.report("simulate: one launch (8800gts, s8)", || {
        simulate(&l, &gts, None)
    });
    b.report("simulate: 14-tile sweep x 2 devices (one inset)", || {
        for dev in [&gtx, &gts] {
            for &tile in &tiles {
                let l = Launch::paper(Interpolator::Bilinear, tile, 8);
                std::hint::black_box(simulate(&l, dev, None));
            }
        }
    });

    println!("\n=== component micro-benches ===");
    let t32x16 = "32x16".parse().unwrap();
    b.report("occupancy(32x16)", || {
        occupancy(t32x16, &KernelResources::BILINEAR, &gtx.cc)
    });
    b.report("block_traffic(32x4, s8)", || block_traffic(&l, &gtx));

    println!("\n=== coordinator substrate micro-benches ===");
    b.report("channel send+recv (cap 64)", || {
        let (tx, rx) = bounded(64);
        for i in 0..32u32 {
            tx.send(i).unwrap();
        }
        let mut s = 0u32;
        for _ in 0..32 {
            s += rx.recv().unwrap();
        }
        s
    });

    let img = generate::gradient(16, 16);
    let key = RequestKey::of(Interpolator::Bilinear, &img, 2);
    b.report("batcher push+flush (batch 8)", || {
        let mut state = BatcherState::new(8, Duration::from_millis(1));
        for i in 0..8u64 {
            let (_t, tx) = Ticket::new(i);
            let out = state.push(ResizeRequest::bare(i, key, img.clone(), tx));
            if out.is_some() {
                return 1usize;
            }
        }
        0usize
    });

    println!("\n=== image substrate ===");
    let scene = generate::test_scene(128, 128, 3);
    b.report("cpu bilinear 128x128 -> 256x256", || {
        Interpolator::Bilinear.run(&scene, 2)
    });
    b.report("cpu bicubic 128x128 -> 256x256", || {
        Interpolator::Bicubic.run(&scene, 2)
    });
}
