//! Bench: the full optimization space of the paper's §I taxonomy —
//! "tiling, using shared memory, unrolling and prefetching" — swept
//! jointly on both paper devices. Tests the central thesis that tiling
//! "is always the decisive factor that affecting programs' performance"
//! by comparing the spread each knob induces while the others are held
//! at their default.
//!
//! Also sweeps the thread-level tiling the paper names but never
//! explores (§III.A).
//!
//! Run: `cargo bench --bench optimizations`.

use tilekit::device::paper_pair;
use tilekit::image::Interpolator;
use tilekit::sim::{simulate_config, KernelConfig, Launch};
use tilekit::tiling::{paper_sweep_tiles, thread_tile_candidates, TileDim, Tiling};
use tilekit::util::text::{fmt_ms, Table};

fn cfg(block: TileDim) -> KernelConfig {
    KernelConfig::paper(Interpolator::Bilinear, block)
}

fn main() {
    let (gtx, gts) = paper_pair();
    let launch = Launch::paper(Interpolator::Bilinear, TileDim::new(32, 4), 6);

    // ---- 1. knob-by-knob spread: which factor is decisive? -------------
    println!("=== which knob is decisive? (scale 6, spread of times over each knob) ===\n");
    let mut t = Table::new(vec!["knob", "gtx260 min..max ms", "gtx260 spread", "8800gts min..max ms", "8800gts spread"]);
    for dev in [&gtx, &gts] {
        let _ = dev;
    }
    let knob_rows: Vec<(&str, Vec<KernelConfig>)> = vec![
        (
            "block tiling (14 shapes)",
            paper_sweep_tiles().into_iter().map(cfg).collect(),
        ),
        (
            "thread tiling (6 shapes)",
            thread_tile_candidates()
                .into_iter()
                .map(|pt| KernelConfig {
                    tiling: Tiling {
                        block: TileDim::new(32, 4),
                        per_thread: pt,
                    },
                    ..cfg(TileDim::new(32, 4))
                })
                .collect(),
        ),
        (
            "shared memory (off/on)",
            [false, true]
                .into_iter()
                .map(|s| KernelConfig {
                    smem_staging: s,
                    ..cfg(TileDim::new(32, 4))
                })
                .collect(),
        ),
        (
            "unrolling (off/on)",
            [false, true]
                .into_iter()
                .map(|u| KernelConfig {
                    unrolled: u,
                    tiling: Tiling {
                        block: TileDim::new(32, 4),
                        per_thread: tilekit::tiling::ThreadTile::new(2, 1),
                    },
                    ..cfg(TileDim::new(32, 4))
                })
                .collect(),
        ),
        (
            "prefetching (off/on)",
            [false, true]
                .into_iter()
                .map(|p| KernelConfig {
                    prefetch: p,
                    ..cfg(TileDim::new(32, 4))
                })
                .collect(),
        ),
    ];
    for (name, cfgs) in &knob_rows {
        let mut cells = vec![name.to_string()];
        for dev in [&gtx, &gts] {
            let times: Vec<f64> = cfgs
                .iter()
                .map(|c| simulate_config(c, &launch, dev, None).ms)
                .filter(|m| m.is_finite())
                .collect();
            let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = times.iter().cloned().fold(0.0f64, f64::max);
            cells.push(format!("{} .. {}", fmt_ms(min), fmt_ms(max)));
            cells.push(format!("{:.2}x", max / min));
        }
        t.row(cells);
    }
    print!("{}", t.render());
    println!(
        "\nPaper §I: \"tiling is the most basic but also most important technique and it\n\
         is always the decisive factor\" — compare the block-tiling spread row.\n"
    );

    // ---- 2. thread-tiling sweep across block shapes ----------------------
    println!("=== thread-level tiling (the §III.A 'deeper tiling', unexplored by the paper) ===\n");
    let mut t = Table::new(vec![
        "block", "per-thread", "blocks", "gtx260 ms", "8800gts ms",
    ]);
    for block in [TileDim::new(32, 4), TileDim::new(16, 8)] {
        for pt in thread_tile_candidates() {
            let c = KernelConfig {
                tiling: Tiling {
                    block,
                    per_thread: pt,
                },
                unrolled: true,
                ..cfg(block)
            };
            let a = simulate_config(&c, &launch, &gtx, None);
            let b = simulate_config(&c, &launch, &gts, None);
            t.row(vec![
                block.label(),
                pt.label(),
                a.total_blocks.to_string(),
                fmt_ms(a.ms),
                fmt_ms(b.ms),
            ]);
        }
    }
    print!("{}", t.render());

    // ---- 3. best combined config per device ------------------------------
    println!("\n=== best combined configuration per device (full joint sweep) ===\n");
    let mut best: Vec<(String, KernelConfig, f64)> = Vec::new();
    for dev in [&gtx, &gts] {
        let mut top: Option<(KernelConfig, f64)> = None;
        for block in paper_sweep_tiles() {
            for pt in thread_tile_candidates() {
                for smem in [false, true] {
                    for unroll in [false, true] {
                        for pf in [false, true] {
                            let c = KernelConfig {
                                kernel: Interpolator::Bilinear,
                                tiling: Tiling {
                                    block,
                                    per_thread: pt,
                                },
                                smem_staging: smem,
                                unrolled: unroll,
                                prefetch: pf,
                            };
                            let ms = simulate_config(&c, &launch, dev, None).ms;
                            if ms.is_finite() && top.map(|(_, b)| ms < b).unwrap_or(true) {
                                top = Some((c, ms));
                            }
                        }
                    }
                }
            }
        }
        let (c, ms) = top.unwrap();
        best.push((dev.id.clone(), c, ms));
    }
    let mut t = Table::new(vec!["device", "best config", "ms", "vs paper 32x4 plain"]);
    for (id, c, ms) in &best {
        let dev = if id == "gtx260" { &gtx } else { &gts };
        let plain = simulate_config(&cfg(TileDim::new(32, 4)), &launch, dev, None).ms;
        t.row(vec![
            id.clone(),
            c.label(),
            fmt_ms(*ms),
            format!("{:.2}x faster", plain / ms),
        ]);
    }
    print!("{}", t.render());
}
