//! Bench: regenerate the paper's **Fig. 3** — execution time vs tiling
//! dimensions for an 800×800 source at scales 2/4/6/8/10 on the
//! simulated GTX 260 and GeForce 8800 GTS, plus the harness timing of
//! the sweep itself (the autotuner's hot path).
//!
//! Run: `cargo bench --bench fig3_tiling` (CSV via TILEKIT_CSV=1).

use tilekit::bench::figures::{fig3_summary, FIG3_SCALES};
use tilekit::bench::Bench;
use tilekit::device::paper_pair;
use tilekit::image::Interpolator;
use tilekit::sim::{simulate, Launch};
use tilekit::tiling::paper_sweep_tiles;

fn main() {
    let csv = std::env::var("TILEKIT_CSV").is_ok();
    println!("=== Fig. 3: time vs tile, both devices, scales {FIG3_SCALES:?} ===");
    let (insets, summary) = fig3_summary(Interpolator::Bilinear, (800, 800));
    for (scale, table) in &insets {
        println!("\n--- inset scale {scale} ---");
        if csv {
            print!("{}", table.to_csv());
        } else {
            print!("{}", table.render());
        }
    }
    println!("\n--- summary (paper findings) ---");
    print!("{}", summary.render());

    // Harness: how fast is one full-sweep point (simulator hot path)?
    println!("\n=== harness: simulator throughput ===");
    let b = Bench::from_env();
    let (gtx, gts) = paper_pair();
    let tiles = paper_sweep_tiles();
    for dev in [&gtx, &gts] {
        let l = Launch::paper(Interpolator::Bilinear, tiles[0], 8);
        b.report(&format!("simulate(800x800, s8) on {}", dev.id), || {
            simulate(&l, dev, None)
        });
    }
    b.report("full fig3 sweep (5 scales x 14 tiles x 2 devices)", || {
        fig3_summary(Interpolator::Bilinear, (800, 800))
    });
}
