//! Bench: REAL execution time of the AOT Pallas artifacts on the PJRT
//! CPU client, swept over the Pallas output-tile variants — the §Perf
//! L1/L2 experiment.
//!
//! The punchline mirrors the paper on our own device pair: the
//! GPU-portable tile (32×4, chosen by the simulator/autotuner for the
//! paper's GPUs) is NOT the best tile for the CPU PJRT backend, where
//! fewer, larger grid steps amortize per-step overhead — "an optimized
//! tiling strategy on one GPU model is not always a good solution when
//! executed on other models", abstract, verified across architectures.
//!
//! Requires `make artifacts`.
//!
//! Run: `cargo bench --bench artifact_exec`.

use std::path::Path;
use tilekit::bench::Bench;
use tilekit::image::{generate, Image, Interpolator};
use tilekit::runtime::{Engine, Manifest};
use tilekit::util::text::Table;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP: no artifacts ({e}); run `make artifacts`");
            return;
        }
    };
    let engine = Engine::cpu(manifest.clone()).expect("PJRT CPU client");
    println!(
        "=== §Perf L1/L2: PJRT execution time vs Pallas output tile ({}) ===\n",
        engine.platform()
    );

    // All bilinear 64x64 s2 b4 variants, ordered by tile area.
    let mut variants: Vec<_> = manifest
        .entries
        .iter()
        .filter(|e| {
            e.kernel == Interpolator::Bilinear && e.src == (64, 64) && e.scale == 2 && e.batch == 4
        })
        .collect();
    variants.sort_by_key(|e| e.tile.threads());

    let imgs: Vec<Image<f32>> = (0..4).map(|i| generate::test_scene(64, 64, i)).collect();
    let b = Bench::from_env();
    let mut t = Table::new(vec![
        "artifact",
        "pallas tile",
        "grid steps",
        "mean exec us",
        "vs 32x4",
    ]);
    let mut baseline_us = None;
    for e in &variants {
        let exe = engine.load(e).expect("compile");
        // correctness first
        let out = exe.run(&imgs).expect("run");
        let want = tilekit::image::bilinear(&imgs[0], 2);
        assert!(out[0].max_abs_diff(&want) < 2e-5, "{} numerics", e.name);

        let m = b.run(&e.name, || exe.run(&imgs).expect("run"));
        let grid = (128u32.div_ceil(e.tile.y)) * (128u32.div_ceil(e.tile.x));
        if e.tile.label() == "32x4" {
            baseline_us = Some(m.mean_us());
        }
        t.row(vec![
            e.name.clone(),
            e.tile.label(),
            grid.to_string(),
            format!("{:.0}", m.mean_us()),
            String::new(), // filled after baseline known
        ]);
    }
    // Re-render with speedups now that the baseline is known.
    let mut t2 = Table::new(vec![
        "artifact",
        "pallas tile",
        "grid steps",
        "mean exec us",
        "vs 32x4",
    ]);
    for e in &variants {
        let exe = engine.load(e).expect("compile");
        let m = b.run(&format!("{} (pass 2)", e.name), || exe.run(&imgs).expect("run"));
        let grid = (128u32.div_ceil(e.tile.y)) * (128u32.div_ceil(e.tile.x));
        let rel = baseline_us
            .map(|b| format!("{:.2}x", b / m.mean_us()))
            .unwrap_or_default();
        t2.row(vec![
            e.name.clone(),
            e.tile.label(),
            grid.to_string(),
            format!("{:.0}", m.mean_us()),
            rel,
        ]);
    }
    let _ = t;
    println!();
    print!("{}", t2.render());

    // ---- per-kernel cost at the CPU-optimal (whole-image) tile ---------
    println!("\n=== per-kernel exec time (whole-image tiles, batch 4) ===\n");
    let mut t3 = Table::new(vec!["artifact", "kernel", "out px", "mean exec us", "us/Mpx"]);
    let mut whole: Vec<_> = manifest
        .entries
        .iter()
        .filter(|e| e.batch == 4 && e.tile.y >= e.src.0 * e.scale)
        .collect();
    whole.sort_by_key(|e| (e.kernel.label(), e.src));
    for e in whole {
        let exe = engine.load(e).expect("compile");
        let imgs: Vec<Image<f32>> = (0..4)
            .map(|i| generate::test_scene(e.src.1 as usize, e.src.0 as usize, i))
            .collect();
        let m = b.run(&e.name, || exe.run(&imgs).expect("run"));
        let out_px = (e.dst().0 as u64 * e.dst().1 as u64) * 4;
        t3.row(vec![
            e.name.clone(),
            e.kernel.label().to_string(),
            out_px.to_string(),
            format!("{:.0}", m.mean_us()),
            format!("{:.0}", m.mean_us() / (out_px as f64 / 1e6)),
        ]);
    }
    print!("{}", t3.render());
    println!(
        "\nGPU-portable 32x4 vs CPU-optimal whole-image tile: the paper's\n\
         cross-device conclusion, reproduced between the simulated GPUs and\n\
         this real CPU backend."
    );
}
