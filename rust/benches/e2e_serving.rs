//! Bench: **end-to-end serving** over real AOT artifacts — the system
//! validation workload. Loads the manifest, starts the coordinator with
//! the PJRT backend, replays a mixed request stream, and reports
//! latency percentiles + throughput. Also sweeps batch_max to show the
//! dynamic batcher's effect (the ablation recorded in EXPERIMENTS.md).
//!
//! Requires `make artifacts`; falls back to the mock backend with a
//! loud note when artifacts are absent (so `cargo bench` never breaks).
//!
//! Run: `cargo bench --bench e2e_serving`.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};
use tilekit::config::ServingConfig;
use tilekit::coordinator::{BlockWithTimeout, FleetBuilder, Request, TilePolicy};
use tilekit::image::generate;
use tilekit::runtime::executor::EngineHandle;
use tilekit::runtime::{Manifest, MockEngine, ResizeBackend};
use tilekit::util::text::Table;
use tilekit::util::Pcg32;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (manifest, backend, backend_name): (Manifest, Arc<dyn ResizeBackend>, &str) =
        match Manifest::load(&dir) {
            Ok(m) => {
                let h: Arc<dyn ResizeBackend> = Arc::new(EngineHandle::new(m.clone()));
                (m, h, "pjrt-cpu")
            }
            Err(e) => {
                eprintln!("NOTE: artifacts unavailable ({e}); using mock backend");
                let m = Manifest::parse(
                    r#"{"version":1,"artifacts":[
                        {"name":"bl_s2_b4","kernel":"bilinear","src":[64,64],
                         "scale":2,"batch":4,"tile":[4,32],"path":"x"}]}"#,
                    dir,
                )
                .unwrap();
                (m.clone(), Arc::new(MockEngine::new()), "mock")
            }
        };

    let n_requests: usize = std::env::var("TILEKIT_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    println!("=== e2e serving over {backend_name}: {n_requests} requests ===\n");
    let mut table = Table::new(vec![
        "batch_max",
        "workers",
        "wall ms",
        "req/s",
        "mean batch",
        "p50 us",
        "p99 us",
    ]);
    for (batch_max, workers) in [(1usize, 1usize), (1, 2), (4, 1), (4, 2), (8, 2)] {
        let cfg = ServingConfig {
            workers,
            batch_max: Some(batch_max),
            batch_deadline_ms: 1.0,
            queue_cap: 512,
            artifacts_dir: "artifacts".into(),
            ..ServingConfig::default()
        };
        // Largest-tile (CPU-optimal) variants (EXPERIMENTS.md §Perf);
        // closed loop, so block on backpressure instead of rejecting.
        let svc = FleetBuilder::new(&cfg, &manifest)
            .backend(Arc::clone(&backend), TilePolicy::PortableFallback)
            .admission(BlockWithTimeout(Duration::from_secs(60)))
            .build()
            .expect("service starts");
        let keys = svc.keys();
        // Warmup outside the timed region: every worker thread compiles
        // its artifacts on first use (the PJRT client is thread-local);
        // drive enough requests through each shape to warm all workers.
        for _ in 0..workers.max(2) {
            let warm: Vec<_> = keys
                .iter()
                .flat_map(|key| {
                    (0..batch_max).map(|_| {
                        let img =
                            generate::test_scene(key.src.1 as usize, key.src.0 as usize, 0);
                        svc.submit(Request::new(key.kernel, img, key.scale)).unwrap()
                    })
                })
                .collect();
            for t in warm {
                t.wait().unwrap();
            }
        }
        svc.reset_stats();
        let mut rng = Pcg32::seeded(7);
        // Pre-generate request images outside the timed region.
        let reqs: Vec<_> = (0..n_requests)
            .map(|_| {
                let key = *rng.pick(&keys);
                let img =
                    generate::test_scene(key.src.1 as usize, key.src.0 as usize, rng.next_u64());
                (key, img)
            })
            .collect();
        let t0 = Instant::now();
        let tickets: Vec<_> = reqs
            .into_iter()
            .map(|(key, img)| {
                svc.submit(Request::new(key.kernel, img, key.scale))
                    .expect("admitted")
            })
            .collect();
        for t in tickets {
            t.wait().expect("completed");
        }
        let wall = t0.elapsed();
        let stats = svc.shutdown();
        table.row(vec![
            batch_max.to_string(),
            workers.to_string(),
            format!("{:.1}", wall.as_secs_f64() * 1e3),
            format!("{:.0}", n_requests as f64 / wall.as_secs_f64()),
            format!("{:.2}", stats.mean_batch()),
            format!("{:.0}", stats.latency.percentile_us(50.0)),
            format!("{:.0}", stats.latency.percentile_us(99.0)),
        ]);
    }
    print!("{}", table.render());
}
