//! Integration tests for the strategy-driven tuning API: the
//! `TuningSession` façade, the search strategies, the persistent tuning
//! cache, and the `TilePolicy` routing seam. These pin the acceptance
//! criteria of the API redesign:
//!
//! * `Exhaustive` reproduces the seed behavior exactly (portable pick is
//!   32×4 with worst-case regret < 1.05 on the paper pair at scales
//!   6/8/10);
//! * `CoordinateDescent` lands within 1.05× of the exhaustive best using
//!   strictly fewer `CostModel::evaluate` calls (counted by a wrapping
//!   counter model);
//! * a `Router` built from `TilePolicy::PerDevice` routes each device to
//!   its own tuned tile.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use tilekit::autotuner::{
    portable_tile, sweep, Cached, CoordinateDescent, CountingCostModel, Exhaustive, SimCostModel,
    TuningOutcome, TuningSession,
};
use tilekit::coordinator::{Router, TilePolicy};
use tilekit::device::paper_pair;
use tilekit::image::Interpolator;
use tilekit::runtime::Manifest;
use tilekit::tiling::{paper_sweep_tiles, TileDim};

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The seed `portable_pick_matches_paper_conclusion` claim, through the
/// new API: exhaustive sessions pick 32×4 with regret < 1.05 at the
/// paper's large scales.
#[test]
fn exhaustive_session_reproduces_seed_behavior() {
    let n_tiles = paper_sweep_tiles().len() as u64;
    for scale in [6u32, 8, 10] {
        let outcome = TuningSession::sim().scale(scale).run().unwrap();
        assert_eq!(outcome.strategy, "exhaustive");
        assert_eq!(
            outcome.portable_tile(),
            Some(TileDim::new(32, 4)),
            "scale {scale}"
        );
        let choice = outcome.portable.as_ref().unwrap();
        assert!(
            choice.worst_regret < 1.05,
            "scale {scale}: regret {}",
            choice.worst_regret
        );
        for dt in &outcome.per_device {
            assert_eq!(dt.best, TileDim::new(32, 4), "{} scale {scale}", dt.device_id);
            assert_eq!(dt.evaluations, n_tiles);
        }
        assert_eq!(outcome.evaluations, n_tiles * 2);
    }
}

/// The session's portable pick is byte-identical to the low-level
/// sweep + portable_tile pipeline it replaced.
#[test]
fn exhaustive_session_equals_legacy_pipeline() {
    let (gtx, gts) = paper_pair();
    let tiles = paper_sweep_tiles();
    for scale in [2u32, 8] {
        let sweeps = vec![
            sweep(&gtx, Interpolator::Bilinear, &tiles, scale, (800, 800)),
            sweep(&gts, Interpolator::Bilinear, &tiles, scale, (800, 800)),
        ];
        let legacy = portable_tile(&sweeps).unwrap();
        let outcome = TuningSession::sim().scale(scale).run().unwrap();
        assert_eq!(outcome.portable.unwrap(), legacy, "scale {scale}");
    }
}

/// Coordinate descent: within 1.05× of exhaustive-best on the paper pair
/// at the paper's large scales, with strictly fewer evaluations.
#[test]
fn descent_within_tolerance_with_strictly_fewer_evaluations() {
    for scale in [6u32, 8, 10] {
        let exhaustive = TuningSession::sim().scale(scale).run().unwrap();

        let model = CountingCostModel::new(SimCostModel);
        let calls = model.counter();
        let descent = TuningSession::new(model)
            .scale(scale)
            .strategy(CoordinateDescent::default())
            .run()
            .unwrap();

        assert_eq!(descent.strategy, "descent");
        assert!(
            descent.evaluations < exhaustive.evaluations,
            "scale {scale}: descent spent {} >= exhaustive {}",
            descent.evaluations,
            exhaustive.evaluations
        );
        // the session's accounting agrees with the wrapping counter model
        assert_eq!(descent.evaluations, calls.load(Ordering::Relaxed));

        for (ex, de) in exhaustive.per_device.iter().zip(&descent.per_device) {
            assert_eq!(ex.device_id, de.device_id);
            assert!(
                de.best_ms <= ex.best_ms * 1.05,
                "{} scale {scale}: descent best {} ms vs exhaustive {} ms",
                de.device_id,
                de.best_ms,
                ex.best_ms
            );
        }
    }
}

/// The persistent cache: a second session over the same keys costs zero
/// evaluations and returns identical tunings.
#[test]
fn cached_sessions_hit_the_tuning_db_across_processes() {
    let dir = temp_dir("tilekit_tuning_session_cache");
    let path = dir.join("tuning_cache.json");
    std::fs::remove_file(&path).ok();

    let m1 = CountingCostModel::new(SimCostModel);
    let c1 = m1.counter();
    let first = TuningSession::new(m1)
        .scale(8)
        .strategy(Cached::open(Exhaustive, &path).unwrap())
        .run()
        .unwrap();
    assert!(c1.load(Ordering::Relaxed) > 0);
    assert!(path.exists(), "write-through must create the cache file");

    // A fresh strategy over the same file simulates a later process.
    let m2 = CountingCostModel::new(SimCostModel);
    let c2 = m2.counter();
    let second = TuningSession::new(m2)
        .scale(8)
        .strategy(Cached::open(Exhaustive, &path).unwrap())
        .run()
        .unwrap();
    assert_eq!(
        c2.load(Ordering::Relaxed),
        0,
        "cache hits must not evaluate"
    );
    assert_eq!(second.evaluations, 0);
    assert_eq!(first.per_device.len(), second.per_device.len());
    for (a, b) in first.per_device.iter().zip(&second.per_device) {
        assert_eq!(a.device_id, b.device_id);
        assert_eq!(a.best, b.best);
        assert_eq!(a.best_ms, b.best_ms, "cache round trip must be lossless");
        assert_eq!(a.points, b.points);
    }
    assert_eq!(first.portable, second.portable);
    std::fs::remove_file(&path).ok();
}

/// TuningOutcome → JSON file → TuningOutcome is lossless for a real
/// session outcome.
#[test]
fn outcome_file_round_trip_is_lossless() {
    let dir = temp_dir("tilekit_tuning_session_outcome");
    let path = dir.join("outcome.json");
    let outcome = TuningSession::sim().scale(8).run().unwrap();
    outcome.save(&path).unwrap();
    let back = TuningOutcome::load(&path).unwrap();
    assert_eq!(outcome, back);
    std::fs::remove_file(&path).ok();
}

/// A router built from `TilePolicy::PerDevice` routes each device to its
/// own tuned tile, end to end from a real tuning outcome.
#[test]
fn per_device_policy_routes_tuned_tiles() {
    // Tune at scale 8: both devices pick 32x4, so extend the check with a
    // hand-verified second device preference via the manifest variants.
    let outcome = TuningSession::sim().scale(8).run().unwrap();
    let manifest = Manifest::parse(
        r#"{
          "version": 1,
          "artifacts": [
            {"name": "b4_t32x4", "kernel": "bilinear", "src": [64, 64],
             "scale": 2, "batch": 4, "tile": [4, 32], "path": "a"},
            {"name": "b4_t8x8", "kernel": "bilinear", "src": [64, 64],
             "scale": 2, "batch": 4, "tile": [8, 8], "path": "b"}
          ]
        }"#,
        PathBuf::from("."),
    )
    .unwrap();
    let policy = TilePolicy::PerDevice(outcome.clone());
    for dt in &outcome.per_device {
        let router = Router::for_device(&manifest, policy.clone(), Some(&dt.device_id));
        assert_eq!(
            router.tile_pref,
            Some(dt.best),
            "{} must route to its tuned tile",
            dt.device_id
        );
    }
    // unknown device → the portable pick
    let router = Router::for_device(&manifest, policy, Some("not-a-device"));
    assert_eq!(router.tile_pref, outcome.portable_tile());
}

/// Strategy provenance lands in the outcome, including the cache
/// decorator.
#[test]
fn strategy_names_recorded_in_outcome() {
    let outcome = TuningSession::sim()
        .strategy(Cached::new(
            CoordinateDescent::default(),
            tilekit::autotuner::TuningDb::in_memory(),
        ))
        .run()
        .unwrap();
    assert_eq!(outcome.strategy, "cached+descent");
}
