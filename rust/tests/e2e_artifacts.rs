//! Integration: the full AOT path. Loads `artifacts/manifest.json`
//! (produced by `make artifacts`), compiles the HLO through the PJRT CPU
//! client, executes batches, and checks the numerics against the rust
//! CPU reference interpolators — the cross-language twin of the python
//! kernel-vs-ref pytest.
//!
//! Skipped (with a loud message) if artifacts are absent.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;
use tilekit::config::ServingConfig;
use tilekit::coordinator::{BlockWithTimeout, FleetBuilder, Request, TilePolicy};
use tilekit::image::{generate, Image, Interpolator};
use tilekit::runtime::executor::EngineHandle;
use tilekit::runtime::{Engine, Manifest, ResizeBackend};

fn manifest() -> Option<Manifest> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP: no artifacts ({e}); run `make artifacts` first");
            None
        }
    }
}

/// Reference output for an entry+input, via the rust CPU interpolators.
fn reference(kernel: Interpolator, img: &Image<f32>, scale: u32) -> Image<f32> {
    kernel.run(img, scale)
}

#[test]
fn every_artifact_compiles_and_matches_reference() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu(m.clone()).expect("PJRT CPU client");
    println!("platform: {}", engine.platform());
    for entry in &m.entries {
        if entry.src.0 > 256 {
            continue; // paper-sized artifacts exercised in the e2e bench
        }
        let exe = engine
            .load(entry)
            .unwrap_or_else(|e| panic!("compile {}: {e:#}", entry.name));
        let imgs: Vec<Image<f32>> = (0..entry.batch as usize)
            .map(|i| {
                generate::test_scene(entry.src.1 as usize, entry.src.0 as usize, i as u64 + 7)
            })
            .collect();
        let outs = exe
            .run(&imgs)
            .unwrap_or_else(|e| panic!("execute {}: {e:#}", entry.name));
        assert_eq!(outs.len(), imgs.len(), "{}", entry.name);
        for (img, out) in imgs.iter().zip(&outs) {
            let want = reference(entry.kernel, img, entry.scale);
            let err = out.max_abs_diff(&want);
            assert!(
                err < 2e-5,
                "{}: artifact vs rust reference max |err| = {err}",
                entry.name
            );
        }
    }
}

#[test]
fn partial_batches_are_padded_and_truncated() {
    let Some(m) = manifest() else { return };
    let entry = m
        .select(Interpolator::Bilinear, (64, 64), 2, 4, None)
        .expect("b4 artifact")
        .clone();
    assert_eq!(entry.batch, 4);
    let engine = Engine::cpu(m).expect("client");
    let exe = engine.load(&entry).unwrap();
    // Submit only 2 images into the batch-4 executable.
    let imgs: Vec<Image<f32>> = (0..2).map(|i| generate::test_scene(64, 64, i)).collect();
    let outs = exe.run(&imgs).unwrap();
    assert_eq!(outs.len(), 2);
    for (img, out) in imgs.iter().zip(&outs) {
        let want = reference(Interpolator::Bilinear, img, 2);
        assert!(out.max_abs_diff(&want) < 2e-5);
    }
}

#[test]
fn tile_variants_agree_numerically() {
    // The 32x4 and 8x8 Pallas tilings must produce identical outputs —
    // tiling is a performance knob, not a numerics knob (the same
    // property the paper implicitly relies on when comparing times).
    let Some(m) = manifest() else { return };
    let v32x4 = m
        .entries
        .iter()
        .find(|e| e.name.contains("b4_t32x4_64x64") && e.kernel == Interpolator::Bilinear);
    let v8x8 = m
        .entries
        .iter()
        .find(|e| e.name.contains("b4_t8x8_64x64") && e.kernel == Interpolator::Bilinear);
    let (Some(a), Some(b)) = (v32x4, v8x8) else {
        eprintln!("SKIP: tile variants not in manifest");
        return;
    };
    let engine = Engine::cpu(m.clone()).expect("client");
    let imgs: Vec<Image<f32>> = (0..4).map(|i| generate::test_scene(64, 64, 100 + i)).collect();
    let oa = engine.load(a).unwrap().run(&imgs).unwrap();
    let ob = engine.load(b).unwrap().run(&imgs).unwrap();
    for (x, y) in oa.iter().zip(&ob) {
        assert!(x.max_abs_diff(y) < 1e-6, "tile variants diverge");
    }
}

#[test]
fn service_serves_real_artifacts_end_to_end() {
    let Some(m) = manifest() else { return };
    let backend: Arc<dyn ResizeBackend> = Arc::new(EngineHandle::new(m.clone()));
    let cfg = ServingConfig {
        workers: 2,
        batch_max: Some(4),
        batch_deadline_ms: 2.0,
        queue_cap: 64,
        artifacts_dir: "artifacts".into(),
        ..ServingConfig::default()
    };
    let svc = FleetBuilder::new(&cfg, &m)
        .backend(backend, TilePolicy::Fixed("32x4".parse().unwrap()))
        .admission(BlockWithTimeout(Duration::from_secs(60)))
        .build()
        .expect("service starts");
    let img = generate::test_scene(64, 64, 11);
    let want = reference(Interpolator::Bilinear, &img, 2);
    let tickets: Vec<_> = (0..12)
        .map(|_| {
            svc.submit(Request::new(Interpolator::Bilinear, img.clone(), 2))
                .expect("admitted")
        })
        .collect();
    for t in tickets {
        let out = t.wait().expect("completed");
        assert_eq!(out.width(), 128);
        assert!(out.max_abs_diff(&want) < 2e-5);
    }
    let stats = svc.shutdown();
    assert_eq!(stats.completed.get(), 12);
    assert_eq!(stats.failed.get(), 0);
    assert!(
        stats.mean_batch() > 1.0,
        "dynamic batching should group requests (mean batch {})",
        stats.mean_batch()
    );
}
