// Suppression-hygiene fixture: an allow whose finding is gone is an
// `unused-allow` under `--strict` (and silently inert otherwise) — so
// stale annotations rot loudly.

// analyze::allow(duration-through-bounds): the violation this covered was fixed long ago
pub fn poll_interval(ms: u64) -> std::time::Duration {
    std::time::Duration::from_millis(ms)
}
