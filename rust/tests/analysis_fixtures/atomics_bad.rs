// Known-bad fixture for `atomics-pairing`. Analyzed under a pretend
// `rust/src/exec/pool.rs` path; never compiled.
//
// `halt` is published with Release ordering but observed Relaxed: the
// reader is unordered with everything the writer did before the store
// (the `plan_version` contract, inverted).

impl Pool {
    fn shutdown(&self) {
        self.halt.store(true, Ordering::Release);
    }

    fn run(&self) {
        while !self.halt.load(Ordering::Relaxed) {
            self.step();
        }
    }
}
