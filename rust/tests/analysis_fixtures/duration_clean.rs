// Known-clean twin of `duration_bad.rs`: milliseconds route through
// the blessed bounds in `net::protocol` — `duration_from_ms` for typed
// rejection, `saturating_duration_from_ms` for clamp-to-bounds — and
// the integer constructor, which cannot panic, is not flagged.

pub fn poll_interval(ms: f64) -> std::time::Duration {
    crate::net::protocol::saturating_duration_from_ms(ms)
}

pub fn fixed_interval() -> std::time::Duration {
    std::time::Duration::from_millis(250)
}
