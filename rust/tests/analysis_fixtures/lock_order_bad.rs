// Known-bad fixture for `lock-order`. Analyzed under a pretend
// `rust/src/coordinator/server.rs` path; never compiled.
//
// Two violations: `invert` acquires `plan` while holding `topology`
// (the documented order runs plan -> topology), and `republish` calls
// `rebuild_plan()` with the topology write guard still live — the PR 8
// self-deadlock, re-created.

impl Fleet {
    fn invert(&self) {
        let topo = self.topology.write().unwrap();
        let plan = self.plan.write().unwrap();
        plan.rebalance(&topo);
    }

    fn republish(&self) {
        let topo = self.topology.write().unwrap();
        topo.bump();
        self.rebuild_plan();
    }
}
