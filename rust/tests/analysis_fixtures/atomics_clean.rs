// Known-clean twin of `atomics_bad.rs`: the Acquire load pairs with
// the Release store, so the reader sees everything the writer published
// before setting the flag.

impl Pool {
    fn shutdown(&self) {
        self.halt.store(true, Ordering::Release);
    }

    fn run(&self) {
        while !self.halt.load(Ordering::Acquire) {
            self.step();
        }
    }
}
