// Known-clean twin of `no_panic_bad.rs`: the same decode written the
// way the wire tier must be written — bounds-checked access and typed
// errors, nothing that can panic on hostile input.

pub fn decode_len(buf: &[u8]) -> Result<usize, String> {
    let hi = *buf.first().ok_or("short frame")?;
    let lo = *buf.get(1).ok_or("short frame")?;
    if hi == 0xFF {
        return Err("bad frame".to_string());
    }
    Ok((usize::from(hi) << 8) | usize::from(lo))
}
