// Known-clean twin of `guard_bad.rs`: the handle is taken out under
// the lock and joined outside it (this PR's fix), and the condvar wait
// — which hands the guard TO the blocking call, releasing it
// atomically — is exempt by design.

impl Member {
    fn join_threads(&self) {
        let handle = {
            let mut t = self.threads.lock().unwrap();
            t.batcher.take()
        };
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    fn wait_ready(&self) {
        let mut st = self.state.lock().unwrap();
        while !st.ready {
            st = self.cv.wait(st).unwrap();
        }
    }
}
