// Known-bad fixture for `no-as-narrowing-in-decode`. Analyzed under a
// pretend `rust/src/codec/json.rs` path; never compiled.
//
// The PR 6 `scale` bug in miniature: `as u32` silently aliases a
// hostile 2^32 + 2 to 2, so an absurd request decodes as a valid one.

pub fn decode_scale(raw: u64) -> u32 {
    raw as u32
}
