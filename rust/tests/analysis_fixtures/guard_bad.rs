// Known-bad fixture for `no-guard-across-block`. Analyzed under a
// pretend `rust/src/coordinator/member.rs` path; never compiled.
//
// The `join_threads` incident re-created: the `threads` mutex is held
// across `JoinHandle::join`, so every other acquirer stalls for the
// worker's whole drain.

impl Member {
    fn join_threads(&self) {
        let mut t = self.threads.lock().unwrap();
        if let Some(h) = t.batcher.take() {
            let _ = h.join();
        }
    }
}
