// Suppression fixture: a violation silenced by a well-formed
// `analyze::allow(rule-id): reason` annotation — zero findings, one
// suppression, and no `unused-allow` under `--strict`.

pub fn poll_interval(ms: f64) -> std::time::Duration {
    // analyze::allow(duration-through-bounds): fixture — demonstrates a reasoned suppression
    std::time::Duration::from_secs_f64(ms / 1e3)
}
