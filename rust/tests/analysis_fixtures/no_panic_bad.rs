// Known-bad fixture for `no-panic-on-wire`. Analyzed under a pretend
// `rust/src/net/protocol.rs` path by rust/tests/analysis.rs; never
// compiled (the analyzer walk skips `analysis_fixtures/`).
//
// Three violations, one per line: a slice index, an `.unwrap()`, and a
// `panic!` — each is a remote denial of service when `buf` comes off
// the wire.

pub fn decode_len(buf: &[u8]) -> usize {
    let hi = buf[0];
    let lo = buf
        .get(1)
        .copied()
        .unwrap();
    if hi == 0xFF {
        panic!("bad frame");
    }
    (usize::from(hi) << 8) | usize::from(lo)
}
