// Suppression-hygiene fixture: an allow without a reason and an allow
// naming an unknown rule are both `bare-allow` findings, and neither
// suppresses the violation beneath it.

pub fn poll_interval(ms: f64) -> std::time::Duration {
    // analyze::allow(duration-through-bounds)
    std::time::Duration::from_secs_f64(ms / 1e3)
}

pub fn other_interval(ms: f64) -> std::time::Duration {
    // analyze::allow(not-a-rule): the id must come from RULE_IDS
    std::time::Duration::from_secs_f64(ms / 1e3)
}
