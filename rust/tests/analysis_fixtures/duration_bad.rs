// Known-bad fixture for `duration-through-bounds`. Path-independent
// (the rule fires everywhere outside test spans); never compiled.
//
// The PR 6 `deadline_ms` incident in miniature: `f64::clamp` passes
// NaN through, so a "bounded" hostile value still reaches the panicking
// float Duration constructor.

pub fn poll_interval(ms: f64) -> std::time::Duration {
    let bounded = ms.clamp(0.0, 5000.0);
    std::time::Duration::from_secs_f64(bounded / 1e3)
}
