// Known-clean twin of `narrowing_bad.rs`: the decode path rejects
// out-of-range values with `try_from`, and the encode-side cast (a
// value this process produced, not one a peer chose) is exempt by the
// `encode*`/`to_*`/`write*` function-name rule.

pub fn decode_scale(raw: u64) -> Result<u32, String> {
    u32::try_from(raw).map_err(|_| format!("scale {raw} out of range"))
}

pub fn encode_scale(v: u32) -> u64 {
    v as u64
}
