// Known-clean twin of `lock_order_bad.rs`: the topology guard dies in
// its own block before `rebuild_plan()` runs (the PR 8 fix), and
// `ordered` takes the locks in the documented plan -> topology order
// with explicit drops.

impl Fleet {
    fn republish(&self) {
        {
            let mut topo = self.topology.write().unwrap();
            topo.bump();
        }
        self.rebuild_plan();
    }

    fn ordered(&self) {
        let plan = self.plan.write().unwrap();
        let topo = self.topology.read().unwrap();
        plan.rebalance(&topo);
        drop(topo);
        drop(plan);
    }
}
