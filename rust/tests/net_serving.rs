//! Integration tests for the out-of-process serving subsystem (`net`):
//! the PR's acceptance criteria.
//!
//! * A loopback client submit over TCP **and** over a Unix socket returns
//!   bit-identical results to an in-process submit against an identical
//!   fleet (the wire format round-trips `f32` pixels exactly).
//! * Every `FleetController` verb works remotely, including an epoch bump
//!   observed after `add_member`, and typed refusals (`Unsupported`)
//!   survive the wire.
//! * A 2-shard front tier keeps serving with **zero lost tickets** while
//!   one shard is drained and its members removed mid-run.
//! * Shape-hash routing is stable: equal request shapes always land on
//!   the same shard.

use std::sync::Arc;
use std::time::Duration;
use tilekit::autotuner::{SimCostModel, TuningOutcome, TuningSession};
use tilekit::config::ServingConfig;
use tilekit::coordinator::{
    DrainMode, Fleet, FleetBuilder, Request, RequestKey, SubmitError, TilePolicy,
};
use tilekit::device::{find_device, DeviceDescriptor};
use tilekit::image::{generate, Interpolator};
use tilekit::net::{
    BackendFactory, ClientError, FleetClient, FrontTier, FrontTierConfig, ListenAddr,
    NetClientConfig, NetServer, NetServerConfig, PayloadEncoding,
};
use tilekit::runtime::{Manifest, MockEngine, ResizeBackend};
use tilekit::tiling::TileDim;

fn serving_cfg() -> ServingConfig {
    ServingConfig {
        workers: 2,
        batch_max: Some(4),
        batch_deadline_ms: 0.5,
        queue_cap: 512,
        ..ServingConfig::default()
    }
}

fn demo_outcome(devices: &[DeviceDescriptor]) -> TuningOutcome {
    let manifest = Manifest::fleet_demo();
    TuningSession::new(SimCostModel)
        .devices(devices.to_vec())
        .kernel(Interpolator::Bilinear)
        .scale(2)
        .src((64, 64))
        .tiles(vec![TileDim::new(16, 8), TileDim::new(32, 16)])
        .run()
        .unwrap_or_else(|e| panic!("tuning the demo manifest failed: {e} ({manifest:?})"))
}

/// A 2-member mock fleet over the built-in demo manifest, each device
/// routed through its own tuned tile — the same shape `serve --listen
/// --mock --devices gtx260,fermi` builds.
fn demo_fleet() -> Arc<Fleet> {
    let gtx = find_device("gtx260").unwrap();
    let fermi = find_device("fermi").unwrap();
    let outcome = demo_outcome(&[gtx.clone(), fermi.clone()]);
    let manifest = Manifest::fleet_demo();
    let svc = FleetBuilder::new(&serving_cfg(), &manifest)
        .device(
            gtx,
            Arc::new(MockEngine::new()),
            TilePolicy::PerDevice(outcome.clone()),
        )
        .device(
            fermi,
            Arc::new(MockEngine::new()),
            TilePolicy::PerDevice(outcome),
        )
        .build()
        .unwrap();
    Arc::new(svc)
}

fn mock_factory() -> BackendFactory {
    Arc::new(|_d: &DeviceDescriptor| Arc::new(MockEngine::new()) as Arc<dyn ResizeBackend>)
}

fn server_cfg() -> NetServerConfig {
    NetServerConfig {
        read_timeout: Duration::from_millis(25),
        idle_timeout: Duration::from_secs(10),
        drain_timeout: Duration::from_secs(5),
        ..NetServerConfig::default()
    }
}

fn tcp_server(fleet: Arc<Fleet>) -> NetServer {
    NetServer::bind(
        &ListenAddr::Tcp("127.0.0.1:0".into()),
        fleet,
        mock_factory(),
        server_cfg(),
    )
    .expect("bind ephemeral TCP")
}

fn demo_request(seed: u64) -> Request {
    let img = generate::test_scene(64, 64, seed);
    Request::new(Interpolator::Bilinear, img, 2)
}

// ------------------------------------------------- loopback equivalence --

#[test]
fn tcp_loopback_submit_matches_in_process() {
    // Reference: an identical fleet, driven in-process.
    let reference = demo_fleet();
    let expected = reference
        .submit(demo_request(42))
        .unwrap()
        .wait()
        .unwrap();

    let fleet = demo_fleet();
    let server = tcp_server(Arc::clone(&fleet));
    let client = FleetClient::connect(server.local_addr()).unwrap();

    let ticket = client.submit(&demo_request(42)).unwrap();
    assert!(ticket.device_id().is_some(), "mock fleet names its members");
    let got = ticket.wait().unwrap();

    assert_eq!(got.width(), expected.width());
    assert_eq!(got.height(), expected.height());
    assert_eq!(
        got.max_abs_diff(&expected),
        0.0,
        "the wire must round-trip f32 pixels exactly"
    );

    drop(client);
    server.shutdown();
    let stats = reference.stats();
    assert_eq!(stats.completed.get(), 1);
    if let Ok(f) = Arc::try_unwrap(reference) {
        f.shutdown();
    }
}

#[test]
fn unix_socket_loopback_matches_in_process() {
    let reference = demo_fleet();
    let expected = reference
        .submit(demo_request(7))
        .unwrap()
        .wait()
        .unwrap();

    let sock = std::env::temp_dir().join(format!(
        "tilekit-net-test-{}.sock",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&sock);
    let addr = ListenAddr::Unix(sock.clone());
    let fleet = demo_fleet();
    let server =
        NetServer::bind(&addr, Arc::clone(&fleet), mock_factory(), server_cfg()).unwrap();

    let client = FleetClient::connect(server.local_addr()).unwrap();
    let got = client.submit(&demo_request(7)).unwrap().wait().unwrap();
    assert_eq!(got.max_abs_diff(&expected), 0.0);

    drop(client);
    server.shutdown();
    assert!(
        !sock.exists(),
        "shutdown must unlink the Unix socket file"
    );
    if let Ok(f) = Arc::try_unwrap(reference) {
        f.shutdown();
    }
}

#[test]
fn typed_refusals_survive_the_wire() {
    // The demo manifest has no bicubic artifact: an in-process submit
    // refuses with SubmitError::Unsupported, and the remote client must
    // see exactly the same typed error, not a string or a panic.
    let fleet = demo_fleet();
    let server = tcp_server(Arc::clone(&fleet));
    let client = FleetClient::connect(server.local_addr()).unwrap();

    let img = generate::test_scene(64, 64, 3);
    let err = client
        .submit(&Request::new(Interpolator::Bicubic, img, 2))
        .unwrap_err();
    assert_eq!(
        err.submit_error(),
        Some(SubmitError::Unsupported),
        "got: {err}"
    );

    drop(client);
    server.shutdown();
}

// ------------------------------------------------- remote control plane --

#[test]
fn every_controller_verb_works_remotely() {
    let fleet = demo_fleet();
    let server = tcp_server(Arc::clone(&fleet));
    let client = FleetClient::connect(server.local_addr()).unwrap();

    // topology + epoch
    let before = client.topology().unwrap();
    assert_eq!(before.members.len(), 2);
    assert_eq!(client.epoch().unwrap(), before.epoch);

    // add_member: a registry device joins and the epoch bumps.
    let (member_id, epoch_after_add) = client
        .add_member("8800gts", &TilePolicy::Fixed(TileDim::new(16, 8)))
        .unwrap();
    assert!(
        epoch_after_add > before.epoch,
        "add_member must bump the topology epoch ({} -> {epoch_after_add})",
        before.epoch
    );
    let topo = client.topology().unwrap();
    assert_eq!(topo.members.len(), 3);
    let added = topo
        .members
        .iter()
        .find(|m| m.id == member_id)
        .expect("the new member appears in the remote topology");
    assert_eq!(added.device.as_deref(), Some("8800gts"));
    assert_eq!(added.tile, Some(TileDim::new(16, 8)));

    // The grown fleet still serves.
    client.submit(&demo_request(11)).unwrap().wait().unwrap();

    // retune: hot-swap gtx260's tile through the wire; the flipped
    // outcome must change the preferred tile (that's what "flipped"
    // means), with no epoch change.
    let outcome = demo_outcome(&[find_device("gtx260").unwrap()]);
    let tuned = outcome.best_for("gtx260").unwrap();
    let flipped = outcome.with_flipped_winner("gtx260").unwrap();
    let epoch_before_retune = client.epoch().unwrap();
    let swapped = client.retune("gtx260", &flipped).unwrap().unwrap();
    assert_ne!(swapped, tuned, "retune must install the flipped winner");
    assert_eq!(
        client.epoch().unwrap(),
        epoch_before_retune,
        "retune is not a membership change"
    );

    // Scheduler / admission / stealing reconfiguration.
    client.set_scheduler("least-loaded").unwrap();
    client
        .set_admission("block", Duration::from_millis(250))
        .unwrap();
    client.set_steal_config(false, 4).unwrap();
    assert!(matches!(
        client.set_scheduler("no-such-scheduler").unwrap_err(),
        ClientError::Remote(_)
    ));

    // drain + remove_member: epoch bumps again, membership shrinks.
    client.drain("8800gts").unwrap();
    let drained = client.topology().unwrap();
    assert!(
        drained
            .members
            .iter()
            .find(|m| m.id == member_id)
            .unwrap()
            .draining
    );
    let epoch_after_remove = client.remove_member("8800gts", DrainMode::Graceful).unwrap();
    assert!(epoch_after_remove > epoch_after_add);
    assert_eq!(client.topology().unwrap().members.len(), 2);

    // stats: the wire summary reflects the served request.
    let stats = client.stats().unwrap();
    assert!(stats.completed >= 1, "remote stats: {stats:?}");

    // Unknown member -> typed remote error, not a dead connection.
    assert!(client.drain("nope").is_err());
    // ... and the connection still works afterwards.
    client.submit(&demo_request(12)).unwrap().wait().unwrap();

    drop(client);
    server.shutdown();
}

#[test]
fn cancel_and_try_wait_work_remotely() {
    let fleet = demo_fleet();
    let server = tcp_server(Arc::clone(&fleet));
    let client = FleetClient::connect(server.local_addr()).unwrap();

    let ticket = client.submit(&demo_request(21)).unwrap();
    // The mock backend is fast: poll until the result is ready.
    let mut got = None;
    for _ in 0..200 {
        if let Some(img) = ticket.try_wait().unwrap() {
            got = Some(img);
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(got.is_some(), "try_wait never observed the mock result");

    // cancel on an already-resolved ticket is an acknowledged no-op; on
    // a fresh one it resolves the ticket with a cancellation. Either
    // way the verb must round-trip without killing the connection.
    let t2 = client.submit(&demo_request(22)).unwrap();
    t2.cancel().unwrap();
    client.submit(&demo_request(23)).unwrap().wait().unwrap();

    drop(client);
    server.shutdown();
}

// ------------------------------------------------------- the front tier --

#[test]
fn front_tier_survives_drain_and_remove_with_zero_lost_tickets() {
    let fleet_a = demo_fleet();
    let fleet_b = demo_fleet();
    let server_a = tcp_server(Arc::clone(&fleet_a));
    let server_b = tcp_server(Arc::clone(&fleet_b));
    let addrs = vec![
        server_a.local_addr().clone(),
        server_b.local_addr().clone(),
    ];

    // Manual health polling: deterministic tests, no background thread.
    let tier = FrontTier::connect(
        &addrs,
        FrontTierConfig {
            health_poll: None,
            ..FrontTierConfig::default()
        },
    )
    .unwrap();

    // All demo traffic shares one shape, so one shard owns it all.
    let probe = generate::test_scene(64, 64, 0);
    let key = RequestKey::of(Interpolator::Bilinear, &probe, 2);
    let owner = tier.route_for(&key).expect("both shards are live");
    let other = 1 - owner;

    const N: usize = 16;
    let mut tickets = Vec::new();
    for i in 0..N / 2 {
        let (shard, t) = tier.submit(&demo_request(100 + i as u64)).unwrap();
        assert_eq!(shard, owner, "same shape must route to the owner shard");
        tickets.push((shard, t));
    }

    // Drain + remove every member of the owner shard mid-run.
    let victim = tier.client(owner);
    let topo = victim.topology().unwrap();
    for m in &topo.members {
        victim.drain(&m.label).unwrap();
    }
    for m in &topo.members {
        victim.remove_member(&m.label, DrainMode::Graceful).unwrap();
    }
    tier.poll_once();
    let views = tier.shard_views();
    assert!(
        !views[owner].alive || views[owner].draining,
        "the drained shard must stop being routable: {views:?}"
    );

    // The same shape now lands on the surviving shard.
    for i in 0..N / 2 {
        let (shard, t) = tier.submit(&demo_request(200 + i as u64)).unwrap();
        assert_eq!(shard, other, "post-drain traffic must reroute");
        tickets.push((shard, t));
    }

    // Zero lost tickets: every submit — including those issued to the
    // now-removed members before the drain — resolves with a result.
    let mut completed = 0;
    for (_, t) in tickets {
        t.wait().unwrap();
        completed += 1;
    }
    assert_eq!(completed, N);

    let merged = tier.merged_stats();
    assert!(
        merged.completed >= N as u64,
        "merged stats must count both shards: {merged:?}"
    );

    tier.shutdown();
    server_a.shutdown();
    server_b.shutdown();
}

#[test]
fn shape_hash_routing_is_stable_across_polls_and_clients() {
    let fleet_a = demo_fleet();
    let fleet_b = demo_fleet();
    let server_a = tcp_server(Arc::clone(&fleet_a));
    let server_b = tcp_server(Arc::clone(&fleet_b));
    let addrs = vec![
        server_a.local_addr().clone(),
        server_b.local_addr().clone(),
    ];
    let tier = FrontTier::connect(
        &addrs,
        FrontTierConfig {
            health_poll: None,
            ..FrontTierConfig::default()
        },
    )
    .unwrap();

    let probe = generate::test_scene(64, 64, 0);
    let key = RequestKey::of(Interpolator::Bilinear, &probe, 2);
    let first = tier.route_for(&key).unwrap();
    for _ in 0..10 {
        tier.poll_once();
        assert_eq!(
            tier.route_for(&key),
            Some(first),
            "routing must not flap while membership is stable"
        );
    }
    // ... and actual submits agree with route_for.
    for i in 0..4 {
        let (shard, t) = tier.submit(&demo_request(300 + i)).unwrap();
        assert_eq!(shard, first);
        t.wait().unwrap();
    }

    tier.shutdown();
    server_a.shutdown();
    server_b.shutdown();
}

// ------------------------------------------------------- hostile input --

#[test]
fn hostile_submit_frames_get_typed_errors_and_server_survives() {
    use std::io::{BufRead, BufReader, Write};

    let fleet = demo_fleet();
    let server = tcp_server(Arc::clone(&fleet));
    let ListenAddr::Tcp(addr) = server.local_addr().clone() else {
        unreachable!("tcp_server binds TCP");
    };

    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());

    // Each of these used to panic the per-connection reader thread
    // (Duration overflow, u64 dim-product wrap) or silently truncate
    // (oversized scale). All must come back as typed protocol errors
    // on a connection that stays usable.
    let hostiles = [
        r#"{"kernel":"bilinear","scale":2,"deadline_ms":1e300,"image":{"w":1,"h":1,"px":[0]}}"#,
        r#"{"kernel":"bilinear","scale":4294967298,"image":{"w":1,"h":1,"px":[0]}}"#,
        r#"{"kernel":"bilinear","scale":2,"image":{"w":4294967296,"h":4294967296,"px":[]}}"#,
    ];
    for (i, payload) in hostiles.iter().enumerate() {
        let id = i as u64 + 1;
        let frame = format!("{{\"v\":1,\"id\":{id},\"verb\":\"submit\",\"payload\":{payload}}}\n");
        raw.write_all(frame.as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(
            line.contains("\"err\"") && line.contains("protocol"),
            "hostile frame {id} should yield a typed protocol error, got: {line}"
        );
    }

    // The same connection — and the server as a whole — still serves.
    raw.write_all(b"{\"v\":1,\"id\":9,\"verb\":\"topology\",\"payload\":{}}\n")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\""), "topology after hostiles failed: {line}");
    drop(reader);
    drop(raw);

    let client = FleetClient::connect(server.local_addr()).unwrap();
    let img = client.submit(&demo_request(7)).unwrap().wait().unwrap();
    assert_eq!(img.width(), 128, "server must keep serving after hostile frames");

    drop(client);
    server.shutdown();
}

#[test]
fn client_redials_with_bounded_backoff_against_a_black_hole() {
    // A server-shaped black hole: accepts, reads, never responds. The
    // client uses Json encoding so connect skips the hello exchange
    // (which would itself time out against a mute peer).
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = ListenAddr::Tcp(listener.local_addr().unwrap().to_string());
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(mut c) = conn else { break };
            std::thread::spawn(move || {
                let mut buf = [0u8; 1024];
                while matches!(std::io::Read::read(&mut c, &mut buf), Ok(n) if n > 0) {}
            });
        }
    });

    let client = FleetClient::connect_with(
        &addr,
        NetClientConfig {
            response_timeout: Duration::from_millis(100),
            reconnect_backoff: Duration::from_millis(10),
            reconnect_max_tries: 3,
            payload_encoding: PayloadEncoding::Json,
            ..NetClientConfig::default()
        },
    )
    .unwrap();

    // topology is replay-safe: each timeout kills the connection and
    // the call automatically redials (with backoff) until the attempt
    // budget runs out, then surfaces a typed transport error.
    let t0 = std::time::Instant::now();
    let err = client.topology().unwrap_err();
    assert!(
        matches!(err, ClientError::Transport(_)),
        "want timeout transport error, got {err}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "3 attempts x 100ms + backoff must stay bounded, took {:?}",
        t0.elapsed()
    );
    let m = client.wire_metrics();
    assert_eq!(
        m.reconnects, 2,
        "a 3-attempt budget redials exactly twice: {m:?}"
    );
    assert!(client.is_dead(), "the final timeout leaves no live connection");

    // A submit is NOT replay-safe: redialing before anything is written
    // is fine (one reconnect), but once its frame may have reached the
    // server the call must fail instead of retrying a duplicate.
    let before = client.wire_metrics().reconnects;
    let err = client.submit(&demo_request(1)).unwrap_err();
    assert!(matches!(err, ClientError::Transport(_)), "{err}");
    let after = client.wire_metrics().reconnects;
    assert_eq!(
        after - before,
        1,
        "a submit may redial only before its frame hits the wire"
    );

    // Explicit reconnect still works for callers that want connectivity
    // re-established eagerly.
    client.reconnect().unwrap();
    assert!(!client.is_dead());
}

// --------------------------------------------- protocol v2: pipelining --

#[test]
fn pipelined_submits_from_concurrent_clones_lose_no_tickets() {
    let fleet = demo_fleet();
    let server = tcp_server(Arc::clone(&fleet));
    let client = FleetClient::connect(server.local_addr()).unwrap();
    assert!(
        client.wire_metrics().v2_session,
        "the in-tree server must negotiate v2"
    );

    // N threads share ONE connection through clones; each keeps several
    // submits outstanding before waiting any of them, so responses come
    // back out of submission order and the demultiplexer must route
    // every one to its caller.
    const THREADS: usize = 4;
    const PER_THREAD: usize = 8;
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let c = client.clone();
        handles.push(std::thread::spawn(move || {
            let mut tickets = Vec::new();
            for i in 0..PER_THREAD {
                let seed = (t * PER_THREAD + i) as u64;
                tickets.push(c.submit(&demo_request(seed)).unwrap());
            }
            let mut completed = 0usize;
            for ticket in tickets {
                let img = ticket.wait().unwrap();
                assert_eq!(img.width(), 128);
                completed += 1;
            }
            completed
        }));
    }
    // Control-plane calls interleave with the in-flight submits on the
    // same connection — a slow wait must not head-of-line-block them.
    for _ in 0..8 {
        assert_eq!(client.topology().unwrap().members.len(), 2);
    }
    let completed: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(completed, THREADS * PER_THREAD, "zero lost tickets");

    let stats = client.stats().unwrap();
    assert!(
        stats.completed >= (THREADS * PER_THREAD) as u64,
        "server-side stats must see every pipelined submit: {stats:?}"
    );

    drop(client);
    server.shutdown();
}

// ------------------------------------------ protocol v2: interop + cost --

#[test]
fn v1_and_v2_clients_get_bit_identical_results_from_a_v2_server() {
    let fleet = demo_fleet();
    let server = tcp_server(Arc::clone(&fleet));

    // A v1 client (forced JSON pixels, no hello) against the v2 server:
    // the compatibility path of the acceptance criteria.
    let v1 = FleetClient::connect_with(
        server.local_addr(),
        NetClientConfig {
            payload_encoding: PayloadEncoding::Json,
            ..NetClientConfig::default()
        },
    )
    .unwrap();
    assert!(!v1.wire_metrics().v2_session, "Json encoding must pin v1");
    let from_v1 = v1.submit(&demo_request(42)).unwrap().wait().unwrap();

    // The same request through a negotiated v2 session.
    let v2 = FleetClient::connect(server.local_addr()).unwrap();
    assert!(v2.wire_metrics().v2_session, "Binary encoding must pin v2");
    let from_v2 = v2.submit(&demo_request(42)).unwrap().wait().unwrap();

    assert_eq!(from_v1.width(), from_v2.width());
    assert_eq!(from_v1.height(), from_v2.height());
    assert_eq!(
        from_v1.max_abs_diff(&from_v2),
        0.0,
        "v1 and v2 must round-trip the same submit bit-identically"
    );

    // The redesign's headline number: the same exchange moves >=4x
    // fewer bytes on v2 (binary pixels both ways) than on v1 (JSON
    // decimal arrays). Byte counters are deterministic for a fixed
    // image, so this is a hard bound, not a flaky perf assertion.
    let m1 = v1.wire_metrics();
    let m2 = v2.wire_metrics();
    let v1_bytes = m1.bytes_sent + m1.bytes_received;
    let v2_bytes = m2.bytes_sent + m2.bytes_received;
    assert!(
        v1_bytes >= 4 * v2_bytes,
        "v2 must move >=4x fewer bytes per submit+wait: v1={v1_bytes} B, v2={v2_bytes} B"
    );

    drop(v1);
    drop(v2);
    server.shutdown();
}
