//! Integration tests for the fleet-aware serving API: QoS (deadlines,
//! cancellation, priority classes), scheduler routing invariants, and
//! the PR's acceptance criterion — a 2-device simulated fleet with
//! `TilePolicy::PerDevice` beats every single `TilePolicy::Fixed` tile
//! on aggregate sim cost when serving the same replay trace.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;
use tilekit::autotuner::{
    DeviceTuning, SimCostModel, TunedPoint, TuningDb, TuningSession,
};
use tilekit::config::ServingConfig;
use tilekit::coordinator::{
    Autoscaler, AutoscalerOpts, Biased, BlockWithTimeout, CostModelEta, DrainMode, Priority,
    FleetBuilder, RejectWhenFull, Request, RequestKey, RetuneDaemon, RetuneSpec, RoundRobin,
    StandbyMember, SubmitError, TilePolicy,
};
use tilekit::device::{find_device, DeviceDescriptor};
use tilekit::image::{generate, Interpolator};
use tilekit::runtime::{Manifest, MockEngine};
use tilekit::tiling::TileDim;
use tilekit::workload::{replay, Arrival, LoadPhase, Trace};

/// Serving manifest for the fleet tests: the shared fixture — one
/// bilinear 64x64/s2 shape at the two tile variants (16x8, 32x16)
/// whose preference flips between GPU models.
fn fleet_manifest() -> Manifest {
    Manifest::fleet_demo()
}

fn nearest_manifest() -> Manifest {
    Manifest::parse(
        r#"{
          "version": 1,
          "artifacts": [
            {"name": "nn_s2_b4", "kernel": "nearest", "src": [64, 64],
             "scale": 2, "batch": 4, "tile": [8, 16], "path": "x"}
          ]
        }"#,
        PathBuf::from("."),
    )
    .unwrap()
}

fn pair() -> (DeviceDescriptor, DeviceDescriptor) {
    (
        find_device("gtx260").unwrap(),
        find_device("fermi").unwrap(),
    )
}

fn cfg() -> ServingConfig {
    ServingConfig {
        workers: 2,
        batch_max: Some(4),
        batch_deadline_ms: 0.5,
        queue_cap: 512,
        ..ServingConfig::default()
    }
}

fn bilinear_key() -> RequestKey {
    RequestKey {
        kernel: Interpolator::Bilinear,
        src: (64, 64),
        scale: 2,
    }
}

// ---------------------------------------------------------------- QoS --

#[test]
fn deadline_expiry_sheds_before_execution() {
    // One slow worker, batch_max 1: the first request occupies the
    // worker for 100ms, so the second (5ms budget) expires while queued
    // and must be shed WITHOUT reaching the backend.
    let manifest = fleet_manifest();
    let backend = Arc::new(MockEngine::with_delay(Duration::from_millis(100)));
    let slow: Arc<MockEngine> = Arc::clone(&backend);
    let config = ServingConfig {
        workers: 1,
        batch_max: Some(1),
        batch_deadline_ms: 0.1,
        queue_cap: 64,
        ..ServingConfig::default()
    };
    let svc = FleetBuilder::new(&config, &manifest)
        .backend(backend, TilePolicy::PortableFallback)
        .admission(BlockWithTimeout(Duration::from_secs(10)))
        .build()
        .unwrap();
    let img = generate::test_scene(64, 64, 1);
    let occupier = svc
        .submit(Request::new(Interpolator::Bilinear, img.clone(), 2))
        .unwrap();
    let doomed = svc
        .submit(
            Request::new(Interpolator::Bilinear, img, 2).deadline(Duration::from_millis(5)),
        )
        .unwrap();
    let err = doomed.wait().unwrap_err().to_string();
    assert!(err.contains("deadline"), "unexpected error: {err}");
    occupier.wait().unwrap();
    let stats = svc.shutdown();
    assert_eq!(stats.shed.get(), 1);
    assert_eq!(stats.completed.get(), 1);
    assert_eq!(
        slow.executed.get(),
        1,
        "the expired request must never execute"
    );
}

#[test]
fn zero_budget_fails_fast_at_submit() {
    let manifest = fleet_manifest();
    let svc = Fleet::single(
        &cfg(),
        &manifest,
        Arc::new(MockEngine::new()),
        TilePolicy::PortableFallback,
    )
    .unwrap();
    let img = generate::test_scene(64, 64, 2);
    assert!(matches!(
        svc.submit(Request::new(Interpolator::Bilinear, img, 2).deadline(Duration::ZERO)),
        Err(SubmitError::DeadlineExceeded)
    ));
    let stats = svc.shutdown();
    assert_eq!(stats.shed.get(), 1);
}

#[test]
fn cancel_before_batch_pickup_never_reaches_a_worker() {
    // batch_max 4 and a 10s batch deadline: a single request sits in the
    // batcher until either fills. Cancelling it must shed it from the
    // pending table — the backend never sees it.
    let manifest = fleet_manifest();
    let backend = Arc::new(MockEngine::new());
    let engine: Arc<MockEngine> = Arc::clone(&backend);
    let config = ServingConfig {
        workers: 1,
        batch_max: Some(4),
        batch_deadline_ms: 10_000.0,
        queue_cap: 64,
        ..ServingConfig::default()
    };
    let svc = FleetBuilder::new(&config, &manifest)
        .backend(backend, TilePolicy::PortableFallback)
        .admission(RejectWhenFull)
        .build()
        .unwrap();
    let img = generate::test_scene(64, 64, 3);
    let ticket = svc
        .submit(Request::new(Interpolator::Bilinear, img, 2))
        .unwrap();
    ticket.cancel();
    let err = ticket.wait().unwrap_err().to_string();
    assert!(err.contains("cancelled"), "unexpected error: {err}");
    let stats = svc.shutdown();
    assert_eq!(stats.cancelled.get(), 1);
    assert_eq!(stats.completed.get(), 0);
    assert_eq!(engine.executed.get(), 0, "cancelled work must never execute");
}

#[test]
fn priority_class_histograms_fill_in_e2e_serving() {
    let manifest = fleet_manifest();
    let svc = Fleet::single(
        &cfg(),
        &manifest,
        Arc::new(MockEngine::new()),
        TilePolicy::PortableFallback,
    )
    .unwrap();
    let img = generate::test_scene(64, 64, 4);
    let tickets: Vec<_> = (0..16)
        .map(|i| {
            let p = if i % 2 == 0 {
                Priority::Interactive
            } else {
                Priority::Batch
            };
            svc.submit(Request::new(Interpolator::Bilinear, img.clone(), 2).priority(p))
                .unwrap()
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let stats = svc.shutdown();
    assert_eq!(stats.completed.get(), 16);
    for p in Priority::ALL {
        assert!(
            stats.latency_by_class[p.index()].count() >= 8,
            "{} latency histogram must be populated",
            p.label()
        );
        assert!(
            stats.queue_by_class[p.index()].count() >= 8,
            "{} queue histogram must be populated",
            p.label()
        );
    }
    let report = stats.class_summary();
    assert!(report.contains("interactive") && report.contains("batch"));
}

// ---------------------------------------------------------- scheduling --

/// Property: whatever the scheduler, every admitted request lands on a
/// device whose router supports its key. Member A serves only bilinear,
/// member B only nearest; tickets expose the chosen device.
#[test]
fn every_admitted_request_lands_on_a_supporting_device() {
    let (gtx, fermi) = pair();
    for name in ["round-robin", "least-loaded", "cost-eta"] {
        let mut config = cfg();
        config.scheduler = name.to_string();
        let svc = FleetBuilder::new(&config, &fleet_manifest())
            .device(
                gtx.clone(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .device_with_manifest(
                fermi.clone(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
                nearest_manifest(),
            )
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        assert_eq!(svc.scheduler_name(), name);
        let img = generate::test_scene(64, 64, 5);
        let mut tickets = Vec::new();
        for i in 0..30 {
            let kernel = if i % 3 == 0 {
                Interpolator::Nearest
            } else {
                Interpolator::Bilinear
            };
            let t = svc
                .submit(Request::new(kernel, img.clone(), 2))
                .unwrap_or_else(|e| panic!("{name}: submit {i} failed: {e}"));
            // Only one member supports each kernel, so a correct pick is
            // fully determined.
            let expected = if kernel == Interpolator::Nearest {
                "fermi"
            } else {
                "gtx260"
            };
            assert_eq!(
                t.device_id(),
                Some(expected),
                "{name}: {} request routed to a device that cannot serve it",
                kernel.label()
            );
            tickets.push(t);
        }
        for t in tickets {
            t.wait().unwrap();
        }
        // a key nobody serves is rejected, not misrouted
        let img9 = generate::gradient(9, 9);
        assert!(
            matches!(
                svc.submit(Request::new(Interpolator::Bilinear, img9, 2)),
                Err(SubmitError::Unsupported)
            ),
            "{name}: unsupported key must be rejected"
        );
        svc.shutdown();
    }
}

// ---------------------------------------------- the acceptance criterion --

/// Serve `trace` on the 2-device fleet under `policy`; return the
/// aggregate sim cost (ms) accumulated by the workers' cost meters.
fn aggregate_sim_cost(policy: TilePolicy, trace: &Trace) -> f64 {
    let (gtx, fermi) = pair();
    let manifest = fleet_manifest();
    // The PR 2 static fleet: no stealing, so the policy comparison is
    // exactly "which tile does each device route through".
    let config = ServingConfig {
        work_stealing: false,
        ..cfg()
    };
    let svc = FleetBuilder::new(&config, &manifest)
        .device(gtx, Arc::new(MockEngine::new()), policy.clone())
        .device(fermi, Arc::new(MockEngine::new()), policy)
        .scheduler(RoundRobin::default())
        .admission(BlockWithTimeout(Duration::from_secs(30)))
        .build()
        .unwrap();
    let out = replay(&svc, trace);
    assert_eq!(
        out.completed,
        trace.events.len(),
        "replay must complete everything: {}",
        out.summary()
    );
    let stats = svc.shutdown();
    assert!(stats.sim_cost_ns.get() > 0, "metered fleet records cost");
    assert_eq!(
        stats.unpriced.get(),
        0,
        "every request must be priced or the aggregate is not comparable"
    );
    stats.sim_cost_ms()
}

/// The paper's claim, served: per-device tuned tiles beat the best
/// single fixed tile on aggregate sim cost over the same trace.
#[test]
fn per_device_tiles_beat_best_single_fixed_tile_on_a_2_device_fleet() {
    let (gtx, fermi) = pair();
    let tiles = [TileDim::new(16, 8), TileDim::new(32, 16)];
    let outcome = TuningSession::new(SimCostModel)
        .devices([gtx, fermi])
        .kernel(Interpolator::Bilinear)
        .scale(2)
        .src((64, 64))
        .tiles(tiles)
        .run()
        .unwrap();
    // The heterogeneity the fleet exploits: the two models tune to
    // DIFFERENT tiles at this shape (cc1.3's segmented half-warp
    // coalescing vs Fermi's cached warp + higher occupancy headroom).
    let best_gtx = outcome.best_for("gtx260").unwrap();
    let best_fermi = outcome.best_for("fermi").unwrap();
    assert_eq!(best_gtx, TileDim::new(16, 8));
    assert_eq!(best_fermi, TileDim::new(32, 16));
    assert_ne!(best_gtx, best_fermi);

    let trace = Trace::generate(
        &[bilinear_key()],
        60,
        Arrival::Uniform { rate: 4000.0 },
        2010,
    );
    let per_device = aggregate_sim_cost(TilePolicy::PerDevice(outcome), &trace);
    let fixed: Vec<f64> = tiles
        .iter()
        .map(|&t| aggregate_sim_cost(TilePolicy::Fixed(t), &trace))
        .collect();
    let best_fixed = fixed.iter().copied().fold(f64::INFINITY, f64::min);
    assert!(
        per_device < best_fixed,
        "per-device tiles ({per_device:.4} ms) must beat the best fixed tile \
         ({best_fixed:.4} ms; all fixed: {fixed:?})"
    );
}

// ------------------------------------------------ the adaptive fleet --

/// THE acceptance criterion of this PR: under a skewed replay trace
/// (>=70% of requests initially routed to one member), the adaptive
/// fleet — work-stealing on, so idle capacity pulls queued work out of
/// the hot member and serves it through its own tuned tile — beats the
/// PR 2 static fleet on BOTH aggregate sim cost and interactive p99.
#[test]
fn adaptive_fleet_beats_static_fleet_on_skewed_trace() {
    let (gtx, fermi) = pair();
    let tiles = [TileDim::new(16, 8), TileDim::new(32, 16)];
    let outcome = TuningSession::new(SimCostModel)
        .devices([gtx.clone(), fermi.clone()])
        .kernel(Interpolator::Bilinear)
        .scale(2)
        .src((64, 64))
        .tiles(tiles)
        .run()
        .unwrap();
    // Hot-spot the device whose tuned tile simulates MORE expensive:
    // stolen overflow then executes on the cheaper device, so the
    // adaptive win shows up in aggregate sim cost as well as latency.
    let ms_of = |id: &str| outcome.device(id).unwrap().best_ms;
    assert_ne!(
        ms_of("gtx260"),
        ms_of("fermi"),
        "fleet must be heterogeneous for the comparison to mean anything"
    );
    let hot = if ms_of("gtx260") >= ms_of("fermi") { 0 } else { 1 };

    let n = 160;
    let trace = Trace::generate(&[bilinear_key()], n, Arrival::Immediate, 77);
    let run = |stealing: bool| {
        let config = ServingConfig {
            workers: 1,
            batch_max: Some(2),
            batch_deadline_ms: 0.2,
            queue_cap: 512,
            work_stealing: stealing,
            steal_threshold: 2,
            ..ServingConfig::default()
        };
        let delay = Duration::from_millis(2);
        let svc = FleetBuilder::new(&config, &fleet_manifest())
            .device(
                gtx.clone(),
                Arc::new(MockEngine::with_delay(delay)),
                TilePolicy::PerDevice(outcome.clone()),
            )
            .device(
                fermi.clone(),
                Arc::new(MockEngine::with_delay(delay)),
                TilePolicy::PerDevice(outcome.clone()),
            )
            // 85% of submissions land on the hot member: the skew the
            // static fleet cannot escape.
            .scheduler(Biased::new(hot, 85))
            .admission(BlockWithTimeout(Duration::from_secs(30)))
            .build()
            .unwrap();
        let out = replay(&svc, &trace);
        assert_eq!(out.completed, n, "replay must complete everything: {}", out.summary());
        let admitted_hot = svc.members()[hot].stats.admitted.get();
        assert!(
            admitted_hot as f64 >= 0.7 * n as f64,
            "trace must be skewed (hot member admitted {admitted_hot}/{n})"
        );
        let stats = svc.shutdown();
        assert_eq!(stats.unpriced.get(), 0, "aggregate must be comparable");
        (
            stats.sim_cost_ms(),
            stats.latency_by_class[Priority::Interactive.index()].percentile_us(99.0),
            stats.steals.get(),
            stats.stolen.get(),
        )
    };

    let (static_cost, static_p99, static_steals, _) = run(false);
    let (adaptive_cost, adaptive_p99, adaptive_steals, adaptive_stolen) = run(true);

    assert_eq!(static_steals, 0, "the static fleet must not steal");
    assert!(adaptive_steals > 0, "the adaptive fleet must actually steal");
    assert_eq!(
        adaptive_steals, adaptive_stolen,
        "every theft is accounted on both sides"
    );
    assert!(
        adaptive_cost < static_cost,
        "adaptive fleet must beat the static fleet on aggregate sim cost: \
         adaptive {adaptive_cost:.4} ms vs static {static_cost:.4} ms \
         ({adaptive_steals} steals)"
    );
    assert!(
        adaptive_p99 < static_p99,
        "adaptive fleet must beat the static fleet on interactive p99: \
         adaptive {adaptive_p99:.0} us vs static {static_p99:.0} us"
    );
}

// ------------------------------------------------- tuned-tile refresh --

/// A `TuningDb` refresh changed a member's winner: `TuningDb::outcome_for`
/// assembles the fresh fleet outcome and `Fleet::retune` hot-swaps the
/// member's router without draining the fleet.
#[test]
fn tuning_db_refresh_drives_retune() {
    let t16x8 = TileDim::new(16, 8);
    let t32x16 = TileDim::new(32, 16);
    let tuning = |id: &str, best: TileDim, other: TileDim| {
        DeviceTuning::from_points(
            id.to_string(),
            vec![
                TunedPoint { tile: best, ms: 1.0 },
                TunedPoint { tile: other, ms: 2.0 },
            ],
            2,
        )
        .unwrap()
    };
    let fp = TuningDb::tiles_fingerprint(&[t16x8, t32x16]);
    let key = (Interpolator::Bilinear, 2u32, (64u32, 64u32));

    // Yesterday's cache: both devices prefer 16x8.
    let mut db = TuningDb::in_memory();
    db.insert(key.0, key.1, key.2, "exhaustive", &fp, tuning("gtx260", t16x8, t32x16));
    db.insert(key.0, key.1, key.2, "exhaustive", &fp, tuning("fermi", t16x8, t32x16));
    let stale = db
        .outcome_for(key.0, key.1, key.2, "exhaustive", &fp, &["gtx260", "fermi"])
        .unwrap();

    let (gtx, fermi) = pair();
    let svc = FleetBuilder::new(&cfg(), &fleet_manifest())
        .device(gtx, Arc::new(MockEngine::new()), TilePolicy::PerDevice(stale.clone()))
        .device(fermi, Arc::new(MockEngine::new()), TilePolicy::PerDevice(stale))
        .admission(BlockWithTimeout(Duration::from_secs(10)))
        .build()
        .unwrap();
    assert!(svc.members().iter().all(|v| v.tile_pref == Some(t16x8)));

    // The refresh flips fermi's winner to 32x16. Retune only fermi —
    // traffic keeps flowing through both members across the swap.
    db.insert(key.0, key.1, key.2, "exhaustive", &fp, tuning("fermi", t32x16, t16x8));
    let fresh = db
        .outcome_for(key.0, key.1, key.2, "exhaustive", &fp, &["gtx260", "fermi"])
        .unwrap();
    let img = generate::test_scene(64, 64, 21);
    let before = svc
        .submit(Request::new(Interpolator::Bilinear, img.clone(), 2))
        .unwrap();
    assert_eq!(
        svc.controller().retune("fermi", &fresh).unwrap(),
        Some(t32x16)
    );
    let after = svc
        .submit(Request::new(Interpolator::Bilinear, img, 2))
        .unwrap();
    before.wait().unwrap();
    after.wait().unwrap();

    let views = svc.members();
    let tile_of = |label: &str| {
        views
            .iter()
            .find(|v| &*v.label == label)
            .map(|v| v.tile_pref)
            .unwrap()
    };
    assert_eq!(tile_of("gtx260"), Some(t16x8), "untouched member keeps its tile");
    assert_eq!(tile_of("fermi"), Some(t32x16), "retuned member hot-swapped");
    drop(views);
    let stats = svc.shutdown();
    assert_eq!(stats.retunes.get(), 1);
    assert_eq!(stats.completed.get(), 2);
}

// ------------------------------------------------- elastic membership --

/// THE elastic acceptance criterion: a 1-member fleet serves under
/// load; `FleetController::add_member` brings a second tuned device in
/// live; aggregate sim cost improves vs staying single-member, and no
/// submission errors and no ticket is lost across the epoch flip.
#[test]
fn live_add_member_improves_cost_without_losing_a_ticket() {
    let (gtx, fermi) = pair();
    let tiles = [TileDim::new(16, 8), TileDim::new(32, 16)];
    let outcome = TuningSession::new(SimCostModel)
        .devices([gtx.clone(), fermi.clone()])
        .kernel(Interpolator::Bilinear)
        .scale(2)
        .src((64, 64))
        .tiles(tiles)
        .run()
        .unwrap();
    // Start on the device whose tuned tile simulates MORE expensive, so
    // the live joiner is a strict improvement the scheduler can exploit.
    let ms_of = |id: &str| outcome.device(id).unwrap().best_ms;
    assert_ne!(ms_of("gtx260"), ms_of("fermi"));
    let (solo, joiner) = if ms_of("gtx260") >= ms_of("fermi") {
        (gtx, fermi)
    } else {
        (fermi, gtx)
    };

    let n1 = 40usize;
    let n2 = 80usize;
    let run = |elastic: bool| {
        let config = ServingConfig {
            workers: 1,
            batch_max: Some(2),
            batch_deadline_ms: 0.2,
            queue_cap: 512,
            work_stealing: false, // isolate the scheduler's contribution
            ..ServingConfig::default()
        };
        let svc = FleetBuilder::new(&config, &fleet_manifest())
            .device(
                solo.clone(),
                Arc::new(MockEngine::with_delay(Duration::from_millis(1))),
                TilePolicy::PerDevice(outcome.clone()),
            )
            .scheduler(CostModelEta)
            .admission(BlockWithTimeout(Duration::from_secs(30)))
            .build()
            .unwrap();
        let ctl = svc.controller();
        let epoch0 = ctl.epoch();
        let img = generate::test_scene(64, 64, 41);
        let mut tickets = Vec::with_capacity(n1 + n2);
        for i in 0..n1 {
            tickets.push(
                svc.submit(Request::new(Interpolator::Bilinear, img.clone(), 2))
                    .unwrap_or_else(|e| panic!("wave-1 submit {i} failed: {e}")),
            );
        }
        if elastic {
            // The transition under load: wave-1 work is still in flight.
            ctl.add_member(
                joiner.clone(),
                Arc::new(MockEngine::with_delay(Duration::from_millis(1))),
                TilePolicy::PerDevice(outcome.clone()),
            )
            .unwrap();
            assert_eq!(svc.member_count(), 2);
            assert!(ctl.epoch() > epoch0, "add bumps the membership epoch");
        }
        for i in 0..n2 {
            tickets.push(
                svc.submit(Request::new(Interpolator::Bilinear, img.clone(), 2))
                    .unwrap_or_else(|e| panic!("wave-2 submit {i} failed: {e}")),
            );
        }
        for (i, t) in tickets.into_iter().enumerate() {
            t.wait()
                .unwrap_or_else(|e| panic!("ticket {i} lost across the transition: {e}"));
        }
        let stats = svc.shutdown();
        assert_eq!(stats.completed.get(), (n1 + n2) as u64, "nothing lost");
        assert_eq!(stats.failed.get() + stats.shed.get() + stats.cancelled.get(), 0);
        assert_eq!(stats.unpriced.get(), 0, "aggregate must be comparable");
        stats.sim_cost_ms()
    };

    let solo_cost = run(false);
    let elastic_cost = run(true);
    assert!(
        elastic_cost < solo_cost,
        "adding a tuned member live must improve aggregate sim cost: \
         elastic {elastic_cost:.4} ms vs solo {solo_cost:.4} ms"
    );
}

/// Satellite: in-flight tickets issued before `remove_member(Graceful)`
/// still complete; nothing is lost or double-executed across the epoch
/// flip, and the removed member's stats survive in the fleet totals.
#[test]
fn graceful_remove_under_load_completes_every_ticket() {
    let (gtx, fermi) = pair();
    let config = ServingConfig {
        workers: 1,
        batch_max: Some(2),
        batch_deadline_ms: 0.2,
        queue_cap: 512,
        steal_threshold: 2,
        ..ServingConfig::default()
    };
    let n = 40usize;
    let svc = FleetBuilder::new(&config, &fleet_manifest())
        .device(
            gtx,
            Arc::new(MockEngine::with_delay(Duration::from_millis(2))),
            TilePolicy::PortableFallback,
        )
        .device(
            fermi,
            Arc::new(MockEngine::with_delay(Duration::from_millis(2))),
            TilePolicy::PortableFallback,
        )
        .scheduler(RoundRobin::default())
        .admission(BlockWithTimeout(Duration::from_secs(30)))
        .build()
        .unwrap();
    let ctl = svc.controller();
    let img = generate::test_scene(64, 64, 42);
    let tickets: Vec<_> = (0..n)
        .map(|i| {
            svc.submit(Request::new(Interpolator::Bilinear, img.clone(), 2))
                .unwrap_or_else(|e| panic!("submit {i} failed: {e}"))
        })
        .collect();
    // Remove a member while roughly half the fleet's work is queued on
    // it. Graceful: its pipeline drains everything it owns first.
    ctl.remove_member("fermi", DrainMode::Graceful).unwrap();
    assert_eq!(svc.member_count(), 1);
    for (i, t) in tickets.into_iter().enumerate() {
        t.wait()
            .unwrap_or_else(|e| panic!("ticket {i} lost by graceful removal: {e}"));
    }
    // The fleet keeps serving after the removal, on the survivor only.
    let t = svc
        .submit(Request::new(Interpolator::Bilinear, img, 2))
        .unwrap();
    assert_eq!(t.device_id(), Some("gtx260"));
    t.wait().unwrap();
    let stats = svc.shutdown();
    // Exactly n+1 completions fleet-wide: a double-executed request
    // would overshoot, a lost one undershoot; removed-member stats are
    // retained in the merged totals.
    assert_eq!(stats.completed.get(), (n + 1) as u64);
    assert_eq!(stats.failed.get(), 0);
    assert_eq!(
        stats.admitted.get() + stats.steals.get(),
        stats.completed.get() + stats.stolen.get(),
        "ownership accounting balances across the epoch flip"
    );
}

// ------------------------------------------------- the retune daemon --

/// THE daemon acceptance criterion: a `TuningDb` file refresh hot-swaps
/// a member's winner (retunes counter increments) with no fleet drain.
#[test]
fn retune_daemon_applies_tuning_db_file_refresh() {
    let t16x8 = TileDim::new(16, 8);
    let t32x16 = TileDim::new(32, 16);
    let tuning = |id: &str, best: TileDim, other: TileDim| {
        DeviceTuning::from_points(
            id.to_string(),
            vec![
                TunedPoint { tile: best, ms: 1.0 },
                TunedPoint { tile: other, ms: 2.0 },
            ],
            2,
        )
        .unwrap()
    };
    let fp = TuningDb::tiles_fingerprint(&[t16x8, t32x16]);
    let dir = std::env::temp_dir().join("tilekit_retune_daemon_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tuning_cache.json");
    std::fs::remove_file(&path).ok();

    // Yesterday's cache on disk: both devices prefer 16x8.
    let mut db = TuningDb::open(&path).unwrap();
    let stale_gtx = tuning("gtx260", t16x8, t32x16);
    let stale_fermi = tuning("fermi", t16x8, t32x16);
    db.insert(Interpolator::Bilinear, 2, (64, 64), "exhaustive", &fp, stale_gtx);
    db.insert(Interpolator::Bilinear, 2, (64, 64), "exhaustive", &fp, stale_fermi);
    db.persist().unwrap();
    let stale = db
        .outcome_for(Interpolator::Bilinear, 2, (64, 64), "exhaustive", &fp, &["gtx260", "fermi"])
        .unwrap();

    let (gtx, fermi) = pair();
    let svc = FleetBuilder::new(&cfg(), &fleet_manifest())
        .device(gtx, Arc::new(MockEngine::new()), TilePolicy::PerDevice(stale.clone()))
        .device(fermi, Arc::new(MockEngine::new()), TilePolicy::PerDevice(stale))
        .admission(BlockWithTimeout(Duration::from_secs(10)))
        .build()
        .unwrap();
    assert!(svc.members().iter().all(|v| v.tile_pref == Some(t16x8)));
    let daemon = RetuneDaemon::spawn(
        svc.controller(),
        path.clone(),
        RetuneSpec {
            kernel: Interpolator::Bilinear,
            scale: 2,
            src: (64, 64),
            strategy: "exhaustive".to_string(),
            tiles_fp: fp.clone(),
        },
        Duration::from_millis(10),
    );
    // First sighting of the file matches the running winners: a refresh
    // is observed but nothing needs retuning.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while daemon.stats().refreshes.get() == 0 {
        assert!(std::time::Instant::now() < deadline, "daemon never read the db");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(daemon.stats().applied.get(), 0);

    // The re-tuning run: fermi's winner flips on disk.
    let fresh_fermi = tuning("fermi", t32x16, t16x8);
    db.insert(Interpolator::Bilinear, 2, (64, 64), "exhaustive", &fp, fresh_fermi);
    db.persist().unwrap();
    while daemon.stats().applied.get() == 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "daemon never applied the refreshed winner"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    // The hot swap happened with no fleet drain: both members serve.
    let img = generate::test_scene(64, 64, 43);
    let tickets: Vec<_> = (0..8)
        .map(|_| svc.submit(Request::new(Interpolator::Bilinear, img.clone(), 2)).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let views = svc.members();
    let tile_of = |label: &str| {
        views
            .iter()
            .find(|v| &*v.label == label)
            .and_then(|v| v.tile_pref)
    };
    assert_eq!(tile_of("fermi"), Some(t32x16), "winner hot-swapped");
    assert_eq!(tile_of("gtx260"), Some(t16x8), "unmoved winner untouched");
    daemon.stop();
    drop(views);
    let stats = svc.shutdown();
    assert_eq!(stats.retunes.get(), 1, "retunes counter increments");
    assert_eq!(stats.completed.get(), 8);
    std::fs::remove_file(&path).ok();
}

/// Drain is the softer half of removal: the member stays registered and
/// finishes what it holds, but the scheduler routes new work around it.
#[test]
fn drained_member_takes_no_new_work_but_finishes_old() {
    let (gtx, fermi) = pair();
    let svc = FleetBuilder::new(&cfg(), &fleet_manifest())
        .device(
            gtx,
            Arc::new(MockEngine::with_delay(Duration::from_millis(1))),
            TilePolicy::PortableFallback,
        )
        .device(
            fermi,
            Arc::new(MockEngine::with_delay(Duration::from_millis(1))),
            TilePolicy::PortableFallback,
        )
        .scheduler(RoundRobin::default())
        .admission(BlockWithTimeout(Duration::from_secs(30)))
        .build()
        .unwrap();
    let ctl = svc.controller();
    let img = generate::test_scene(64, 64, 44);
    let before: Vec<_> = (0..12)
        .map(|_| svc.submit(Request::new(Interpolator::Bilinear, img.clone(), 2)).unwrap())
        .collect();
    ctl.drain("fermi").unwrap();
    let topo = ctl.topology();
    assert!(topo.members.iter().any(|m| &*m.label == "fermi" && m.draining));
    for _ in 0..12 {
        let t = svc
            .submit(Request::new(Interpolator::Bilinear, img.clone(), 2))
            .unwrap();
        assert_eq!(t.device_id(), Some("gtx260"), "drained member must not be picked");
        t.wait().unwrap();
    }
    for t in before {
        t.wait().unwrap();
    }
    let stats = svc.shutdown();
    assert_eq!(stats.completed.get(), 24);
    assert_eq!(stats.failed.get(), 0);
}

// ---------------------------------------------------------- autoscaler --

/// A standby device the simulator prices ~`factor`x above `base`: same
/// architecture (occupancy and tuning behave identically), clocks cut
/// by `factor`, so per-launch sim ms scales up by ~`factor` across the
/// compute, memory, and latency terms alike. Deterministic cost
/// asymmetry without depending on registry specifics.
fn surge_spare(base: &DeviceDescriptor, factor: f64) -> DeviceDescriptor {
    let mut d = base.clone();
    d.id = "spare".into();
    d.name = "Surge Spare".into();
    d.sp_clock_mhz /= factor;
    d.mem_clock_mhz /= factor;
    d
}

/// PR 7 acceptance: under a quiet-heavy burst trace, the autoscaled
/// fleet beats EVERY fixed fleet size (1..=standby-pool max) on
/// aggregate sim cost x interactive p99, with zero lost tickets across
/// every scale event and both scale directions exercised.
///
/// The geometry that makes each leg decidable:
/// * the burst (4400 rps) exceeds even the two-member throughput
///   (~4000 rps at 1 ms mock batches of 2), so the autoscaled fleet and
///   fixed-2 queue nearly identically through it (common-mode tail)
///   while fixed-1 (~2000 rps) takes a catastrophic backlog;
/// * the quiet phases dominate the event count, so fixed-2 pays the
///   spare's ~20x launch premium on half of ALL traffic while the
///   autoscaled fleet pays it only for the rush.
#[test]
fn autoscaled_fleet_beats_every_fixed_size_under_burst_trace() {
    let manifest = fleet_manifest();
    let base = find_device("fermi").unwrap();
    let spare = surge_spare(&base, 20.0);
    let outcome = TuningSession::new(SimCostModel)
        .devices([base.clone(), spare.clone()])
        .kernel(Interpolator::Bilinear)
        .scale(2)
        .src((64, 64))
        .tiles([TileDim::new(16, 8), TileDim::new(32, 16)])
        .run()
        .unwrap();
    let ms_of = |id: &str| outcome.device(id).unwrap().best_ms;
    assert!(
        ms_of("spare") > 3.0 * ms_of("fermi"),
        "the spare must be decisively pricier per launch (got {} vs {})",
        ms_of("spare"),
        ms_of("fermi")
    );

    // 2.55s of traffic: 700 rps quiet, one 150ms burst at 4400 rps.
    let keys = vec![bilinear_key()];
    let phases = [
        LoadPhase { rate: 700.0, dur_us: 1_200_000 },
        LoadPhase { rate: 4400.0, dur_us: 150_000 },
        LoadPhase { rate: 700.0, dur_us: 1_200_000 },
    ];
    let trace = Trace::phased(&keys, &phases, 7);

    let config = ServingConfig {
        workers: 1,
        batch_max: Some(2),
        batch_deadline_ms: 0.2,
        queue_cap: 4096,
        work_stealing: true,
        steal_threshold: 2,
        ..ServingConfig::default()
    };
    let delay = Duration::from_millis(1);

    // Serve the identical trace; `standby` parks the spare behind the
    // control loop instead of building it in. Returns (sim cost ms,
    // interactive p99 us, scale_ups, scale_downs).
    let run = |members: &[&DeviceDescriptor], standby: bool| -> (f64, f64, u64, u64) {
        let mut builder = FleetBuilder::new(&config, &manifest)
            .scheduler(RoundRobin::default())
            .admission(RejectWhenFull);
        for d in members {
            builder = builder.device(
                (*d).clone(),
                Arc::new(MockEngine::with_delay(delay)),
                TilePolicy::PerDevice(outcome.clone()),
            );
        }
        let svc = builder.build().unwrap();
        let scaler = standby.then(|| {
            Autoscaler::spawn(
                svc.controller(),
                vec![StandbyMember {
                    device: spare.clone(),
                    backend: Arc::new(MockEngine::with_delay(delay)),
                    policy: TilePolicy::PerDevice(outcome.clone()),
                }],
                AutoscalerOpts {
                    poll: Duration::from_millis(2),
                    low_queue: 0.5,
                    high_queue: 6.0,
                    high_p99_us: 0,
                    cooldown_ticks: 60,
                    start_disabled: false,
                },
            )
            .unwrap()
        });
        let out = replay(&svc, &trace);
        if let Some(a) = scaler {
            a.stop();
        }
        let stats = svc.shutdown();
        // Zero lost tickets across every scale event: all requests the
        // trace offered resolved successfully — none rejected, none
        // failed, none dropped by an engage or a graceful retire.
        assert_eq!(out.completed, out.offered, "lost work: {}", out.summary());
        assert_eq!(out.failed, 0, "{}", out.summary());
        assert_eq!(out.rejected, 0, "{}", out.summary());
        assert_eq!(stats.unpriced.get(), 0, "costs must be comparable");
        (
            stats.sim_cost_ms(),
            out.latency.percentile_us(99.0),
            stats.scale_ups.get(),
            stats.scale_downs.get(),
        )
    };

    let (c1, p1, u1, d1) = run(&[&base], false);
    let (c2, p2, u2, d2) = run(&[&base, &spare], false);
    let (ca, pa, ups, downs) = run(&[&base], true);
    assert_eq!((u1, d1, u2, d2), (0, 0, 0, 0), "fixed fleets never scale");
    assert!(ups > 0, "the burst must engage the spare");
    assert!(downs > 0, "the trailing quiet must park it again");

    // Each individually winnable leg, then the product against every
    // fixed size in the pool's range (1..=2).
    assert!(
        pa < p1,
        "autoscaled p99 {pa:.0}us must beat melted fixed-1 {p1:.0}us"
    );
    assert!(
        ca < c2,
        "autoscaled sim cost {ca:.0}ms must beat always-on fixed-2 {c2:.0}ms"
    );
    for (k, (ck, pk)) in [(1, (c1, p1)), (2, (c2, p2))] {
        assert!(
            ca * pa < ck * pk,
            "autoscaled cost x p99 {:.0} must beat fixed-{k} {:.0} \
             (auto {ca:.0}ms x {pa:.0}us, fixed {ck:.0}ms x {pk:.0}us)",
            ca * pa,
            ck * pk
        );
    }
}

/// Cross-member batch migration, deterministically: a not-yet-full
/// pending group (6 of 8, long flush deadline) sits on the only member;
/// a freshly added idle member finds nothing to steal from the admit
/// queue and re-homes the WHOLE group, counted once in
/// `migrated_batches` and per-request in the steal counters. Every
/// ticket completes.
#[test]
fn batch_migration_rehomes_pending_group_to_new_member() {
    let (gtx, fermi) = pair();
    let config = ServingConfig {
        workers: 1,
        batch_max: Some(8),
        batch_deadline_ms: 150.0,
        queue_cap: 64,
        work_stealing: true,
        steal_threshold: 2,
        ..ServingConfig::default()
    };
    let svc = FleetBuilder::new(&config, &fleet_manifest())
        .device(
            gtx,
            Arc::new(MockEngine::with_delay(Duration::from_millis(1))),
            TilePolicy::PortableFallback,
        )
        .scheduler(RoundRobin::default())
        .admission(RejectWhenFull)
        .build()
        .unwrap();
    let img = generate::test_scene(64, 64, 9);
    let tickets: Vec<_> = (0..6)
        .map(|_| svc.submit(Request::new(Interpolator::Bilinear, img.clone(), 2)).unwrap())
        .collect();
    // Let the sole member's batcher pull the admissions into its
    // pending table: 6 < batch_max keeps the group parked against the
    // 150ms flush deadline.
    std::thread::sleep(Duration::from_millis(10));

    // A new idle member joins mid-wait: its batcher steals first (the
    // victim's admit queue is empty), then claims the whole pending
    // group — requests keep their original admission times, so they
    // flush through the thief's tile well inside the deadline.
    svc.controller()
        .add_member(fermi, Arc::new(MockEngine::new()), TilePolicy::PortableFallback)
        .unwrap();
    for t in tickets {
        t.wait().unwrap();
    }
    let stats = svc.shutdown();
    assert_eq!(stats.completed.get(), 6);
    assert_eq!(stats.failed.get(), 0);
    assert!(
        stats.migrated_batches.get() >= 1,
        "the pending group must migrate as a unit (migrated_batches {})",
        stats.migrated_batches.get()
    );
    assert!(
        stats.steals.get() >= 6,
        "migration accounts each re-homed request as a steal (steals {})",
        stats.steals.get()
    );
}

// ----------------------------------------------------- the churn hammer --

/// PR 8 hammer: four submitter threads drive the lock-free submit fast
/// path while the control plane churns underneath them — retunes that
/// flip gtx260's winner back and forth, a third member repeatedly
/// joining and gracefully leaving, and scheduler swaps. Every mutation
/// republishes the immutable `SubmitPlan`, so three invariants must
/// hold under fire:
///
/// * **zero lost tickets** — every `Ok` ticket resolves (submitters may
///   see `Saturated`/`ShuttingDown` mid-churn, both typed and
///   retryable, never a hang or a dropped completion);
/// * **balanced ownership accounting** — `admitted + steals ==
///   completed + stolen` across every membership flip;
/// * **the retune ack contract** — by the time `retune` returns the
///   plan is republished (version bumped, tile preference flipped), so
///   no submit that starts after the ack can route the stale tile.
#[test]
fn submit_hot_path_survives_control_plane_churn() {
    use std::sync::atomic::{AtomicBool, Ordering};

    let t16x8 = TileDim::new(16, 8);
    let t32x16 = TileDim::new(32, 16);
    let tuning = |id: &str, best: TileDim, other: TileDim| {
        DeviceTuning::from_points(
            id.to_string(),
            vec![
                TunedPoint { tile: best, ms: 1.0 },
                TunedPoint { tile: other, ms: 2.0 },
            ],
            2,
        )
        .unwrap()
    };
    let fp = TuningDb::tiles_fingerprint(&[t16x8, t32x16]);
    // Two fleet outcomes differing only in gtx260's winner: the churn
    // loop retunes back and forth between them.
    let outcome_with = |gtx_best: TileDim, gtx_other: TileDim| {
        let mut db = TuningDb::in_memory();
        db.insert(
            Interpolator::Bilinear,
            2,
            (64, 64),
            "exhaustive",
            &fp,
            tuning("gtx260", gtx_best, gtx_other),
        );
        db.insert(
            Interpolator::Bilinear,
            2,
            (64, 64),
            "exhaustive",
            &fp,
            tuning("fermi", t32x16, t16x8),
        );
        db.outcome_for(
            Interpolator::Bilinear,
            2,
            (64, 64),
            "exhaustive",
            &fp,
            &["gtx260", "fermi"],
        )
        .unwrap()
    };
    let outcome_a = outcome_with(t16x8, t32x16);
    let outcome_b = outcome_with(t32x16, t16x8);

    let (gtx, fermi) = pair();
    let spare = find_device("8800gts").unwrap();
    let config = ServingConfig {
        workers: 2,
        batch_max: Some(4),
        batch_deadline_ms: 0.2,
        queue_cap: 512,
        work_stealing: false, // keep the ownership ledger two-sided
        ..ServingConfig::default()
    };
    let svc = FleetBuilder::new(&config, &fleet_manifest())
        .device(
            gtx,
            Arc::new(MockEngine::new()),
            TilePolicy::PerDevice(outcome_a.clone()),
        )
        .device(
            fermi,
            Arc::new(MockEngine::new()),
            TilePolicy::PerDevice(outcome_a.clone()),
        )
        .scheduler(RoundRobin::default())
        .admission(RejectWhenFull)
        .build()
        .unwrap();

    let stop = AtomicBool::new(false);
    let completed_ok: u64 = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for worker in 0..4u64 {
            let svc = &svc;
            let stop = &stop;
            handles.push(s.spawn(move || {
                let img = generate::test_scene(64, 64, 50 + worker);
                let mut pending: Vec<_> = Vec::with_capacity(64);
                let mut ok = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match svc.submit(Request::new(Interpolator::Bilinear, img.clone(), 2)) {
                        Ok(t) => pending.push(t),
                        // Both are typed, expected mid-churn outcomes:
                        // a full queue under non-blocking admission, or
                        // a stale plan racing a member's retirement.
                        Err(SubmitError::Saturated) | Err(SubmitError::ShuttingDown) => {
                            for t in pending.drain(..) {
                                t.wait().expect("admitted ticket lost under churn");
                                ok += 1;
                            }
                            std::thread::yield_now();
                        }
                        Err(e) => panic!("submitter {worker}: unexpected error: {e}"),
                    }
                    if pending.len() >= 64 {
                        for t in pending.drain(..) {
                            t.wait().expect("admitted ticket lost under churn");
                            ok += 1;
                        }
                    }
                }
                for t in pending {
                    t.wait().expect("admitted ticket lost at churn end");
                    ok += 1;
                }
                ok
            }));
        }

        // The churn loop: every iteration flips the retuned winner,
        // bounces the third member through join + graceful leave, and
        // swaps the scheduler — each op republishing the plan.
        let ctl = svc.controller();
        let tile_of_gtx = || {
            svc.members()
                .iter()
                .find(|v| &*v.label == "gtx260")
                .and_then(|v| v.tile_pref)
        };
        for i in 0..12usize {
            let (outcome, expect) = if i % 2 == 0 {
                (&outcome_b, t32x16)
            } else {
                (&outcome_a, t16x8)
            };
            let v_before = svc.plan_metrics().version;
            assert_eq!(ctl.retune("gtx260", outcome).unwrap(), Some(expect));
            // The ack contract: retune returned, so the republished plan
            // is already the one any subsequent submit refreshes onto.
            assert!(
                svc.plan_metrics().version > v_before,
                "retune ack precedes the plan republish"
            );
            assert_eq!(tile_of_gtx(), Some(expect), "stale tile visible after ack");

            ctl.add_member(
                spare.clone(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .unwrap();
            std::thread::sleep(Duration::from_millis(1));
            ctl.remove_member("8800gts", DrainMode::Graceful).unwrap();

            ctl.set_scheduler_by_name(if i % 2 == 0 { "least-loaded" } else { "round-robin" })
                .unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });

    assert!(completed_ok > 0, "the hammer must actually admit work");
    let metrics = svc.plan_metrics();
    assert!(
        metrics.fast_hits > 0,
        "submitters must ride the version fast path between mutations"
    );
    let stats = svc.shutdown();
    assert_eq!(
        stats.completed.get(),
        completed_ok,
        "every Ok ticket resolves exactly once"
    );
    assert_eq!(stats.failed.get(), 0);
    assert_eq!(
        stats.admitted.get() + stats.steals.get(),
        stats.completed.get() + stats.stolen.get(),
        "ownership accounting balances across every churn event"
    );
}
