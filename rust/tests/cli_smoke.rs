//! CLI smoke tests: run the built `tilekit` binary as a subprocess and
//! check each subcommand's output carries the expected experiment
//! content. Skips (loudly) if the binary hasn't been built.

use std::path::PathBuf;
use std::process::Command;

fn binary() -> Option<PathBuf> {
    // Integration tests live next to the binary under target/<profile>/.
    let mut path = std::env::current_exe().ok()?;
    path.pop(); // deps/
    path.pop(); // debug|release/
    let bin = path.join("tilekit");
    if bin.exists() {
        Some(bin)
    } else {
        eprintln!("SKIP: {} not built", bin.display());
        None
    }
}

fn run(args: &[&str]) -> (String, String, bool) {
    let bin = binary().expect("binary checked by caller");
    let out = Command::new(bin)
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn tilekit");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    if binary().is_none() {
        return;
    }
    let (out, _, ok) = run(&["help"]);
    assert!(ok);
    for cmd in ["devices", "occupancy", "sweep", "simulate", "autotune", "serve", "bench"] {
        assert!(out.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn devices_table1() {
    if binary().is_none() {
        return;
    }
    let (out, _, ok) = run(&["devices", "--table1"]);
    assert!(ok);
    assert!(out.contains("16384") && out.contains("8192"));
    assert!(out.contains("GTX 260") && out.contains("8800"));
}

#[test]
fn occupancy_cliff() {
    if binary().is_none() {
        return;
    }
    let (out, _, ok) = run(&["occupancy", "--tile", "32x16"]);
    assert!(ok);
    assert!(out.contains("gtx260") && out.contains("100%"));
    assert!(out.contains("8800gts") && (out.contains("67%") || out.contains("66%")));
}

#[test]
fn sweep_single_scale_finds_best() {
    if binary().is_none() {
        return;
    }
    let (out, _, ok) = run(&["sweep", "--scale", "8"]);
    assert!(ok);
    assert!(out.contains("best: 32x4"), "expected 32x4 best:\n{out}");
}

#[test]
fn simulate_extreme_matches_paper() {
    if binary().is_none() {
        return;
    }
    let (out, _, ok) = run(&["simulate", "--extreme"]);
    assert!(ok);
    assert!(out.contains("0.250") && out.contains("0.025"), "{out}");
}

#[test]
fn autotune_portable_is_32x4() {
    if binary().is_none() {
        return;
    }
    let (out, _, ok) = run(&["autotune", "--scale", "8"]);
    assert!(ok);
    assert!(out.contains("portable tile (min-max regret): 32x4"), "{out}");
}

#[test]
fn tune_help_lists_strategies_and_cache_flags() {
    if binary().is_none() {
        return;
    }
    let (out, _, ok) = run(&["tune", "--help"]);
    assert!(ok);
    for needle in ["--strategy", "exhaustive", "descent", "cached", "--cache", "--out"] {
        assert!(out.contains(needle), "tune --help missing '{needle}':\n{out}");
    }
}

#[test]
fn sweep_help_lists_strategies_and_cache_flags() {
    if binary().is_none() {
        return;
    }
    let (out, _, ok) = run(&["sweep", "--help"]);
    assert!(ok);
    for needle in ["--strategy", "exhaustive", "descent", "cached", "--cache"] {
        assert!(out.contains(needle), "sweep --help missing '{needle}':\n{out}");
    }
}

#[test]
fn tune_unknown_strategy_is_a_friendly_error() {
    if binary().is_none() {
        return;
    }
    let (_, err, ok) = run(&["tune", "--strategy", "annealing"]);
    assert!(!ok);
    assert!(err.contains("unknown strategy 'annealing'"), "{err}");
    for valid in ["exhaustive", "descent", "cached"] {
        assert!(err.contains(valid), "error must name '{valid}': {err}");
    }
}

#[test]
fn tune_exhaustive_portable_is_32x4() {
    if binary().is_none() {
        return;
    }
    let (out, err, ok) = run(&["tune", "--scale", "8"]);
    assert!(ok, "stderr: {err}");
    assert!(
        out.contains("portable tile (min-max regret): 32x4"),
        "{out}"
    );
    assert!(out.contains("gtx260") && out.contains("8800gts"), "{out}");
}

#[test]
fn tune_descent_with_cache_round_trips() {
    if binary().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join("tilekit_cli_tune_cache");
    std::fs::create_dir_all(&dir).unwrap();
    let cache = dir.join("cache.json");
    std::fs::remove_file(&cache).ok();
    let args = [
        "tune",
        "--strategy",
        "descent",
        "--scale",
        "8",
        "--cache",
        cache.to_str().unwrap(),
    ];
    let (out, err, ok) = run(&args);
    assert!(ok, "stderr: {err}");
    assert!(
        out.contains("portable tile (min-max regret): 32x4"),
        "{out}"
    );
    let written = std::fs::read_to_string(&cache).expect("cache file written");
    assert!(written.contains("gtx260") && written.contains("8800gts"));
    // second run is served from the cache: zero evaluations
    let (out2, err2, ok2) = run(&args);
    assert!(ok2, "stderr: {err2}");
    assert!(out2.contains("(0 evaluations)"), "{out2}");
    assert!(
        out2.contains("portable tile (min-max regret): 32x4"),
        "{out2}"
    );
    std::fs::remove_file(&cache).ok();
}

#[test]
fn serve_mock_single_backend_runs() {
    if binary().is_none() {
        return;
    }
    // --mock falls back to the built-in demo manifest when no artifacts
    // exist, so this works in a clean checkout.
    let (out, err, ok) = run(&["serve", "--mock", "--requests", "16"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("completed 16/16"), "{out}");
    assert!(out.contains("per-device breakdown"), "{out}");
    assert!(out.contains("per-priority latency"), "{out}");
    assert!(out.contains("interactive") && out.contains("batch"), "{out}");
}

#[test]
fn serve_mock_fleet_routes_per_device_tiles() {
    if binary().is_none() {
        return;
    }
    let (out, err, ok) = run(&[
        "serve",
        "--mock",
        "--requests",
        "24",
        "--devices",
        "gtx260,fermi",
        "--scheduler",
        "least-loaded",
        "--policy",
        "shed-batch",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("2 member(s)"), "{out}");
    assert!(out.contains("least-loaded") && out.contains("shed-batch"), "{out}");
    // the fleet tunes each device to its own tile before serving
    assert!(out.contains("fleet tuning"), "{out}");
    assert!(out.contains("gtx260") && out.contains("fermi"), "{out}");
    // With the built-in demo manifest the tuned tiles flip between the
    // models; a real artifacts/ dir may tune differently, so only pin
    // the flip when the fallback manifest was in play.
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !artifacts.exists() {
        assert!(out.contains("gtx260 -> 16x8"), "{out}");
        assert!(out.contains("fermi -> 32x16"), "{out}");
    }
}

#[test]
fn serve_rejects_unknown_scheduler_and_policy() {
    if binary().is_none() {
        return;
    }
    let (_, err, ok) = run(&[
        "serve", "--mock", "--devices", "gtx260", "--scheduler", "random",
    ]);
    assert!(!ok);
    assert!(err.contains("unknown scheduler 'random'"), "{err}");
    let (_, err, ok) = run(&["serve", "--mock", "--policy", "yolo"]);
    assert!(!ok);
    assert!(err.contains("unknown admission policy 'yolo'"), "{err}");
}

#[test]
fn analyze_gates_on_findings_and_self_hosts() {
    if binary().is_none() {
        return;
    }
    let (out, _, ok) = run(&["help"]);
    assert!(ok);
    assert!(out.contains("analyze"), "help missing 'analyze':\n{out}");
    // A known-bad fixture exits nonzero, prints file:line and the rule
    // id on stdout, and carries the gate message on stderr.
    let (out, err, ok) = run(&["analyze", "rust/tests/analysis_fixtures/duration_bad.rs"]);
    assert!(!ok, "bad fixture must gate\nstdout: {out}");
    assert!(out.contains("duration_bad.rs:10"), "{out}");
    assert!(out.contains("[duration-through-bounds]"), "{out}");
    assert!(err.contains("analyze found"), "{err}");
    // A missing path is a friendly error, not a panic.
    let (_, err, ok) = run(&["analyze", "no/such/path.rs"]);
    assert!(!ok);
    assert!(err.contains("no such path"), "{err}");
    // The self-hosting gate CI runs: the committed tree is clean under
    // --strict (zero findings, zero unused allows).
    let (out, err, ok) = run(&["analyze", "--strict"]);
    assert!(ok, "stderr: {err}\nstdout: {out}");
    assert!(out.contains("0 finding(s)"), "{out}");
    assert!(out.contains("(strict)"), "{out}");
}

#[test]
fn unknown_command_fails_cleanly() {
    if binary().is_none() {
        return;
    }
    let (_, err, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(err.contains("unknown subcommand"));
}

#[test]
fn init_config_round_trips() {
    if binary().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join("tilekit_cli_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("t.toml");
    let (_, _, ok) = run(&["init-config", "--out", cfg.to_str().unwrap()]);
    assert!(ok);
    // the generated config must itself be loadable
    let (out, err, ok) = run(&[
        "devices",
        "--config",
        cfg.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("gtx260"));
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn artifacts_listing_if_built() {
    if binary().is_none() {
        return;
    }
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !artifacts.exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let (out, err, ok) = run(&["artifacts"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("bilinear") && out.contains("whiles"));
    // every artifact row parses to non-zero instructions
    assert!(out.contains("artifacts in"), "{out}");
}

#[test]
fn resize_file_round_trip_if_artifacts() {
    if binary().is_none() {
        return;
    }
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !artifacts.exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    // Write a 64x64 PGM, upscale it through the artifact, check header.
    let dir = std::env::temp_dir().join("tilekit_cli_resize");
    std::fs::create_dir_all(&dir).unwrap();
    let src = dir.join("in.pgm");
    let dst = dir.join("out.pgm");
    let mut body = b"P5\n64 64\n255\n".to_vec();
    body.extend((0..64 * 64).map(|i| (i % 251) as u8));
    std::fs::write(&src, body).unwrap();
    let (out, err, ok) = run(&[
        "resize",
        src.to_str().unwrap(),
        dst.to_str().unwrap(),
        "--scale",
        "2",
    ]);
    assert!(ok, "stderr: {err}\nstdout: {out}");
    let result = std::fs::read(&dst).unwrap();
    assert!(result.starts_with(b"P5\n128 128\n255\n"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_mock_accepts_and_validates_tiles_flag() {
    if binary().is_none() {
        return;
    }
    // A custom tile set replaces the baked-in demo list; force the demo
    // manifest with a non-existent artifacts dir so the assertion holds
    // even when artifacts/ is built.
    let (out, err, ok) = run(&[
        "serve", "--mock", "--requests", "12", "--artifacts", "no-such-dir",
        "--devices", "gtx260,fermi", "--tiles", "16x8,32x16",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("fleet tuning"), "{out}");
    assert!(out.contains("16x8") && out.contains("32x16"), "{out}");
    // Malformed, empty-matching, and duplicate tile lists fail loudly.
    let (_, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir", "--tiles", "banana",
    ]);
    assert!(!ok);
    assert!(err.contains("--tiles"), "{err}");
    let (_, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir", "--tiles", "8x8,8x8",
    ]);
    assert!(!ok);
    assert!(err.contains("duplicate"), "{err}");
}

#[test]
fn serve_mock_batch_max_and_no_steal_flags() {
    if binary().is_none() {
        return;
    }
    // Default: per-member capability-derived caps, stealing on.
    let (out, err, ok) = run(&[
        "serve", "--mock", "--requests", "12", "--artifacts", "no-such-dir",
        "--devices", "gtx260,fermi",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("auto (per compute capability)"), "{out}");
    assert!(out.contains("stealing on"), "{out}");
    // Override pins the cap and --no-steal switches stealing off.
    let (out, err, ok) = run(&[
        "serve", "--mock", "--requests", "12", "--artifacts", "no-such-dir",
        "--devices", "gtx260,fermi", "--batch-max", "2", "--no-steal",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("batch_max 2"), "{out}");
    assert!(out.contains("stealing off"), "{out}");
}

#[test]
fn bench_gate_runs_against_committed_baseline() {
    if binary().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join("tilekit_cli_bench");
    std::fs::create_dir_all(&dir).unwrap();
    let pr = dir.join("BENCH_PR.json");
    let pr_s = pr.to_str().unwrap().to_string();
    // The committed baseline is what CI gates against; the smoke run
    // must produce a comparable report and write the artifact.
    let (out, err, ok) = run(&[
        "bench", "--out", &pr_s, "--baseline", "BENCH_BASELINE.json",
    ]);
    assert!(ok, "stderr: {err}\nstdout: {out}");
    assert!(out.contains("regression gate"), "{out}");
    assert!(out.contains("calibration"), "{out}");
    let written = std::fs::read_to_string(&pr).unwrap();
    assert!(written.contains("\"records\""), "{written}");
    assert!(written.contains("steal select"), "{written}");
    // --update-baseline writes a non-provisional baseline.
    let base = dir.join("BENCH_BASE.json");
    let base_s = base.to_str().unwrap().to_string();
    let (_, err, ok) = run(&["bench", "--update-baseline", "--baseline", &base_s]);
    assert!(ok, "stderr: {err}");
    let written = std::fs::read_to_string(&base).unwrap();
    assert!(written.contains("\"provisional\": false"), "{written}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_serving_quick_reports_and_gates() {
    if binary().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join("tilekit_cli_bench_serving");
    std::fs::create_dir_all(&dir).unwrap();
    let pr = dir.join("BENCH_PR.json");
    let pr_s = pr.to_str().unwrap().to_string();
    // The serving benchmark appends its records to the same report the
    // micro suite writes, so they ride the committed baseline's gate
    // (new records are noted, never failed).
    let (out, err, ok) = run(&[
        "bench", "--serving", "--quick",
        "--out", &pr_s, "--baseline", "BENCH_BASELINE.json",
    ]);
    assert!(ok, "stderr: {err}\nstdout: {out}");
    assert!(out.contains("serving benchmark (quick profile)"), "{out}");
    for rec in [
        "serving: submit us/op",
        "serving: submit p50",
        "serving: submit p99",
        "serving: open-loop e2e p99",
        "serving: open-loop us/req",
    ] {
        assert!(out.contains(rec), "bench output missing '{rec}':\n{out}");
    }
    // The sampled submit-path breakdown surfaces in the bench report.
    assert!(out.contains("submit path (n="), "{out}");
    assert!(out.contains("regression gate"), "{out}");
    let written = std::fs::read_to_string(&pr).unwrap();
    assert!(written.contains("serving: submit us/op"), "{written}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_wire_quick_reports_and_gates() {
    if binary().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join("tilekit_cli_bench_wire");
    std::fs::create_dir_all(&dir).unwrap();
    let pr = dir.join("BENCH_PR.json");
    let pr_s = pr.to_str().unwrap().to_string();
    // The wire benchmark appends per-protocol-version records to the
    // same gated report: us/req (wall-clock) and bytes/req
    // (deterministic transport counters).
    let (out, err, ok) = run(&[
        "bench", "--wire", "--quick",
        "--out", &pr_s, "--baseline", "BENCH_BASELINE.json",
    ]);
    assert!(ok, "stderr: {err}\nstdout: {out}");
    assert!(out.contains("wire loopback benchmark (quick profile)"), "{out}");
    for rec in [
        "wire: v1 submit+wait us/req",
        "wire: v2 submit+wait us/req",
        "wire: v1 bytes/req",
        "wire: v2 bytes/req",
    ] {
        assert!(out.contains(rec), "bench output missing '{rec}':\n{out}");
    }
    assert!(out.contains("regression gate"), "{out}");
    let written = std::fs::read_to_string(&pr).unwrap();
    assert!(written.contains("wire: v2 bytes/req"), "{written}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_quick_without_serving_is_rejected() {
    if binary().is_none() {
        return;
    }
    let (_, err, ok) = run(&["bench", "--quick"]);
    assert!(!ok);
    assert!(err.contains("--serving"), "{err}");
}

#[test]
fn serve_mock_reports_submit_path_breakdown() {
    if binary().is_none() {
        return;
    }
    // Default breakdown sampling is 1-in-16, so 32 requests guarantee
    // at least two sampled submits and the summary line prints.
    let (out, err, ok) = run(&["serve", "--mock", "--requests", "32"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("completed 32/32"), "{out}");
    assert!(out.contains("submit path (n="), "{out}");
    for stage in ["snapshot", "schedule", "admit"] {
        assert!(out.contains(stage), "breakdown missing '{stage}' stage:\n{out}");
    }
}

#[test]
fn fleet_topology_prints_epoch_stamped_snapshot() {
    if binary().is_none() {
        return;
    }
    let (out, err, ok) = run(&["fleet", "topology", "--requests", "12"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("topology epoch"), "{out}");
    assert!(out.contains("gtx260") && out.contains("fermi"), "{out}");
    assert!(out.contains("completed 12/12"), "{out}");
    // Action and flag validation fail loudly.
    let (_, err, ok) = run(&["fleet", "explode"]);
    assert!(!ok);
    assert!(err.contains("unknown fleet action 'explode'"), "{err}");
    let (_, err, ok) = run(&["fleet"]);
    assert!(!ok);
    assert!(err.contains("usage: tilekit fleet"), "{err}");
    let (out, _, ok) = run(&["fleet", "--help"]);
    assert!(ok);
    for needle in ["topology", "drain", "retune", "--devices", "--device"] {
        assert!(out.contains(needle), "fleet --help missing '{needle}':\n{out}");
    }
}

#[test]
fn fleet_drain_and_retune_drive_the_control_plane() {
    if binary().is_none() {
        return;
    }
    let (out, err, ok) = run(&["fleet", "drain", "--device", "fermi", "--requests", "12"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("drain('fermi')"), "{out}");
    assert!(out.contains("yes"), "draining column must flip: {out}");
    assert!(out.contains("completed 12/12"), "{out}");
    let (out, err, ok) = run(&["fleet", "retune", "--device", "gtx260", "--requests", "12"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("retune('gtx260')"), "{out}");
    assert!(out.contains("completed 12/12"), "{out}");
    // A target outside the fleet is rejected.
    let (_, err, ok) = run(&["fleet", "drain", "--device", "ghost"]);
    assert!(!ok);
    assert!(err.contains("not in the fleet"), "{err}");
}

#[test]
fn serve_watch_db_flag_validates_and_runs() {
    if binary().is_none() {
        return;
    }
    let dir = std::env::temp_dir().join("tilekit_cli_watch_db");
    std::fs::create_dir_all(&dir).unwrap();
    let db = dir.join("tuning_cache.json");
    let db_s = db.to_str().unwrap().to_string();
    // No device fleet -> the daemon has nothing to retune.
    let (_, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir", "--watch-db", &db_s,
    ]);
    assert!(!ok);
    assert!(err.contains("--watch-db"), "{err}");
    // A fixed tile pins every member: nothing tuned to watch either.
    let (_, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir", "--devices", "gtx260,fermi",
        "--tile", "16x8", "--watch-db", &db_s,
    ]);
    assert!(!ok);
    assert!(err.contains("--watch-db"), "{err}");
    // A bad poll interval is rejected.
    let (_, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir", "--devices", "gtx260,fermi",
        "--watch-db", &db_s, "--watch-poll-ms", "0",
    ]);
    assert!(!ok);
    assert!(err.contains("--watch-poll-ms"), "{err}");
    // The happy path: the daemon runs alongside the demo (the missing db
    // file is fine — it waits for one to appear) and reports activity.
    let (out, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir", "--devices", "gtx260,fermi",
        "--requests", "8", "--watch-db", &db_s,
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("watching tuning db"), "{out}");
    assert!(out.contains("retune daemon:"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tune_src_flag_retargets_the_tuned_shape() {
    if binary().is_none() {
        return;
    }
    // The shape a `serve --watch-db` fleet serves: tune must be able to
    // key cache entries at it, or refreshes never match the daemon.
    let (out, err, ok) = run(&[
        "tune", "--devices", "gtx260,fermi", "--scale", "2", "--src", "64x64",
        "--tiles", "16x8,32x16",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("64x64"), "tuned shape must be reported: {out}");
    assert!(out.contains("gtx260") && out.contains("fermi"), "{out}");
    let (_, err, ok) = run(&["tune", "--src", "banana"]);
    assert!(!ok);
    assert!(err.contains("--src"), "{err}");
    let (_, err, ok) = run(&["tune", "--src", "0x64"]);
    assert!(!ok);
    assert!(err.contains("--src"), "{err}");
}

#[test]
fn serve_listen_flag_validation() {
    if binary().is_none() {
        return;
    }
    // Bad addresses are rejected before any socket is opened.
    for bad in ["noport", "host:", ":7441", "host:notaport", "host:99999", "unix:"] {
        let (_, err, ok) = run(&[
            "serve", "--mock", "--artifacts", "no-such-dir", "--listen", bad,
        ]);
        assert!(!ok, "--listen {bad} must fail");
        assert!(err.contains("--listen"), "--listen {bad}: {err}");
    }
    // A negative lifetime is rejected too.
    let (_, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir",
        "--listen", "127.0.0.1:0", "--listen-for-ms", "-5",
    ]);
    assert!(!ok);
    assert!(err.contains("--listen-for-ms"), "{err}");
}

#[test]
fn serve_listen_loopback_smoke() {
    use std::io::BufRead;
    use tilekit::net::{FleetClient, ListenAddr};

    if binary().is_none() {
        return;
    }
    // Spawn a mock fleet on an ephemeral port and read the bound
    // address off its stdout.
    let bin = binary().unwrap();
    let mut child = Command::new(bin)
        .args([
            "serve", "--mock", "--artifacts", "no-such-dir",
            "--devices", "gtx260,fermi",
            "--listen", "127.0.0.1:0", "--listen-for-ms", "30000",
        ])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn tilekit serve --listen");
    let stdout = child.stdout.take().unwrap();
    let mut reader = std::io::BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read server stdout");
        assert!(n > 0, "server exited before printing the bound address");
        if let Some(rest) = line.strip_prefix("listening on ") {
            let token = rest.split_whitespace().next().unwrap().to_string();
            break ListenAddr::parse(&token).expect("printed address parses");
        }
    };

    // One client round trip: a submit and a topology fetch.
    let client = FleetClient::connect(&addr).expect("loopback connect");
    let img = tilekit::image::generate::test_scene(64, 64, 5);
    let req = tilekit::coordinator::Request::new(tilekit::image::Interpolator::Bilinear, img, 2);
    let out = client
        .submit(&req)
        .expect("remote submit")
        .wait()
        .expect("remote wait");
    assert_eq!(out.width(), 128);
    assert_eq!(out.height(), 128);
    let topo = client.topology().expect("remote topology");
    assert_eq!(topo.members.len(), 2, "{topo:?}");
    drop(client);

    child.kill().ok();
    child.wait().ok();
}

/// Spawn `tilekit serve --listen 127.0.0.1:0 <extra>` and return the
/// child plus the bound address token read off its stdout.
fn spawn_listener(extra: &[&str]) -> (std::process::Child, String) {
    use std::io::BufRead;
    let bin = binary().unwrap();
    let mut args = vec![
        "serve", "--mock", "--artifacts", "no-such-dir",
        "--devices", "gtx260,fermi",
        "--listen", "127.0.0.1:0", "--listen-for-ms", "30000",
    ];
    args.extend_from_slice(extra);
    let mut child = Command::new(bin)
        .args(&args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn tilekit serve --listen");
    let stdout = child.stdout.take().unwrap();
    let mut reader = std::io::BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read server stdout");
        assert!(n > 0, "server exited before printing the bound address");
        if let Some(rest) = line.strip_prefix("listening on ") {
            break rest.split_whitespace().next().unwrap().to_string();
        }
    };
    (child, addr)
}

#[test]
fn serve_autoscale_flag_validation() {
    if binary().is_none() {
        return;
    }
    // A standby pool without the loop is a configuration mistake.
    let (_, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir",
        "--devices", "gtx260,fermi", "--standby-devices", "8800gtx",
    ]);
    assert!(!ok);
    assert!(err.contains("--standby-devices needs --autoscale"), "{err}");
    // The loop needs a device fleet to scale...
    let (_, err, ok) = run(&["serve", "--mock", "--artifacts", "no-such-dir", "--autoscale"]);
    assert!(!ok);
    assert!(err.contains("needs a device fleet"), "{err}");
    // ...and a pool to scale with.
    let (_, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir",
        "--devices", "gtx260,fermi", "--autoscale",
    ]);
    assert!(!ok);
    assert!(err.contains("needs a standby pool"), "{err}");
    // A standby id already serving, or listed twice, fails loudly.
    let (_, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir",
        "--devices", "gtx260,fermi", "--autoscale", "--standby-devices", "fermi",
    ]);
    assert!(!ok);
    assert!(err.contains("already a fleet member"), "{err}");
    let (_, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir",
        "--devices", "gtx260,fermi", "--autoscale",
        "--standby-devices", "8800gtx,8800gtx",
    ]);
    assert!(!ok);
    assert!(err.contains("twice"), "{err}");
}

#[test]
fn serve_autoscale_demo_reports_the_loop() {
    if binary().is_none() {
        return;
    }
    let (out, err, ok) = run(&[
        "serve", "--mock", "--artifacts", "no-such-dir",
        "--devices", "gtx260,fermi", "--autoscale", "--standby-devices", "8800gtx",
        "--requests", "16",
    ]);
    assert!(ok, "stderr: {err}");
    // The flag arms the loop (never parked) over a min..=max band of
    // fleet size..fleet size + pool.
    assert!(out.contains("autoscaler enabled"), "{out}");
    assert!(out.contains("members 2..=3"), "{out}");
    assert!(out.contains("completed 16/16"), "{out}");
}

#[test]
fn fleet_autoscaler_demo_status_enable_set() {
    if binary().is_none() {
        return;
    }
    // Default action is `status`; the demo loop starts parked per the
    // config table, with the default 8800gtx standby pool.
    let (out, err, ok) = run(&["fleet", "autoscaler"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("demo fleet: 2 member(s) + 1 standby"), "{out}");
    assert!(out.contains("before: autoscaler disabled"), "{out}");
    assert!(out.contains("standby_free=1"), "{out}");
    // `enable` arms it and echoes the post-update state.
    let (out, err, ok) = run(&["fleet", "autoscaler", "enable"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("after:  autoscaler enabled"), "{out}");
    // `set` retunes the band; --cooldown-ms converts against the
    // config's poll (default 100ms -> 3 ticks).
    let (out, err, ok) = run(&[
        "fleet", "autoscaler", "set", "--low", "2", "--high", "9", "--cooldown-ms", "300",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("low=2 high=9"), "{out}");
    assert!(out.contains("cooldown=3"), "{out}");
    // Validation: an empty `set`, an unknown action, a pool id that
    // already serves.
    let (_, err, ok) = run(&["fleet", "autoscaler", "set"]);
    assert!(!ok);
    assert!(err.contains("set needs at least one"), "{err}");
    let (_, err, ok) = run(&["fleet", "autoscaler", "explode"]);
    assert!(!ok);
    assert!(err.contains("unknown autoscaler action 'explode'"), "{err}");
    let (_, err, ok) = run(&["fleet", "autoscaler", "status", "--standby-devices", "fermi"]);
    assert!(!ok);
    assert!(err.contains("already a fleet member"), "{err}");
}

#[test]
fn fleet_autoscaler_over_the_wire() {
    if binary().is_none() {
        return;
    }
    // A listener with the loop armed answers status/set/disable.
    let (mut child, addr) = spawn_listener(&["--autoscale", "--standby-devices", "8800gtx"]);
    let (out, err, ok) = run(&["fleet", "--connect", &addr, "autoscaler"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("autoscaler enabled"), "{out}");
    assert!(out.contains("members 2..=3"), "{out}");
    let (out, err, ok) = run(&["fleet", "--connect", &addr, "autoscaler", "set", "--high", "12"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("high=12"), "{out}");
    let (out, err, ok) = run(&["fleet", "--connect", &addr, "autoscaler", "disable"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("autoscaler disabled"), "{out}");
    child.kill().ok();
    child.wait().ok();

    // A listener WITHOUT the loop reports the typed not-found error.
    let (mut child, addr) = spawn_listener(&[]);
    let (_, err, ok) = run(&["fleet", "--connect", &addr, "autoscaler"]);
    assert!(!ok);
    assert!(err.contains("no autoscaler running"), "{err}");
    child.kill().ok();
    child.wait().ok();
}
