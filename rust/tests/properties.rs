//! Property-based tests over the library's invariants, using the
//! in-tree [`tilekit::prop`] mini-framework (see DESIGN.md §2 for why
//! proptest itself is not available). Each `forall` draws seeded random
//! cases and reports the reproducing seed on failure.

use std::sync::Arc;
use std::time::Duration;
use tilekit::codec::json::Json;
use tilekit::config::ServingConfig;
use tilekit::coordinator::{BlockWithTimeout, FleetBuilder, Request, TilePolicy};
use tilekit::device::{builtin_devices, ComputeCapability};
use tilekit::image::{generate, Interpolator};
use tilekit::prop::{forall, prop_assert, prop_close};
use tilekit::runtime::{Manifest, MockEngine};
use tilekit::sim::{simulate, Launch};
use tilekit::tiling::occupancy::{occupancy, KernelResources};
use tilekit::tiling::TileDim;

const CCS: [ComputeCapability; 4] = [
    ComputeCapability::CC_1_0,
    ComputeCapability::CC_1_1,
    ComputeCapability::CC_1_2,
    ComputeCapability::CC_1_3,
];

#[test]
fn prop_occupancy_bounds_and_monotonicity() {
    forall("occupancy bounds", 500, |g| {
        let cc = *g.choose(&CCS);
        let tile = TileDim::new(g.pow2(0, 9), g.pow2(0, 9));
        let res = KernelResources {
            regs_per_thread: g.u32(1, 64),
            smem_per_block: g.u32(0, 20 * 1024),
        };
        let o = occupancy(tile, &res, &cc);
        prop_assert(
            o.threads_per_sm <= cc.max_threads_per_sm,
            format!("threads {} > cap", o.threads_per_sm),
        )?;
        prop_assert(o.warps_per_sm <= cc.max_warps_per_sm, "warps over cap")?;
        prop_assert(o.blocks_per_sm <= cc.max_blocks_per_sm, "blocks over cap")?;
        prop_assert((0.0..=1.0 + 1e-12).contains(&o.ratio), "ratio out of range")?;
        // More registers per thread can never raise residency.
        let hungrier = KernelResources {
            regs_per_thread: res.regs_per_thread + g.u32(1, 32),
            smem_per_block: res.smem_per_block,
        };
        let o2 = occupancy(tile, &hungrier, &cc);
        prop_assert(
            o2.blocks_per_sm <= o.blocks_per_sm,
            "register monotonicity violated",
        )
    });
}

#[test]
fn prop_tile_grid_covers_output() {
    forall("grid covers output", 500, |g| {
        let tile = TileDim::new(g.pow2(0, 9), g.pow2(0, 9));
        let w = g.u32(1, 4096);
        let h = g.u32(1, 4096);
        let (gx, gy) = tile.grid_for(w, h);
        prop_assert(gx as u64 * tile.x as u64 >= w as u64, "x not covered")?;
        prop_assert(gy as u64 * tile.y as u64 >= h as u64, "y not covered")?;
        // minimality
        prop_assert(
            (gx as u64 - 1) * (tile.x as u64) < (w as u64),
            "gx not minimal",
        )?;
        prop_assert(
            (gy as u64 - 1) * (tile.y as u64) < (h as u64),
            "gy not minimal",
        )
    });
}

#[test]
fn prop_simulator_sanity() {
    let devices = builtin_devices();
    forall("simulator sanity", 300, |g| {
        let dev = g.choose(&devices).clone();
        let tile = TileDim::new(g.pow2(2, 5), g.pow2(2, 5));
        let scale = *g.choose(&[1u32, 2, 3, 4, 6, 8, 10]);
        let kernel = *g.choose(&[
            Interpolator::Nearest,
            Interpolator::Bilinear,
            Interpolator::Bicubic,
        ]);
        let src = g.pow2(5, 8); // 32..256
        let l = Launch {
            kernel,
            tile,
            src_w: src,
            src_h: src,
            scale,
        };
        let r = simulate(&l, &dev, None);
        // A tile can be dimensionally valid yet unlaunchable when one
        // block over-subscribes a resource (e.g. bicubic's 24 regs/thread
        // at 512 threads needs 12K registers > cc1.0's 8K).
        let res = tilekit::sim::KernelCost::of(kernel).resources;
        let occ = occupancy(tile, &res, &dev.cc);
        if !tile.is_valid(&dev.cc) || occ.blocks_per_sm == 0 {
            return prop_assert(r.ms.is_infinite(), "unlaunchable tile must be inf");
        }
        prop_assert(r.ms.is_finite() && r.ms > 0.0, format!("ms={}", r.ms))?;
        // More SMs -> never slower.
        let mut bigger = dev.clone();
        bigger.sm_count = dev.sm_count * 2;
        let r2 = simulate(&l, &bigger, None);
        prop_assert(
            r2.ms <= r.ms + 1e-9,
            format!("more SMs slower: {} vs {}", r2.ms, r.ms),
        )?;
        // Rounds account for every block.
        let blocks_covered = r.rounds as u128 * r.occupancy.blocks_per_sm as u128;
        prop_assert(
            blocks_covered >= r.total_blocks as u128,
            "rounds don't cover grid",
        )
    });
}

#[test]
fn prop_json_round_trip() {
    fn gen_json(g: &mut tilekit::prop::Gen, depth: u32) -> Json {
        match if depth == 0 { g.usize(0, 3) } else { g.usize(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(
                (0..g.usize(0, 12))
                    .map(|_| *g.choose(&['a', 'ß', '"', '\\', '\n', '😀', ' ', 'z']))
                    .collect(),
            ),
            4 => Json::Arr((0..g.usize(0, 4)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => {
                let mut obj = Json::obj();
                for i in 0..g.usize(0, 4) {
                    obj = obj.set(&format!("k{i}"), gen_json(g, depth - 1));
                }
                obj
            }
        }
    }
    forall("json round trip", 300, |g| {
        let v = gen_json(g, 3);
        let compact = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        prop_assert(compact == v, "compact round-trip differs")?;
        let pretty = Json::parse(&v.pretty()).map_err(|e| e.to_string())?;
        prop_assert(pretty == v, "pretty round-trip differs")
    });
}

#[test]
fn prop_interpolators_preserve_affine_and_bounds() {
    forall("interp bounds", 60, |g| {
        let w = g.usize(2, 24);
        let h = g.usize(2, 24);
        let scale = g.u32(1, 6);
        let img = generate::test_scene(w, h, g.u32(0, 1000) as u64);
        // bilinear and nearest stay within the input's range
        for kernel in [Interpolator::Nearest, Interpolator::Bilinear] {
            let out = kernel.run(&img, scale);
            for y in 0..out.height() {
                for x in 0..out.width() {
                    let v = out.get(x, y);
                    prop_assert(
                        (-1e-6..=1.0 + 1e-6).contains(&(v as f64)),
                        format!("{:?} out of range: {v}", kernel),
                    )?;
                }
            }
        }
        // at source sample points bilinear reproduces the source
        let out = Interpolator::Bilinear.run(&img, scale);
        let s = scale as usize;
        for y in 0..h {
            for x in 0..w {
                prop_close(
                    out.get(x * s, y * s) as f64,
                    img.get(x, y) as f64,
                    1e-5,
                    "sample point",
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tuning_outcome_json_round_trip() {
    // TuningOutcome → JSON text → TuningOutcome is lossless for any
    // finite tuning data (f64 times survive exactly: the JSON writer
    // emits shortest round-trippable representations).
    use tilekit::autotuner::{portable_over, DeviceTuning, TunedPoint, TuningOutcome};

    forall("tuning outcome round trip", 200, |g| {
        let n_dev = g.usize(1, 4);
        let n_tiles = g.usize(1, 8);
        let tiles: Vec<TileDim> = (0..n_tiles)
            .map(|_| TileDim::new(g.pow2(0, 6), g.pow2(0, 6)))
            .collect();
        let mut per_device = Vec::new();
        for d in 0..n_dev {
            let points: Vec<TunedPoint> = tiles
                .iter()
                .map(|&tile| TunedPoint {
                    tile,
                    ms: g.f64(1e-3, 500.0),
                })
                .collect();
            let dt = DeviceTuning::from_points(format!("dev{d}"), points, g.u32(1, 200) as u64)
                .expect("finite points always yield a best");
            per_device.push(dt);
        }
        let kernel = *g.choose(&[
            Interpolator::Nearest,
            Interpolator::Bilinear,
            Interpolator::Bicubic,
        ]);
        let portable = portable_over(&per_device);
        let outcome = TuningOutcome {
            kernel,
            scale: g.u32(1, 16),
            src: (g.u32(1, 2048), g.u32(1, 2048)),
            strategy: g
                .choose(&["exhaustive", "descent", "cached+exhaustive", "cached+descent"])
                .to_string(),
            evaluations: g.u32(0, 50_000) as u64,
            per_device,
            portable,
        };
        for text in [outcome.to_json().to_string(), outcome.to_json().pretty()] {
            let parsed = Json::parse(&text).map_err(|e| e.to_string())?;
            let back = TuningOutcome::from_json(&parsed).map_err(|e| e.to_string())?;
            prop_assert(back == outcome, format!("round trip differs via {text}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_coordinator_conserves_requests() {
    // Every admitted request is answered exactly once (completed or
    // failed), across random load patterns and failure injection.
    let manifest = Manifest::parse(
        r#"{
          "version": 1,
          "artifacts": [
            {"name": "bl2", "kernel": "bilinear", "src": [16, 16],
             "scale": 2, "batch": 4, "tile": [4, 32], "path": "x"},
            {"name": "bl4", "kernel": "bilinear", "src": [16, 16],
             "scale": 4, "batch": 2, "tile": [4, 32], "path": "x"},
            {"name": "nn2", "kernel": "nearest", "src": [16, 16],
             "scale": 2, "batch": 8, "tile": [4, 32], "path": "x"}
          ]
        }"#,
        std::path::PathBuf::from("."),
    )
    .unwrap();

    forall("request conservation", 15, |g| {
        let fail_every = *g.choose(&[0u64, 2, 3]);
        let cfg = ServingConfig {
            workers: g.usize(1, 4),
            batch_max: Some(g.usize(1, 6)),
            batch_deadline_ms: 0.5,
            queue_cap: 128,
            ..ServingConfig::default()
        };
        let backend = Arc::new(MockEngine::failing_every(fail_every));
        let svc = FleetBuilder::new(&cfg, &manifest)
            .backend(backend, TilePolicy::PortableFallback)
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .map_err(|e| format!("service start: {e}"))?;
        let n = g.usize(1, 60);
        let img = generate::test_scene(16, 16, 3);
        let mut tickets = Vec::new();
        for _ in 0..n {
            let (kernel, scale) = *g.choose(&[
                (Interpolator::Bilinear, 2u32),
                (Interpolator::Bilinear, 4),
                (Interpolator::Nearest, 2),
            ]);
            match svc.submit(Request::new(kernel, img.clone(), scale)) {
                Ok(t) => tickets.push(t),
                Err(e) => return Err(format!("unexpected submit error: {e}")),
            }
        }
        let mut answered = 0usize;
        for t in tickets {
            match t.wait_timeout(Duration::from_secs(5)) {
                Ok(Some(_)) => answered += 1,
                Err(_) => answered += 1, // failed is still answered
                Ok(None) => return Err("request timed out".into()),
            }
        }
        let stats = svc.shutdown();
        prop_assert(answered == n, format!("answered {answered} of {n}"))?;
        prop_assert(
            stats.completed.get() + stats.failed.get() == n as u64,
            format!(
                "stats disagree: {} + {} != {n}",
                stats.completed.get(),
                stats.failed.get()
            ),
        )
    });
}

#[test]
fn prop_work_stealing_selection_invariants() {
    // The pure steal-selection policy (applied under the queue lock by
    // Receiver::steal_by) must (1) take only requests the thief can
    // route, (2) never take cancelled or deadline-expired requests,
    // (3) respect priority ordering — an Interactive request moves only
    // if every stealable Batch request moves too — and (4) leave the
    // victim at least half its backlog.
    use std::collections::VecDeque;
    use std::sync::mpsc;
    use std::time::Instant;
    use tilekit::coordinator::stealing::select_steals;
    use tilekit::coordinator::{Priority, RequestKey, ResizeRequest};

    forall("steal selection invariants", 300, |g| {
        let now = Instant::now();
        let n = g.usize(0, 12);
        let img = generate::gradient(8, 8);
        let mut queue: VecDeque<ResizeRequest> = VecDeque::new();
        for i in 0..n {
            let scale = *g.choose(&[2u32, 4]);
            // Selection never replies, so the receiver can drop.
            let (tx, _rx) = mpsc::channel();
            let mut r = ResizeRequest::bare(
                i as u64,
                RequestKey::of(Interpolator::Bilinear, &img, scale),
                img.clone(),
                tx,
            );
            if g.bool() {
                r.priority = Priority::Batch;
            }
            if g.u32(0, 9) == 0 {
                r.cancel.cancel();
            }
            if g.u32(0, 9) == 0 {
                r.deadline = Some(now - Duration::from_millis(1));
            }
            queue.push_back(r);
        }
        let max = g.usize(0, 10);
        // The thief only routes scale-2 work.
        let supports = |k: &RequestKey| k.scale == 2;
        let picked = select_steals(&queue, supports, now, max);

        // Indices valid and unique.
        let mut uniq = picked.clone();
        uniq.sort_unstable();
        uniq.dedup();
        prop_assert(uniq.len() == picked.len(), "duplicate indices")?;
        prop_assert(
            picked.iter().all(|&i| i < queue.len()),
            "index out of range",
        )?;
        // (4) budget: at most max, at most half the queue.
        prop_assert(
            picked.len() <= max.min(queue.len() / 2),
            format!("stole {} of {} (max {max})", picked.len(), queue.len()),
        )?;
        let stealable = |r: &ResizeRequest| {
            !r.is_cancelled() && !r.is_expired(now) && supports(&r.key)
        };
        for &i in &picked {
            let r = &queue[i];
            // (1) + (2)
            prop_assert(supports(&r.key), format!("unroutable steal at {i}"))?;
            prop_assert(!r.is_cancelled(), format!("stole cancelled at {i}"))?;
            prop_assert(!r.is_expired(now), format!("stole expired at {i}"))?;
        }
        // (3) priority ordering: if any Interactive request was picked,
        // every stealable Batch request must have been picked too.
        let picked_interactive = picked
            .iter()
            .any(|&i| queue[i].priority == Priority::Interactive);
        if picked_interactive {
            for (i, r) in queue.iter().enumerate() {
                if r.priority == Priority::Batch && stealable(r) {
                    prop_assert(
                        picked.contains(&i),
                        format!("interactive stolen while batch {i} left behind"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_serving_stats_merge_is_associative() {
    // Fleet aggregation folds per-member stats (including the new
    // steal/shed/infeasible counters) in arbitrary order; merge_from
    // must be associative so (a+b)+c == a+(b+c) for every counter and
    // histogram count.
    use tilekit::coordinator::{Priority, ServingStats};

    fn random_stats(g: &mut tilekit::prop::Gen) -> ServingStats {
        let s = ServingStats::new();
        s.admitted.add(g.usize(0, 50) as u64);
        s.rejected.add(g.usize(0, 10) as u64);
        s.completed.add(g.usize(0, 50) as u64);
        s.failed.add(g.usize(0, 5) as u64);
        s.shed.add(g.usize(0, 5) as u64);
        s.cancelled.add(g.usize(0, 5) as u64);
        s.steals.add(g.usize(0, 20) as u64);
        s.stolen.add(g.usize(0, 20) as u64);
        s.infeasible.add(g.usize(0, 5) as u64);
        s.retunes.add(g.usize(0, 3) as u64);
        s.scale_ups.add(g.usize(0, 3) as u64);
        s.scale_downs.add(g.usize(0, 3) as u64);
        s.migrated_batches.add(g.usize(0, 5) as u64);
        s.batches.add(g.usize(0, 20) as u64);
        s.batched.add(g.usize(0, 60) as u64);
        for _ in 0..g.usize(0, 4) {
            s.record_latency(
                *g.choose(&[Priority::Interactive, Priority::Batch]),
                Duration::from_micros(g.usize(1, 5000) as u64),
            );
        }
        s.record_sim_cost_ms(g.f64(0.0, 2.0));
        s
    }

    fn merged(x: &ServingStats, y: &ServingStats) -> ServingStats {
        let out = ServingStats::new();
        out.merge_from(x);
        out.merge_from(y);
        out
    }

    forall("merge_from associativity", 100, |g| {
        let a = random_stats(g);
        let b = random_stats(g);
        let c = random_stats(g);
        let left = merged(&merged(&a, &b), &c);
        let right = merged(&a, &merged(&b, &c));
        let counters = |s: &ServingStats| {
            vec![
                s.admitted.get(),
                s.rejected.get(),
                s.completed.get(),
                s.failed.get(),
                s.shed.get(),
                s.cancelled.get(),
                s.steals.get(),
                s.stolen.get(),
                s.infeasible.get(),
                s.retunes.get(),
                s.scale_ups.get(),
                s.scale_downs.get(),
                s.migrated_batches.get(),
                s.batches.get(),
                s.batched.get(),
                s.sim_cost_ns.get(),
                s.unpriced.get(),
                s.latency.count(),
                s.latency_by_class[0].count(),
                s.latency_by_class[1].count(),
                s.inflight(),
            ]
        };
        prop_assert(
            counters(&left) == counters(&right),
            format!("{:?} != {:?}", counters(&left), counters(&right)),
        )
    });
}

// ------------------------------------------------- net wire protocol --

/// Random JSON payloads for frame round-trips (depth-bounded).
fn gen_payload(g: &mut tilekit::prop::Gen, depth: u32) -> Json {
    match if depth == 0 { g.usize(0, 3) } else { g.usize(0, 5) } {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num((g.f64(-1e9, 1e9) * 1e3).round() / 1e3),
        3 => Json::Str(
            (0..g.usize(0, 10))
                .map(|_| *g.choose(&['a', '"', '\\', '\n', '{', '}', 'ß', '😀']))
                .collect(),
        ),
        4 => Json::Arr(
            (0..g.usize(0, 3))
                .map(|_| gen_payload(g, depth - 1))
                .collect(),
        ),
        _ => {
            let mut obj = Json::obj();
            for i in 0..g.usize(0, 3) {
                obj = obj.set(&format!("k{i}"), gen_payload(g, depth - 1));
            }
            obj
        }
    }
}

#[test]
fn prop_net_request_frames_round_trip() {
    use tilekit::net::{RequestFrame, Verb};

    forall("request frame round trip", 400, |g| {
        let verb = *g.choose(&Verb::ALL);
        // Wire ids travel as JSON numbers: exact up to 2^53.
        let id = g.usize(0, 1 << 53) as u64;
        let frame = RequestFrame::new(id, verb, gen_payload(g, 3));
        let line = frame.to_line();
        prop_assert(line.ends_with('\n'), "frame line must be newline-terminated")?;
        prop_assert(
            !line[..line.len() - 1].contains('\n'),
            "frame body must be a single line (embedded newlines escaped)",
        )?;
        let back = RequestFrame::parse(&line).map_err(|e| e.to_string())?;
        prop_assert(back == frame, format!("round trip differs via {line}"))
    });
}

#[test]
fn prop_net_response_frames_round_trip() {
    use tilekit::net::{ResponseFrame, WireError, WireErrorKind};

    forall("response frame round trip", 400, |g| {
        let id = g.usize(0, 1 << 53) as u64;
        let frame = if g.bool() {
            ResponseFrame::ok(id, gen_payload(g, 3))
        } else {
            let kind = *g.choose(&WireErrorKind::ALL);
            let msg: String = (0..g.usize(0, 16))
                .map(|_| *g.choose(&['e', ' ', '"', '\\', 'ø', ':', '0']))
                .collect();
            ResponseFrame::err(id, WireError::new(kind, msg))
        };
        let line = frame.to_line();
        prop_assert(line.ends_with('\n'), "newline-terminated")?;
        let back = ResponseFrame::parse(&line).map_err(|e| e.to_string())?;
        prop_assert(back == frame, format!("round trip differs via {line}"))
    });
}

#[test]
fn prop_net_submit_payload_round_trips_exactly() {
    use tilekit::coordinator::Priority;
    use tilekit::net::protocol::{decode_submit, encode_submit};

    forall("submit payload round trip", 150, |g| {
        let w = g.usize(1, 24);
        let h = g.usize(1, 24);
        let img = generate::test_scene(w, h, g.u32(0, 10_000) as u64);
        let kernel = *g.choose(&[
            Interpolator::Nearest,
            Interpolator::Bilinear,
            Interpolator::Bicubic,
        ]);
        let mut req = Request::new(kernel, img, g.u32(1, 8));
        if g.bool() {
            req = req.priority(Priority::Batch);
        }
        if g.bool() {
            req = req.deadline(Duration::from_millis(g.usize(0, 60_000) as u64));
        }
        let back = decode_submit(&encode_submit(&req)).map_err(|e| e.to_string())?;
        prop_assert(back.kernel == req.kernel, "kernel differs")?;
        prop_assert(back.scale == req.scale, "scale differs")?;
        prop_assert(back.priority == req.priority, "priority differs")?;
        prop_assert(back.deadline == req.deadline, "deadline differs")?;
        prop_assert(
            back.image.width() == req.image.width()
                && back.image.height() == req.image.height(),
            "dims differ",
        )?;
        prop_assert(
            back.image.max_abs_diff(&req.image) == 0.0,
            "f32 pixels must survive the wire bit-exactly",
        )
    });
}

#[test]
fn prop_net_malformed_input_yields_typed_errors_not_panics() {
    use tilekit::net::{RequestFrame, ResponseFrame, Verb};

    forall("malformed frames", 600, |g| {
        // Arbitrary garbage: parse must return, never panic.
        let garbage: String = (0..g.usize(0, 40))
            .map(|_| {
                *g.choose(&[
                    '{', '}', '[', ']', '"', ':', ',', 'v', '1', '\\', 'n', ' ', '\u{7}', 'ß',
                ])
            })
            .collect();
        let _ = RequestFrame::parse(&garbage);
        let _ = ResponseFrame::parse(&garbage);

        // A valid frame truncated at a random byte boundary must parse
        // as a typed Malformed error or (rarely) still be valid JSON —
        // but never panic and never mis-parse into a *different* frame.
        let frame = RequestFrame::new(g.usize(0, 1 << 30) as u64, *g.choose(&Verb::ALL), {
            let mut o = Json::obj();
            for i in 0..g.usize(0, 3) {
                o = o.set(&format!("f{i}"), gen_payload(g, 1));
            }
            o
        });
        let line = frame.to_line();
        let mut cut = g.usize(0, line.len());
        while !line.is_char_boundary(cut) {
            cut -= 1;
        }
        match RequestFrame::parse(&line[..cut]) {
            Ok(parsed) => prop_assert(
                parsed == frame,
                format!("truncation at {cut} invented a different frame"),
            )?,
            Err(e) => {
                let msg = e.to_string();
                prop_assert(!msg.is_empty(), "typed error must describe itself")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_net_read_frame_line_enforces_caps_without_panicking() {
    use std::io::Cursor;
    use tilekit::net::protocol::read_frame_line;
    use tilekit::net::ProtocolError;

    forall("read_frame_line caps", 300, |g| {
        let max = g.usize(4, 64);
        let n = g.usize(0, 128);
        let body: String = (0..n).map(|_| *g.choose(&['x', 'y', '{', '"'])).collect();

        // Newline-terminated: under the cap it reads back exactly;
        // over the cap it is a typed Oversized error.
        let mut r = Cursor::new(format!("{body}\n"));
        match read_frame_line(&mut r, max) {
            Ok(Some(line)) if body.len() + 1 <= max => {
                prop_assert(line == format!("{body}\n"), "line mangled")?;
            }
            Ok(Some(_)) => return Err("oversized line was not rejected".into()),
            Err(ProtocolError::Oversized { limit }) => {
                prop_assert(limit == max, "wrong limit reported")?;
                prop_assert(body.len() + 1 > max, "under-cap line rejected")?;
            }
            other => return Err(format!("unexpected: {other:?}")),
        }

        // EOF mid-line is Truncated; EOF at a boundary is a clean None.
        let mut r = Cursor::new(body.clone());
        match read_frame_line(&mut r, usize::MAX) {
            Ok(None) => prop_assert(body.is_empty(), "clean EOF on a partial line")?,
            Err(ProtocolError::Truncated) => {
                prop_assert(!body.is_empty(), "Truncated on empty input")?;
            }
            other => return Err(format!("unexpected: {other:?}")),
        }
        Ok(())
    });
}

#[test]
fn prop_net_topology_and_stats_round_trip() {
    use tilekit::net::{TopologyDesc, WireStats};

    forall("topology/stats round trip", 200, |g| {
        let n = g.usize(0, 5);
        let members = (0..n)
            .map(|i| tilekit::net::protocol::MemberDesc {
                id: i as u64,
                label: format!("m{i}"),
                device: if g.bool() { Some(format!("dev{i}")) } else { None },
                tile: if g.bool() {
                    Some(TileDim::new(g.pow2(0, 6), g.pow2(0, 6)))
                } else {
                    None
                },
                batch_max: g.usize(1, 64) as u64,
                draining: g.bool(),
                admitted: g.usize(0, 1000) as u64,
                completed: g.usize(0, 1000) as u64,
                inflight: g.usize(0, 64) as u64,
            })
            .collect();
        let topo = TopologyDesc {
            epoch: g.usize(0, 1 << 40) as u64,
            members,
        };
        let back = TopologyDesc::from_json(&topo.to_json()).map_err(|e| e.to_string())?;
        prop_assert(back == topo, "topology round trip differs")?;

        let stats = WireStats {
            admitted: g.usize(0, 9999) as u64,
            rejected: g.usize(0, 99) as u64,
            completed: g.usize(0, 9999) as u64,
            failed: g.usize(0, 99) as u64,
            shed: g.usize(0, 99) as u64,
            cancelled: g.usize(0, 99) as u64,
            steals: g.usize(0, 99) as u64,
            stolen: g.usize(0, 99) as u64,
            infeasible: g.usize(0, 99) as u64,
            retunes: g.usize(0, 9) as u64,
            scale_ups: g.usize(0, 9) as u64,
            scale_downs: g.usize(0, 9) as u64,
            migrated_batches: g.usize(0, 99) as u64,
            batches: g.usize(0, 999) as u64,
            batched: g.usize(0, 9999) as u64,
            sim_cost_ns: g.usize(0, 1 << 40) as u64,
            unpriced: g.usize(0, 99) as u64,
            latency_count: g.usize(0, 9999) as u64,
            latency_mean_us: (g.f64(0.0, 1e6) * 1e3).round() / 1e3,
            latency_p50_us: (g.f64(0.0, 1e6) * 1e3).round() / 1e3,
            latency_p99_us: (g.f64(0.0, 1e6) * 1e3).round() / 1e3,
            submit_samples: g.usize(0, 999) as u64,
            submit_snapshot_p99_us: (g.f64(0.0, 1e4) * 1e3).round() / 1e3,
            submit_schedule_p99_us: (g.f64(0.0, 1e4) * 1e3).round() / 1e3,
            submit_admit_p99_us: (g.f64(0.0, 1e4) * 1e3).round() / 1e3,
        };
        let back = WireStats::from_json(&stats.to_json()).map_err(|e| e.to_string())?;
        prop_assert(back == stats, "stats round trip differs")
    });
}

// ------------------------------------------------ submit-plan routing --

#[test]
fn prop_reused_plan_buffer_routes_like_a_fresh_snapshot() {
    use tilekit::coordinator::{scheduler_by_name, DeviceSnapshot, RequestKey, Scheduler};

    // The lock-free submit path refills one reusable thread-local buffer
    // per request where the old path allocated a fresh `Vec` of member
    // snapshots. Property: for ANY member state and every named
    // scheduler, routing over the reused (clear + refill, dirty
    // capacity) buffer is indistinguishable from routing over a freshly
    // allocated snapshot — same pick, same ETA floor — and a pick always
    // lands on a supporting member, existing iff one does.
    forall("plan buffer = fresh snapshot", 300, |g| {
        let key = RequestKey {
            kernel: if g.bool() {
                Interpolator::Bilinear
            } else {
                Interpolator::Nearest
            },
            src: (64, 64),
            scale: 2,
        };
        let n = g.usize(1, 6);
        let fresh: Vec<DeviceSnapshot> = (0..n)
            .map(|index| DeviceSnapshot {
                index,
                device_id: format!("d{index}").into(),
                supports: g.bool(),
                inflight: g.usize(0, 64) as u64,
                cost_ms: if g.bool() { Some(g.f64(0.01, 8.0)) } else { None },
                slots: g.usize(1, 16) as u64,
                queued: g.usize(0, 32) as u64,
                stealable: g.bool(),
            })
            .collect();
        // The reused buffer arrives dirty from a previous, differently
        // sized submit — exactly what the thread-local sees.
        let mut reused: Vec<DeviceSnapshot> = (0..g.usize(0, 8))
            .map(|index| DeviceSnapshot {
                index,
                device_id: "stale".into(),
                supports: true,
                inflight: 999,
                cost_ms: Some(999.0),
                slots: 1,
                queued: 999,
                stealable: true,
            })
            .collect();
        reused.clear();
        reused.extend(fresh.iter().cloned());
        for name in ["round-robin", "least-loaded", "cost-eta"] {
            // Two fresh instances, so a stateful scheduler (round-robin's
            // rotation counter) sees both buffers from the same state.
            let a = scheduler_by_name(name).map_err(|e| e.to_string())?;
            let b = scheduler_by_name(name).map_err(|e| e.to_string())?;
            let pa = a.pick(&key, &fresh);
            let pb = b.pick(&key, &reused);
            prop_assert(pa == pb, format!("{name}: picks differ: {pa:?} vs {pb:?}"))?;
            let ea = a.min_eta_ms(&key, &fresh);
            let eb = b.min_eta_ms(&key, &reused);
            prop_assert(ea == eb, format!("{name}: ETA floors differ: {ea:?} vs {eb:?}"))?;
            prop_assert(
                pa.is_some() == fresh.iter().any(|s| s.supports),
                format!("{name}: pick exists iff a member supports the key"),
            )?;
            if let Some(i) = pa {
                prop_assert(
                    fresh[i].supports,
                    format!("{name}: picked a non-supporting member"),
                )?;
            }
        }
        Ok(())
    });
}

// ---------------------------------------------- autoscaler invariants --

#[test]
fn prop_autoscaler_policy_never_flaps_inside_the_band() {
    // A metric stream that oscillates arbitrarily WITHIN the watermark
    // band (and sheds nothing) must never change the target: every
    // decision is Hold and no cooldown is ever started.
    use tilekit::coordinator::autoscaler::policy::{
        decide, Decision, PolicyConfig, PolicyState, Sample,
    };

    forall("no flap inside the band", 300, |g| {
        let low = g.f64(0.0, 4.0);
        // Band at least 1.5 wide per member, so an integer queue depth
        // inside it always exists.
        let high = low + g.f64(1.5, 6.0);
        let cfg = PolicyConfig {
            low_queue: low,
            high_queue: high,
            high_p99_us: if g.bool() { g.usize(1, 1_000_000) as u64 } else { 0 },
            cooldown_ticks: g.usize(0, 8) as u32,
            min_members: 1,
            max_members: g.usize(2, 6),
        };
        let mut state = PolicyState::default();
        for tick in 0..40 {
            let members = g.usize(cfg.min_members, cfg.max_members);
            // queued/members stays in [low, high] (edges included).
            let q_min = (low * members as f64).ceil() as u64;
            let q_max = (high * members as f64).floor() as u64;
            let queued = g.usize(q_min as usize, q_max as usize) as u64;
            let s = Sample {
                members,
                queued,
                shed_delta: 0,
                infeasible_delta: 0,
                // The p99 trigger stays quiet (at or below threshold).
                interactive_p99_us: if cfg.high_p99_us > 0 {
                    g.usize(0, cfg.high_p99_us as usize) as u64
                } else {
                    g.usize(0, 1 << 30) as u64
                },
            };
            let d = decide(&cfg, &mut state, &s);
            prop_assert(
                d == Decision::Hold,
                format!("tick {tick}: in-band sample {s:?} produced {d:?}"),
            )?;
            prop_assert(state.cooldown == 0, "Hold must not start a cooldown")?;
        }
        Ok(())
    });
}

#[test]
fn prop_autoscaler_cooldown_spaces_actions_and_clamps() {
    // Under a fully adversarial metric stream: (1) two scale actions
    // are always >= cooldown_ticks + 1 ticks apart; (2) ScaleUp is
    // never issued at max_members, ScaleDown never at min_members.
    use tilekit::coordinator::autoscaler::policy::{
        decide, Decision, PolicyConfig, PolicyState, Sample,
    };

    forall("cooldown monotonicity", 300, |g| {
        let low = g.f64(0.0, 4.0);
        let cfg = PolicyConfig {
            low_queue: low,
            high_queue: low + g.f64(0.5, 8.0),
            high_p99_us: if g.bool() { g.usize(1, 100_000) as u64 } else { 0 },
            cooldown_ticks: g.usize(0, 6) as u32,
            min_members: g.usize(1, 3),
            max_members: g.usize(3, 8),
        };
        let mut state = PolicyState::default();
        let mut last_action: Option<(u64, Decision)> = None;
        for tick in 0..60u64 {
            let s = Sample {
                members: g.usize(0, cfg.max_members + 2),
                queued: g.usize(0, 200) as u64,
                shed_delta: g.usize(0, 3) as u64,
                infeasible_delta: g.usize(0, 2) as u64,
                interactive_p99_us: g.usize(0, 500_000) as u64,
            };
            let d = decide(&cfg, &mut state, &s);
            match d {
                Decision::Hold => {}
                action => {
                    if let Some((prev_tick, prev)) = last_action {
                        let gap = tick - prev_tick;
                        prop_assert(
                            gap >= cfg.cooldown_ticks as u64 + 1,
                            format!(
                                "{prev:?}@{prev_tick} then {action:?}@{tick}: gap {gap} \
                                 < cooldown {} + 1",
                                cfg.cooldown_ticks
                            ),
                        )?;
                    }
                    if action == Decision::ScaleUp {
                        prop_assert(s.members < cfg.max_members, "ScaleUp at max_members")?;
                    } else {
                        prop_assert(s.members > cfg.min_members, "ScaleDown at min_members")?;
                    }
                    last_action = Some((tick, action));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batch_migration_selection_invariants() {
    // Against a random pending table with random cancellations and
    // expiries: a draining victim is never robbed; a selected group is
    // routable, meets the live floor, and is the fullest routable
    // group; its live count excludes every cancelled/expired request.
    use std::time::Instant;
    use tilekit::coordinator::batcher::BatcherState;
    use tilekit::coordinator::{
        select_batch_migration, RequestKey, ResizeRequest, Ticket, MIGRATE_MIN_LIVE,
    };

    forall("migration selection", 200, |g| {
        let img = generate::gradient(8, 8);
        // batch_max high enough that pushes never flush a full batch.
        let mut table = BatcherState::new(1000, Duration::from_secs(60));
        let n_keys = g.usize(0, 5);
        let mut expect_live = vec![0usize; n_keys];
        let past = Instant::now();
        for (k, live_slot) in expect_live.iter_mut().enumerate() {
            for i in 0..g.usize(0, 6) {
                let (_t, tx) = Ticket::new((k * 10 + i) as u64);
                let mut r = ResizeRequest::bare(
                    (k * 10 + i) as u64,
                    RequestKey::of(Interpolator::Bilinear, &img, (k + 2) as u32),
                    img.clone(),
                    tx,
                );
                match g.usize(0, 2) {
                    0 => r.cancel.cancel(),            // dead: cancelled
                    1 => r.deadline = Some(past),      // dead: expired
                    _ => *live_slot += 1,              // live
                }
                table.push(r);
            }
        }
        let now = Instant::now();
        let groups = table.migration_groups(now);
        // Sorted by scale here (same kernel/src), so group i is key i+2.
        for gr in &groups {
            let k = (gr.key.scale - 2) as usize;
            prop_assert(
                gr.live == expect_live[k],
                format!("group {k}: live {} != expected {}", gr.live, expect_live[k]),
            )?;
        }
        // Random routability per group; drain kills every selection.
        let routable: Vec<bool> = (0..groups.len()).map(|_| g.bool()).collect();
        let supports = |key: &RequestKey| routable[(key.scale - 2) as usize];
        prop_assert(
            select_batch_migration(&groups, supports, true, MIGRATE_MIN_LIVE).is_none(),
            "draining victim was robbed",
        )?;
        match select_batch_migration(&groups, supports, false, MIGRATE_MIN_LIVE) {
            None => {
                for (i, gr) in groups.iter().enumerate() {
                    prop_assert(
                        !routable[i] || gr.live < MIGRATE_MIN_LIVE,
                        "eligible group was passed over",
                    )?;
                }
            }
            Some(i) => {
                let win = &groups[i];
                prop_assert(routable[i], "selected an unroutable group")?;
                prop_assert(win.live >= MIGRATE_MIN_LIVE, "selected below the live floor")?;
                for (j, gr) in groups.iter().enumerate() {
                    if routable[j] {
                        prop_assert(
                            gr.live < win.live || (gr.live == win.live && j >= i),
                            "not the fullest routable group (lowest index on ties)",
                        )?;
                    }
                }
                // Extraction takes the WHOLE group; the live ones in it
                // match the advertised count.
                let taken = table.take_group(&win.key);
                let live_taken = taken
                    .iter()
                    .filter(|r| !r.is_cancelled() && !r.is_expired(now))
                    .count();
                prop_assert(
                    live_taken == win.live,
                    format!("took {live_taken} live, advertised {}", win.live),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_autoscaler_desc_round_trips() {
    use tilekit::net::AutoscalerDesc;

    forall("autoscaler desc round trip", 200, |g| {
        let d = AutoscalerDesc {
            enabled: g.bool(),
            low_queue: (g.f64(0.0, 16.0) * 1e3).round() / 1e3,
            high_queue: (g.f64(16.0, 64.0) * 1e3).round() / 1e3,
            high_p99_us: g.usize(0, 1 << 30) as u64,
            cooldown_ticks: g.usize(0, 1 << 16) as u64,
            poll_ms: g.usize(1, 10_000) as u64,
            min_members: g.usize(1, 8) as u64,
            max_members: g.usize(8, 16) as u64,
            standby_free: g.usize(0, 8) as u64,
            ticks: g.usize(0, 1 << 40) as u64,
            scale_ups: g.usize(0, 999) as u64,
            scale_downs: g.usize(0, 999) as u64,
            holds: g.usize(0, 1 << 40) as u64,
            errors: g.usize(0, 99) as u64,
        };
        let back = AutoscalerDesc::from_json(&d.to_json()).map_err(|e| e.to_string())?;
        prop_assert(back == d, "autoscaler desc round trip differs")
    });
}

#[test]
fn prop_net_v2_binary_image_round_trips_bit_exactly() {
    use tilekit::image::Image;
    use tilekit::net::protocol::{decode_image_any, encode_image_blob};

    forall("v2 binary image round trip", 200, |g| {
        let w = g.usize(1, 24);
        let h = g.usize(1, 24);
        let mut data = generate::test_scene(w, h, g.u32(0, 10_000) as u64).to_dense();
        // Sprinkle in the values JSON cannot carry (or mangles): the
        // binary block must round-trip every f32 bit pattern.
        for _ in 0..g.usize(0, 6) {
            let i = g.usize(0, data.len() - 1);
            data[i] = *g.choose(&[
                f32::NAN,
                f32::INFINITY,
                f32::NEG_INFINITY,
                -0.0,
                f32::MIN_POSITIVE,
                f32::MAX,
            ]);
        }
        let img = Image::from_vec(w, h, data.clone());
        let (header, blob) = encode_image_blob(&img);
        prop_assert(
            blob.len() == 4 + 4 * w * h,
            "block must cost exactly 4 bytes per pixel plus the count prefix",
        )?;
        let back = decode_image_any(&header, Some(blob.as_slice())).map_err(|e| e.to_string())?;
        prop_assert(
            back.width() == w && back.height() == h,
            "dims must survive the block",
        )?;
        let back_px = back.to_dense();
        for (i, (a, b)) in data.iter().zip(back_px.iter()).enumerate() {
            prop_assert(
                a.to_bits() == b.to_bits(),
                format!("pixel {i} changed bits: {a:?} -> {b:?}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_net_v2_hostile_blocks_yield_typed_errors() {
    use std::io::Cursor;
    use tilekit::net::protocol::{decode_image_any, encode_image_blob, read_payload};
    use tilekit::net::ProtocolError;

    forall("v2 hostile payload blocks", 300, |g| {
        let w = g.usize(1, 12);
        let h = g.usize(1, 12);
        let img = generate::test_scene(w, h, g.u32(0, 10_000) as u64);
        let (header, blob) = encode_image_blob(&img);

        // A block truncated anywhere short of full length must be a
        // typed error, never a panic or a silently shorter image.
        let cut = g.usize(0, blob.len() - 1);
        prop_assert(
            decode_image_any(&header, Some(&blob[..cut])).is_err(),
            format!("truncation at {cut}/{} went unnoticed", blob.len()),
        )?;

        // A count prefix that disagrees with the header must be
        // rejected (low byte flipped: the count always changes, because
        // MAX_IMAGE_PIXELS keeps it far below the wrap).
        let mut lying = blob.clone();
        lying[0] ^= 0xff;
        prop_assert(
            decode_image_any(&header, Some(lying.as_slice())).is_err(),
            "mismatched count prefix went unnoticed",
        )?;

        // A binary header with no block at all is malformed.
        prop_assert(
            decode_image_any(&header, None).is_err(),
            "binary header with a missing block went unnoticed",
        )?;

        // read_payload: a block past the byte cap is Oversized before a
        // single byte is read; EOF inside the block is Truncated.
        let cap = g.usize(4, 256);
        let n = g.usize(0, 512);
        let avail = g.usize(0, n);
        match read_payload(&mut Cursor::new(vec![0u8; avail]), n, cap) {
            Ok(b) => prop_assert(
                n <= cap && avail == n && b.len() == n,
                "read_payload returned a block it could not have read",
            )?,
            Err(ProtocolError::Oversized { limit }) => {
                prop_assert(n > cap && limit == cap, "wrong Oversized report")?;
            }
            Err(ProtocolError::Truncated) => {
                prop_assert(n <= cap && avail < n, "wrong Truncated report")?;
            }
            Err(e) => return Err(format!("unexpected error: {e}")),
        }
        Ok(())
    });
}

#[test]
fn prop_net_hello_negotiation_is_sound_both_directions() {
    use tilekit::net::protocol::{decode_hello_max, encode_hello, negotiate};
    use tilekit::net::{PROTOCOL_V2, PROTOCOL_VERSION};

    forall("hello negotiation", 300, |g| {
        let client_max = g.usize(0, 6) as u64;
        let server_max = g.usize(0, 6) as u64;
        let v = negotiate(client_max, server_max);
        prop_assert(
            v >= PROTOCOL_VERSION,
            "negotiation may never go below the baseline",
        )?;
        prop_assert(
            v <= client_max.max(PROTOCOL_VERSION) && v <= server_max.max(PROTOCOL_VERSION),
            "negotiation may never exceed either peer's maximum",
        )?;
        // Symmetric: both ends of the exchange compute the same pin.
        prop_assert(
            v == negotiate(server_max, client_max),
            "negotiation must not depend on which side computes it",
        )?;
        // A v2-capable pair lands on v2; a pair with a v1 peer on v1.
        if client_max >= PROTOCOL_V2 && server_max >= PROTOCOL_V2 {
            prop_assert(v >= PROTOCOL_V2, "two v2 peers must speak v2")?;
        }
        if client_max <= PROTOCOL_VERSION || server_max <= PROTOCOL_VERSION {
            prop_assert(v == PROTOCOL_VERSION, "a v1 peer pins the session to v1")?;
        }
        // The payload round-trips the advertised maximum exactly...
        prop_assert(
            decode_hello_max(&encode_hello(client_max)) == client_max,
            "hello payload must carry the advertised maximum",
        )?;
        // ... and an alien payload (old peer, junk shape, no 'max')
        // degrades to the baseline instead of erroring.
        prop_assert(
            decode_hello_max(&gen_payload(g, 1)) == PROTOCOL_VERSION,
            "an unreadable hello must degrade to the baseline",
        )?;
        prop_assert(
            decode_hello_max(&Json::obj()) == PROTOCOL_VERSION,
            "a hello without 'max' must mean the baseline",
        )
    });
}
