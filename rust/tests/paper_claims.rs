//! The paper's findings, asserted as integration tests over the public
//! API. Each test cites the passage it reproduces. These are the
//! "shape" claims of EXPERIMENTS.md: who wins, in which regime, by
//! roughly what structure — never absolute milliseconds.

use tilekit::autotuner::{portable_tile, sweep};
use tilekit::device::{builtin_devices, find_device, paper_pair};
use tilekit::image::Interpolator;
use tilekit::sim::{simulate, Launch, Straggler};
use tilekit::tiling::occupancy::{occupancy, KernelResources};
use tilekit::tiling::{paper_sweep_tiles, TileDim};

fn paper_sweep(dev: &tilekit::device::DeviceDescriptor, scale: u32) -> tilekit::autotuner::SweepResult {
    sweep(
        dev,
        Interpolator::Bilinear,
        &paper_sweep_tiles(),
        scale,
        (800, 800),
    )
}

/// Table I: the registry reproduces every row for the paper pair.
#[test]
fn table1_rows() {
    let (gtx, gts) = paper_pair();
    assert_eq!(
        (gtx.cc.registers_per_sm, gts.cc.registers_per_sm),
        (16384, 8192)
    );
    assert_eq!((gtx.cc.max_warps_per_sm, gts.cc.max_warps_per_sm), (32, 24));
    assert_eq!(
        (gtx.cc.max_threads_per_sm, gts.cc.max_threads_per_sm),
        (1024, 768)
    );
    assert_eq!((gtx.total_sps(), gts.total_sps()), (192, 96));
    assert_eq!((gtx.sm_count, gts.sm_count), (24, 12));
}

/// §IV.A: "It is absolutely clear that, the GTX 260 can provide better
/// performance than the GeForce 8800 GTS."
#[test]
fn gtx260_dominates() {
    let (gtx, gts) = paper_pair();
    for scale in [2, 4, 6, 8, 10] {
        for tile in paper_sweep_tiles() {
            let l = Launch::paper(Interpolator::Bilinear, tile, scale);
            let (a, b) = (simulate(&l, &gtx, None).ms, simulate(&l, &gts, None).ms);
            assert!(a < b, "tile {tile} scale {scale}: {a} !< {b}");
        }
    }
}

/// §IV.B: "the tiling dimensions which can provide the best performance
/// both on GTX 260 and GeForce 8800 GTX ... is the tiling dimensions
/// 32x4 in inset (c), (d) and (e)" — scales 6, 8, 10.
#[test]
fn tile_32x4_best_on_both_at_large_scales() {
    let (gtx, gts) = paper_pair();
    let t32x4: TileDim = "32x4".parse().unwrap();
    for dev in [&gtx, &gts] {
        for scale in [6, 8, 10] {
            let best = paper_sweep(dev, scale).best().unwrap().tile;
            assert_eq!(best, t32x4, "{} at scale {scale}", dev.id);
        }
    }
}

/// §IV.B / Fig. 4: wide-short tiles beat tall-narrow tiles of the same
/// thread count once row crossings are expensive, on both devices.
#[test]
fn fig4_wide_beats_tall() {
    let (gtx, gts) = paper_pair();
    for dev in [&gtx, &gts] {
        for (wide, tall) in [("8x4", "4x8"), ("16x4", "4x16"), ("32x8", "8x32")] {
            let w: TileDim = wide.parse().unwrap();
            let t: TileDim = tall.parse().unwrap();
            for scale in [6, 8, 10] {
                let lw = Launch::paper(Interpolator::Bilinear, w, scale);
                let lt = Launch::paper(Interpolator::Bilinear, t, scale);
                let (tw, tt) = (simulate(&lw, dev, None).ms, simulate(&lt, dev, None).ms);
                assert!(
                    tw <= tt,
                    "{}: {wide} ({tw}) should beat {tall} ({tt}) at scale {scale}",
                    dev.id
                );
            }
        }
    }
}

/// §IV.B: the GTX 260 curve moves in a narrower ms band than the 8800
/// GTS curve ("the lower line is smoother than the upper line").
#[test]
fn gtx_curve_smoother_in_ms() {
    let (gtx, gts) = paper_pair();
    for scale in [2, 4, 6, 8, 10] {
        let rg = paper_sweep(&gtx, scale).range_ms();
        let rs = paper_sweep(&gts, scale).range_ms();
        assert!(rg < rs, "scale {scale}: {rg} !< {rs}");
    }
}

/// §III.B: the 32×16 occupancy cliff — 2 blocks/1024 threads on the
/// GTX 260, 1 block/512 threads (66%) on the 8800 GTS.
#[test]
fn occupancy_cliff_32x16() {
    let (gtx, gts) = paper_pair();
    let tile: TileDim = "32x16".parse().unwrap();
    let a = occupancy(tile, &KernelResources::BILINEAR, &gtx.cc);
    let b = occupancy(tile, &KernelResources::BILINEAR, &gts.cc);
    assert_eq!((a.blocks_per_sm, a.threads_per_sm), (2, 1024));
    assert_eq!((b.blocks_per_sm, b.threads_per_sm), (1, 512));
    assert!((a.ratio - 1.0).abs() < 1e-9);
    assert!((b.ratio - 2.0 / 3.0).abs() < 1e-9);
}

/// §IV.C: a half-speed SM costs G1 (2 SMs) ≈1/4 of efficiency and G2
/// (20 SMs) ≈1/40 — "the effect caused by tiling dimensions is less when
/// the number of cores is larger".
#[test]
fn extreme_example_dilution() {
    let g1 = find_device("g1").unwrap();
    let g2 = find_device("g2").unwrap();
    let l = Launch::paper(Interpolator::Bilinear, "32x4".parse().unwrap(), 4);
    let loss = |dev| {
        let clean = simulate(&l, dev, None).ms;
        let hurt = simulate(&l, dev, Some(Straggler { sm: 0, speed: 0.5 })).ms;
        (hurt - clean) / hurt
    };
    let (l1, l2) = (loss(&g1), loss(&g2));
    assert!((l1 - 0.25).abs() < 0.05, "G1 loss {l1} (paper: 1/4)");
    assert!((l2 - 0.025).abs() < 0.01, "G2 loss {l2} (paper: 1/40)");
}

/// §V: the portable (min-max regret) tile over the paper pair is 32×4
/// at the large scales — "consider more about the performance on the
/// worst-case GPU".
#[test]
fn portable_tile_is_32x4() {
    let (gtx, gts) = paper_pair();
    let tiles = paper_sweep_tiles();
    for scale in [6, 8, 10] {
        let sweeps = vec![
            sweep(&gtx, Interpolator::Bilinear, &tiles, scale, (800, 800)),
            sweep(&gts, Interpolator::Bilinear, &tiles, scale, (800, 800)),
        ];
        let c = portable_tile(&sweeps).unwrap();
        assert_eq!(c.tile, "32x4".parse().unwrap(), "scale {scale}");
    }
}

/// Cross-registry sanity: every builtin device runs the whole paper
/// sweep to finite positive times for launchable tiles.
#[test]
fn all_devices_simulate_cleanly() {
    for dev in builtin_devices() {
        for tile in paper_sweep_tiles() {
            let l = Launch::paper(Interpolator::Bilinear, tile, 4);
            let r = simulate(&l, &dev, None);
            if tile.is_valid(&dev.cc) {
                assert!(r.ms.is_finite() && r.ms > 0.0, "{} {tile}", dev.id);
            }
        }
    }
}
