//! Integration tests for `tilekit::analysis` — the invariant analyzer
//! behind `tilekit analyze`.
//!
//! Every rule has a known-bad fixture asserted to produce exactly its
//! finding and a known-clean twin asserted to produce none (the
//! fixtures live under `analysis_fixtures/`, which the analyzer walk
//! skips, and are fed to [`analyze_corpus`] under pretend paths so
//! path-scoped rules fire). The last test is the self-hosting gate:
//! the real tree under `rust/src` + `rust/tests` must be clean under
//! `--strict` — the same invocation CI runs.

use tilekit::analysis::{analyze_corpus, analyze_paths, Report};

fn run_one(pretend_path: &str, src: &str, strict: bool) -> Report {
    analyze_corpus(&[(pretend_path.to_string(), src.to_string())], strict)
}

fn rules_of(report: &Report) -> Vec<&'static str> {
    report.findings.iter().map(|f| f.rule).collect()
}

// ------------------------------------------------- no-panic-on-wire --

const NO_PANIC_BAD: &str = include_str!("analysis_fixtures/no_panic_bad.rs");
const NO_PANIC_CLEAN: &str = include_str!("analysis_fixtures/no_panic_clean.rs");

#[test]
fn no_panic_bad_fixture_fires() {
    let r = run_one("rust/src/net/protocol.rs", NO_PANIC_BAD, false);
    assert_eq!(
        rules_of(&r),
        ["no-panic-on-wire", "no-panic-on-wire", "no-panic-on-wire"],
        "expected the index, unwrap, and panic! violations: {:?}",
        r.findings
    );
    // One finding per line: index at 10, unwrap at 14, panic! at 16.
    let lines: Vec<u32> = r.findings.iter().map(|f| f.line).collect();
    assert_eq!(lines, [10, 14, 16]);
}

#[test]
fn no_panic_clean_fixture_is_clean() {
    let r = run_one("rust/src/net/protocol.rs", NO_PANIC_CLEAN, false);
    assert!(r.clean(), "unexpected findings: {:?}", r.findings);
}

#[test]
fn no_panic_rule_is_scoped_to_wire_files() {
    let r = run_one("rust/src/tiling/mod.rs", NO_PANIC_BAD, false);
    assert!(r.clean(), "rule fired off the wire path: {:?}", r.findings);
}

// ------------------------------------------ no-as-narrowing-in-decode --

const NARROWING_BAD: &str = include_str!("analysis_fixtures/narrowing_bad.rs");
const NARROWING_CLEAN: &str = include_str!("analysis_fixtures/narrowing_clean.rs");

#[test]
fn narrowing_bad_fixture_fires() {
    let r = run_one("rust/src/codec/json.rs", NARROWING_BAD, false);
    assert_eq!(rules_of(&r), ["no-as-narrowing-in-decode"], "{:?}", r.findings);
    assert!(
        r.findings[0].message.contains("decode_scale"),
        "message should name the decode fn: {}",
        r.findings[0].message
    );
}

#[test]
fn narrowing_clean_fixture_is_clean() {
    // `try_from` in the decode fn, a cast only in the encode-named fn.
    let r = run_one("rust/src/codec/json.rs", NARROWING_CLEAN, false);
    assert!(r.clean(), "unexpected findings: {:?}", r.findings);
}

#[test]
fn narrowing_rule_is_scoped_to_decode_files() {
    let r = run_one("rust/src/tiling/mod.rs", NARROWING_BAD, false);
    assert!(r.clean(), "rule fired off the decode files: {:?}", r.findings);
}

// --------------------------------------------- duration-through-bounds --

const DURATION_BAD: &str = include_str!("analysis_fixtures/duration_bad.rs");
const DURATION_CLEAN: &str = include_str!("analysis_fixtures/duration_clean.rs");

#[test]
fn duration_bad_fixture_fires() {
    let r = run_one("rust/src/config/mod.rs", DURATION_BAD, false);
    assert_eq!(rules_of(&r), ["duration-through-bounds"], "{:?}", r.findings);
    assert_eq!(r.findings[0].line, 10);
}

#[test]
fn duration_clean_fixture_is_clean() {
    let r = run_one("rust/src/config/mod.rs", DURATION_CLEAN, false);
    assert!(r.clean(), "unexpected findings: {:?}", r.findings);
}

// --------------------------------------------------------- lock-order --

const LOCK_ORDER_BAD: &str = include_str!("analysis_fixtures/lock_order_bad.rs");
const LOCK_ORDER_CLEAN: &str = include_str!("analysis_fixtures/lock_order_clean.rs");

#[test]
fn lock_order_bad_fixture_fires() {
    let r = run_one("rust/src/coordinator/server.rs", LOCK_ORDER_BAD, false);
    assert_eq!(rules_of(&r), ["lock-order", "lock-order"], "{:?}", r.findings);
    // The inversion (plan acquired under topology) and the
    // rebuild_plan-under-guard call, in source order.
    assert!(r.findings[0].message.contains("inverts"), "{}", r.findings[0].message);
    assert!(r.findings[1].message.contains("rebuild_plan"), "{}", r.findings[1].message);
}

#[test]
fn lock_order_clean_fixture_is_clean() {
    let r = run_one("rust/src/coordinator/server.rs", LOCK_ORDER_CLEAN, false);
    assert!(r.clean(), "unexpected findings: {:?}", r.findings);
}

// ---------------------------------------------------- atomics-pairing --

const ATOMICS_BAD: &str = include_str!("analysis_fixtures/atomics_bad.rs");
const ATOMICS_CLEAN: &str = include_str!("analysis_fixtures/atomics_clean.rs");

#[test]
fn atomics_bad_fixture_fires() {
    let r = run_one("rust/src/exec/pool.rs", ATOMICS_BAD, false);
    assert_eq!(rules_of(&r), ["atomics-pairing"], "{:?}", r.findings);
    assert!(r.findings[0].message.contains("halt"), "{}", r.findings[0].message);
}

#[test]
fn atomics_clean_fixture_is_clean() {
    let r = run_one("rust/src/exec/pool.rs", ATOMICS_CLEAN, false);
    assert!(r.clean(), "unexpected findings: {:?}", r.findings);
}

#[test]
fn atomics_rule_skips_tests_dir_files() {
    // A tests-dir file's same-named atomics are different objects;
    // pairing them with src fields would be a false positive.
    let r = run_one("rust/tests/foo.rs", ATOMICS_BAD, false);
    assert!(r.clean(), "rule fired in a tests-dir file: {:?}", r.findings);
}

// ------------------------------------------------ no-guard-across-block --

const GUARD_BAD: &str = include_str!("analysis_fixtures/guard_bad.rs");
const GUARD_CLEAN: &str = include_str!("analysis_fixtures/guard_clean.rs");

#[test]
fn guard_bad_fixture_fires() {
    let r = run_one("rust/src/coordinator/member.rs", GUARD_BAD, false);
    assert_eq!(rules_of(&r), ["no-guard-across-block"], "{:?}", r.findings);
    assert!(r.findings[0].message.contains("join"), "{}", r.findings[0].message);
}

#[test]
fn guard_clean_fixture_is_clean() {
    // Handle taken out under the lock; condvar wait hands the guard
    // over (and `if let Some(..)` patterns must not bind phantom
    // guards from a later statement's lock chain).
    let r = run_one("rust/src/coordinator/member.rs", GUARD_CLEAN, false);
    assert!(r.clean(), "unexpected findings: {:?}", r.findings);
}

// ------------------------------------------------------- suppressions --

const ALLOW_OK: &str = include_str!("analysis_fixtures/allow_ok.rs");
const BARE_ALLOW: &str = include_str!("analysis_fixtures/bare_allow.rs");
const UNUSED_ALLOW: &str = include_str!("analysis_fixtures/unused_allow.rs");

#[test]
fn reasoned_allow_suppresses_and_counts() {
    let r = run_one("rust/src/config/mod.rs", ALLOW_OK, true);
    assert!(r.clean(), "suppression failed: {:?}", r.findings);
    assert_eq!(r.suppressed, 1);
}

#[test]
fn bare_and_unknown_allows_are_findings_and_do_not_suppress() {
    let r = run_one("rust/src/config/mod.rs", BARE_ALLOW, false);
    assert_eq!(
        rules_of(&r),
        [
            "bare-allow",
            "duration-through-bounds",
            "bare-allow",
            "duration-through-bounds",
        ],
        "{:?}",
        r.findings
    );
    assert_eq!(r.suppressed, 0);
}

#[test]
fn unused_allow_is_strict_only() {
    let strict = run_one("rust/src/config/mod.rs", UNUSED_ALLOW, true);
    assert_eq!(rules_of(&strict), ["unused-allow"], "{:?}", strict.findings);
    let lax = run_one("rust/src/config/mod.rs", UNUSED_ALLOW, false);
    assert!(lax.clean(), "unused-allow leaked outside --strict: {:?}", lax.findings);
}

#[test]
fn findings_render_as_file_line_rule() {
    let r = run_one("rust/src/config/mod.rs", DURATION_BAD, false);
    let line = r.findings[0].to_string();
    assert!(
        line.starts_with("rust/src/config/mod.rs:10: [duration-through-bounds]"),
        "unexpected rendering: {line}"
    );
}

// ------------------------------------------------------- self-hosting --

#[test]
fn the_tree_is_clean_under_strict() {
    // Integration tests run from the package root, which is the repo
    // root (Cargo.toml points lib/bin/tests into rust/).
    let report = analyze_paths(
        &["rust/src".into(), "rust/tests".into()],
        true,
    )
    .expect("walk failed");
    assert!(report.files > 50, "walk looks truncated: {} files", report.files);
    let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(
        report.clean(),
        "the tree must self-host clean under --strict:\n{}",
        rendered.join("\n")
    );
    assert!(report.suppressed > 0, "the deliberate exceptions should register");
}
