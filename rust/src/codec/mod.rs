//! Self-contained data codecs (the offline environment has no serde):
//!
//! * [`json`] — a full JSON parser/serializer used for the artifact
//!   `manifest.json` interchange with the python AOT pipeline and for
//!   machine-readable report output.
//! * [`toml`] — a pragmatic TOML-subset parser (tables, arrays of tables,
//!   scalars, arrays) used by the config system.

pub mod json;
pub mod toml;

pub use json::Json;
pub use toml::TomlDoc;
