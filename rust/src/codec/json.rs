//! A small, strict JSON implementation (RFC 8259 subset sufficient for the
//! artifact manifest and report emission): full parsing of objects, arrays,
//! strings with escapes (incl. `\uXXXX` + surrogate pairs), numbers, bools,
//! null; serialization with stable key order (insertion order preserved).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` (sorted keys) — good enough for
/// manifests, and deterministic output helps diffing artifacts.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    // ----- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view of a number: non-negative, fraction-free, and below
    /// 2^64. The upper bound matters — `1e300 as u64` would silently
    /// saturate to `u64::MAX` instead of reporting "not a u64".
    pub fn as_u64(&self) -> Option<u64> {
        const TWO_POW_64: f64 = 18_446_744_073_709_551_616.0;
        match self {
            Json::Num(n) if *n >= 0.0 && *n < TWO_POW_64 && n.fract() == 0.0 => {
                // analyze::allow(no-as-narrowing-in-decode): guarded —
                // 0 <= n < 2^64 and fraction-free, so the cast is exact.
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ----- parsing --------------------------------------------------------
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ----- serialization ----------------------------------------------------
    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(w * depth));
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset and message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("invalid literal, expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            // analyze::allow(no-as-narrowing-in-decode): u16 -> u32 widenings of range-checked surrogate halves
                            0x10000 + (((hi - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32
                        } else {
                            // analyze::allow(no-as-narrowing-in-decode): u16 -> u32 widening cannot truncate
                            hi as u32
                        };
                        s.push(
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid utf8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            // analyze::allow(no-as-narrowing-in-decode): to_digit(16)
            // returns 0..=15; the u32 -> u16 cast cannot truncate.
            v = (v << 4) | d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_manifest_shape() {
        let doc = r#"{
            "version": 1,
            "artifacts": [
                {"name": "bilinear_s2_t32x4", "kernel": "bilinear",
                 "scale": 2, "tile": [4, 32], "path": "bilinear_s2_t32x4.hlo.txt",
                 "src": [800, 800], "dst": [1600, 1600]}
            ],
            "flags": {"interpret": true, "dtype": "f32"}
        }"#;
        let v = Json::parse(doc).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(
            arts[0].get("kernel").unwrap().as_str().unwrap(),
            "bilinear"
        );
        assert_eq!(arts[0].get("scale").unwrap().as_u64().unwrap(), 2);
        // round trip
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀 ü""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀 ü");
        let out = v.to_string();
        let re = Json::parse(&out).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        for (s, want) in [
            ("0", 0.0),
            ("-1", -1.0),
            ("3.5", 3.5),
            ("1e3", 1000.0),
            ("-2.5E-2", -0.025),
        ] {
            assert_eq!(Json::parse(s).unwrap().as_f64().unwrap(), want, "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "\"abc", "tru", "1 2", "{\"a\":}", "nul", ""] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn builder_api() {
        let j = Json::obj()
            .set("name", "gtx260")
            .set("sms", 24u64)
            .set("ok", true)
            .set("tags", vec!["a", "b"]);
        assert_eq!(j.get("sms").unwrap().as_u64(), Some(24));
        assert_eq!(j.get("tags").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn integer_output_has_no_decimal_point() {
        assert_eq!(Json::Num(24.0).to_string(), "24");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }
}
