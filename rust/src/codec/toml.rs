//! A pragmatic TOML-subset parser for the config system.
//!
//! Supported: `[table]` and `[table.sub]` headers, `[[array-of-tables]]`,
//! `key = value` with string / integer / float / bool / array values,
//! comments, and bare or quoted keys. Unsupported TOML (dates, inline
//! tables, multi-line strings) produces a clear error rather than silent
//! misparse — the config files this crate ships stay inside the subset.

use std::collections::BTreeMap;
use std::fmt;

/// A TOML scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// One `[table]` (or one element of a `[[table]]` array): flat key→value.
pub type TomlTable = BTreeMap<String, TomlValue>;

/// A parsed document: the root table, named tables (dotted path joined
/// with '.'), and arrays of tables.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub root: TomlTable,
    pub tables: BTreeMap<String, TomlTable>,
    pub arrays: BTreeMap<String, Vec<TomlTable>>,
}

impl TomlDoc {
    /// Look up a table by dotted path; the empty path returns the root.
    pub fn table(&self, path: &str) -> Option<&TomlTable> {
        if path.is_empty() {
            Some(&self.root)
        } else {
            self.tables.get(path)
        }
    }

    /// Look up `key` inside table `path` (empty path = root).
    pub fn get(&self, path: &str, key: &str) -> Option<&TomlValue> {
        self.table(path).and_then(|t| t.get(key))
    }

    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        // Which container new keys go into:
        enum Target {
            Root,
            Table(String),
            ArrayElem(String),
        }
        let mut target = Target::Root;

        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError {
                line: lineno + 1,
                msg: msg.to_string(),
            };
            if let Some(rest) = line.strip_prefix("[[") {
                let name = rest
                    .strip_suffix("]]")
                    .ok_or_else(|| err("unterminated [[table]] header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err("empty table name"));
                }
                doc.arrays
                    .entry(name.to_string())
                    .or_default()
                    .push(TomlTable::new());
                target = Target::ArrayElem(name.to_string());
            } else if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated [table] header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err("empty table name"));
                }
                doc.tables.entry(name.to_string()).or_default();
                target = Target::Table(name.to_string());
            } else {
                let eq = line
                    .find('=')
                    .ok_or_else(|| err("expected 'key = value'"))?;
                let key = parse_key(line[..eq].trim()).map_err(|m| err(&m))?;
                let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
                let table = match &target {
                    Target::Root => &mut doc.root,
                    Target::Table(name) => doc.tables.get_mut(name).unwrap(),
                    Target::ArrayElem(name) => {
                        doc.arrays.get_mut(name).unwrap().last_mut().unwrap()
                    }
                };
                if table.insert(key.clone(), val).is_some() {
                    return Err(err(&format!("duplicate key '{key}'")));
                }
            }
        }
        Ok(doc)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_key(s: &str) -> Result<String, String> {
    if let Some(q) = s.strip_prefix('"') {
        q.strip_suffix('"')
            .map(|k| k.to_string())
            .ok_or_else(|| "unterminated quoted key".to_string())
    } else if !s.is_empty()
        && s.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
    {
        Ok(s.to_string())
    } else {
        Err(format!("invalid bare key '{s}'"))
    }
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("missing value".to_string());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let body = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(unescape(body)?));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .unwrap()
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    // number: TOML allows underscores
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        cleaned
            .parse::<f64>()
            .map(TomlValue::Float)
            .map_err(|_| format!("invalid float '{s}'"))
    } else {
        cleaned
            .parse::<i64>()
            .map(TomlValue::Int)
            .map_err(|_| format!("invalid value '{s}'"))
    }
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape '\\{:?}'", other)),
        }
    }
    Ok(out)
}

/// Split on commas that are not nested inside brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Parse error with 1-based line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for TomlError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_shape() {
        let doc = TomlDoc::parse(
            r#"
# tilekit config
title = "sweep"

[sweep]
scales = [2, 4, 6, 8, 10]
source = [800, 800]
min_threads = 32
max_threads = 512

[serving]
batch_max = 8
deadline_ms = 5.5
enabled = true

[[device]]
name = "gtx260"
sms = 24

[[device]]
name = "8800gts"
sms = 12
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "title").unwrap().as_str(), Some("sweep"));
        let scales: Vec<i64> = doc
            .get("sweep", "scales")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_int().unwrap())
            .collect();
        assert_eq!(scales, vec![2, 4, 6, 8, 10]);
        assert_eq!(doc.get("serving", "deadline_ms").unwrap().as_float(), Some(5.5));
        assert_eq!(doc.get("serving", "enabled").unwrap().as_bool(), Some(true));
        let devs = &doc.arrays["device"];
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[0]["name"].as_str(), Some("gtx260"));
        assert_eq!(devs[1]["sms"].as_int(), Some(12));
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let doc = TomlDoc::parse("k = \"a#b\" # trailing\n").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn nested_arrays() {
        let doc = TomlDoc::parse("tiles = [[32, 4], [16, 8]]").unwrap();
        let outer = doc.get("", "tiles").unwrap().as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_array().unwrap()[0].as_int(), Some(32));
        assert_eq!(outer[1].as_array().unwrap()[1].as_int(), Some(8));
    }

    #[test]
    fn underscored_numbers() {
        let doc = TomlDoc::parse("mem = 1_073_741_824\nclk = 1.242_000").unwrap();
        assert_eq!(doc.get("", "mem").unwrap().as_int(), Some(1073741824));
        assert!((doc.get("", "clk").unwrap().as_float().unwrap() - 1.242).abs() < 1e-9);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = TomlDoc::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn int_as_float_coercion() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float(), Some(3.0));
    }
}
