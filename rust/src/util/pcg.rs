//! PCG32 (PCG-XSH-RR 64/32) — a small, fast, statistically solid PRNG.
//!
//! Implemented in-tree because the offline crate set has no `rand`; the
//! property-testing framework ([`crate::prop`]), the workload generators,
//! and the synthetic-image module all need seeded, reproducible streams.
//! Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation" (2014).

/// PCG-XSH-RR 64/32 generator. 64-bit state, 64-bit stream selector,
/// 32-bit output. Copy-cheap (`Clone + Copy`), deterministic across
/// platforms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id. Distinct streams with
    /// the same seed are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut g = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        g.next_u32();
        g.state = g.state.wrapping_add(seed);
        g.next_u32();
        g
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift rejection.
    /// `bound` must be non-zero.
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0, "below(0) is meaningless");
        // Rejection sampling to remove modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in `[0, bound)` for 64-bit bounds — the wide analogue of
    /// [`below`](Self::below), same Lemire multiply-shift rejection.
    /// `bound` must be non-zero.
    #[inline]
    pub fn below_u64(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "below_u64(0) is meaningless");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let m = (r as u128) * (bound as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` (inclusive). Wide spans (property
    /// tests draw epochs and nanosecond costs up to `1 << 40`) take the
    /// 64-bit path; narrow spans keep the cheaper single-u32 draw.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64;
        if span < u32::MAX as u64 {
            return lo + self.below(span as u32 + 1) as usize;
        }
        if span == u64::MAX {
            return self.next_u64() as usize;
        }
        lo + self.below_u64(span + 1) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    #[inline]
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "pick from empty slice");
        &xs[self.below(xs.len() as u32) as usize]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 54);
        let mut b = Pcg32::new(42, 54);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be effectively independent");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut g = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = g.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn range_usize_handles_wide_spans() {
        let mut g = Pcg32::seeded(11);
        // Narrow span: inclusive bounds hold.
        for _ in 0..200 {
            let v = g.range_usize(3, 7);
            assert!((3..=7).contains(&v));
        }
        // Spans past u32::MAX used to assert; now they sample uniformly.
        let hi = 1usize << 40;
        let mut above_u32 = false;
        for _ in 0..64 {
            let v = g.range_usize(0, hi);
            assert!(v <= hi);
            above_u32 |= v > u32::MAX as usize;
        }
        assert!(above_u32, "wide draws should reach past u32::MAX");
        // The exact-boundary span routes through the wide path too.
        let v = g.range_usize(0, u32::MAX as usize);
        assert!(v <= u32::MAX as usize);
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut g = Pcg32::seeded(1234);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = g.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Pcg32::seeded(9);
        let mut xs: Vec<u32> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut g = Pcg32::seeded(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = g.range_u32(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }
}
