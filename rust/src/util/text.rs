//! Plain-text table rendering for reports and bench output. Produces the
//! aligned rows the paper's tables/figures are regenerated as, plus CSV for
//! downstream plotting.

/// A simple column-aligned text table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with padded columns and a separator rule under the header.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
                .trim_end()
                .to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (no quoting needed for our numeric/identifier cells;
    /// cells containing commas are quoted defensively).
    pub fn to_csv(&self) -> String {
        let esc = |c: &String| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(esc)
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a duration in milliseconds with adaptive precision.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.1}")
    } else if ms >= 1.0 {
        format!("{ms:.3}")
    } else {
        format!("{ms:.5}")
    }
}

/// Format a count with thousands separators (e.g. `12_345_678`).
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push('_');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(vec!["tile", "time(ms)"]);
        t.row(vec!["32x4", "1.5"]);
        t.row(vec!["8x8", "12.25"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("tile"));
        assert!(lines[2].starts_with("32x4"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_enforced() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(vec!["k", "v"]);
        t.row(vec!["a,b", "c\"d"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"c\"\"d\""));
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(1), "1");
        assert_eq!(fmt_count(1234), "1_234");
        assert_eq!(fmt_count(12345678), "12_345_678");
    }

    #[test]
    fn ms_formatting_precision() {
        assert_eq!(fmt_ms(123.456), "123.5");
        assert_eq!(fmt_ms(1.23456), "1.235");
        assert_eq!(fmt_ms(0.0012345), "0.00123");
    }
}
