//! Small shared utilities: a deterministic PRNG, descriptive statistics,
//! and text-formatting helpers used by the bench harness and reports.

pub mod pcg;
pub mod stats;
pub mod text;

pub use pcg::Pcg32;
pub use stats::Summary;
