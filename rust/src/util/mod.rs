//! Small shared utilities: a deterministic PRNG, descriptive statistics,
//! text-formatting helpers used by the bench harness and reports, and a
//! shared content hash.

pub mod pcg;
pub mod stats;
pub mod text;

pub use pcg::Pcg32;
pub use stats::Summary;

/// FNV-1a over a byte stream: the stable 64-bit content fingerprint
/// shared by the tuning cache's tile-set keys and the retune daemon's
/// file-change detection. Not cryptographic — change detection and
/// cache keying only.
pub fn fnv1a64(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod fnv_tests {
    #[test]
    fn fnv1a64_is_stable_and_content_sensitive() {
        assert_eq!(super::fnv1a64(*b"abc"), super::fnv1a64(*b"abc"));
        assert_ne!(super::fnv1a64(*b"abc"), super::fnv1a64(*b"abd"));
        // The canonical FNV-1a empty-input offset basis.
        assert_eq!(super::fnv1a64([]), 0xcbf2_9ce4_8422_2325);
    }
}
