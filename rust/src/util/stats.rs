//! Descriptive statistics over `f64` samples: mean, stddev, percentiles,
//! coefficient of variation. Used by the bench harness, the autotuner's
//! smoothness metric (the paper's "GTX 260 curve is smoother" claim is
//! asserted as a CV comparison), and the serving stats.

/// Summary statistics of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / if n > 1 { (n - 1) as f64 } else { 1.0 };
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }

    /// Coefficient of variation (std/mean); 0 for a degenerate mean.
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < f64::EPSILON {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice, `p` in [0,100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Ratio of the range to the mean: a simple "jaggedness" measure for the
/// Fig. 3 curves (max spread across tiles relative to typical time).
pub fn spread_ratio(samples: &[f64]) -> f64 {
    match Summary::of(samples) {
        Some(s) if s.mean > 0.0 => (s.max - s.min) / s.mean,
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[4.0]).unwrap();
        assert_eq!(s.mean, 4.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 4.0);
        assert_eq!(s.min, 4.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn cv_zero_mean() {
        let s = Summary::of(&[0.0, 0.0]).unwrap();
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn spread_ratio_flat_vs_jagged() {
        let flat = [10.0, 10.1, 9.9, 10.0];
        let jagged = [5.0, 15.0, 7.0, 13.0];
        assert!(spread_ratio(&flat) < spread_ratio(&jagged));
    }
}
