//! Transport-agnostic fleet operations.
//!
//! The serving system has two ways to drive a fleet: in process through
//! [`Fleet`]/[`FleetController`], and over the wire through
//! [`FleetClient`]. Before this module existed the CLI carried two
//! parallel drivers — one per transport — that drifted whenever a verb
//! grew a flag. This module is the single vocabulary both transports
//! speak:
//!
//! * [`FleetOps`] — the data plane: submit a [`Request`], get back a
//!   waitable/pollable/cancellable [`TicketOps`] handle, read fleet
//!   stats.
//! * [`ControlOps`] — the control plane: every controller verb
//!   (`topology`, membership, drain, retune, scheduler/admission/steal
//!   swaps) plus the autoscaler surface.
//!
//! [`LocalFleet`] implements both over an in-process fleet with exactly
//! the semantics [`NetServer`](crate::net::NetServer) gives the same
//! verbs (registry device lookup, epoch-stamped membership changes,
//! "no autoscaler running" when none was started). [`FleetClient`]
//! implements both over the wire. Code written against the traits —
//! `tilekit fleet` is the in-tree example — cannot tell the difference:
//!
//! ```no_run
//! use tilekit::ops::ControlOps;
//!
//! fn epoch_of(ctl: &dyn ControlOps) -> anyhow::Result<u64> {
//!     Ok(ctl.topology_desc().map_err(|e| anyhow::anyhow!("{e}"))?.epoch)
//! }
//! ```
//!
//! Results come back in the wire-level descriptor types
//! ([`TopologyDesc`], [`WireStats`], [`AutoscalerDesc`]) rather than the
//! in-process views: those are the transport-neutral lingua franca — the
//! local implementation snapshots into them for free, and the remote one
//! already receives them.

use crate::autotuner::TuningOutcome;
use crate::coordinator::{
    AutoscalerHandle, AutoscalerUpdate, DrainMode, Fleet, FleetController, Request, SubmitError,
    Ticket, TilePolicy,
};
use crate::image::Image;
use crate::net::{
    AutoscalerDesc, BackendFactory, ClientError, FleetClient, RemoteTicket, TopologyDesc,
    WireStats,
};
use crate::tiling::TileDim;
use std::fmt;
use std::sync::Arc;
use std::time::Duration;

/// Why a fleet operation failed, independent of transport.
#[derive(Debug)]
pub enum OpsError {
    /// The fleet refused the submission — the same typed backpressure
    /// whether it was reached in process or over the wire.
    Submit(SubmitError),
    /// The path to the fleet failed (socket died, protocol violation).
    /// Never produced by the in-process implementation.
    Transport(String),
    /// The operation reached the fleet and failed there (unknown
    /// device, invalid knob value, no autoscaler running, ...).
    Failed(String),
}

impl OpsError {
    /// The typed [`SubmitError`], when this error is one.
    pub fn submit_error(&self) -> Option<SubmitError> {
        match self {
            OpsError::Submit(e) => Some(*e),
            _ => None,
        }
    }
}

impl fmt::Display for OpsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpsError::Submit(e) => write!(f, "fleet refused submit: {e}"),
            OpsError::Transport(m) | OpsError::Failed(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for OpsError {}

impl From<ClientError> for OpsError {
    fn from(e: ClientError) -> OpsError {
        match e {
            ClientError::Submit(s) => OpsError::Submit(s),
            remote @ ClientError::Remote(_) => OpsError::Failed(remote.to_string()),
            broken => OpsError::Transport(broken.to_string()),
        }
    }
}

/// Map the `anyhow` errors the in-process fleet hands back onto
/// [`OpsError`], preserving the typed refusal when there is one (the
/// wire does the same: the server encodes the [`SubmitError`] kind and
/// the client rehydrates it).
fn local_err(e: anyhow::Error) -> OpsError {
    match e.downcast::<SubmitError>() {
        Ok(s) => OpsError::Submit(s),
        Err(e) => OpsError::Failed(format!("{e:#}")),
    }
}

/// A pending submission, waitable regardless of transport. Implemented
/// by the in-process [`Ticket`] and the remote
/// [`RemoteTicket`](crate::net::RemoteTicket).
pub trait TicketOps {
    /// The submission id (stable across polls).
    fn ticket_id(&self) -> u64;
    /// The device the scheduler picked, when known.
    fn device_id(&self) -> Option<&str>;
    /// Block until the response arrives.
    fn wait(self) -> Result<Image<f32>, OpsError>
    where
        Self: Sized;
    /// Wait with a budget; `Ok(None)` on timeout.
    fn wait_timeout(&self, budget: Duration) -> Result<Option<Image<f32>>, OpsError>;
    /// Non-blocking poll; `Ok(None)` while still pending.
    fn try_wait(&self) -> Result<Option<Image<f32>>, OpsError>;
    /// Request cooperative cancellation.
    fn cancel(&self) -> Result<(), OpsError>;
}

/// The fleet data plane, transport-agnostic.
pub trait FleetOps {
    /// The pending-submission handle this transport hands out.
    type Ticket: TicketOps;

    /// Submit a request; a refusal is a typed
    /// [`OpsError::Submit`].
    fn submit_request(&self, req: Request) -> Result<Self::Ticket, OpsError>;

    /// Fleet-wide serving counters in the wire summary shape.
    fn fleet_stats(&self) -> Result<WireStats, OpsError>;
}

/// The fleet control plane, transport-agnostic. Object-safe: the CLI
/// drives `&dyn ControlOps` so one driver serves both transports.
pub trait ControlOps {
    /// Epoch-stamped topology snapshot.
    fn topology_desc(&self) -> Result<TopologyDesc, OpsError>;

    /// Current topology epoch.
    fn current_epoch(&self) -> Result<u64, OpsError>;

    /// Add a registry device as a member; returns
    /// `(member id, new epoch)`.
    fn add_member_by_id(&self, device: &str, policy: &TilePolicy) -> Result<(u64, u64), OpsError>;

    /// Remove a member; returns the new epoch.
    fn remove_member_by_id(&self, device: &str, mode: DrainMode) -> Result<u64, OpsError>;

    /// Stop admissions to a member without removing it; returns the new
    /// epoch.
    fn drain_member(&self, device: &str) -> Result<u64, OpsError>;

    /// Hot-swap a member's tuned tile from a fresh outcome; returns the
    /// tile now in effect (`None` if the outcome had no tile for it).
    fn retune_member(
        &self,
        device: &str,
        outcome: &TuningOutcome,
    ) -> Result<Option<TileDim>, OpsError>;

    /// Swap the scheduler by registry name.
    fn set_scheduler_named(&self, name: &str) -> Result<(), OpsError>;

    /// Swap the admission policy by registry name.
    fn set_admission_named(&self, name: &str, timeout: Duration) -> Result<(), OpsError>;

    /// Reconfigure work stealing.
    fn set_stealing(&self, enabled: bool, threshold: usize) -> Result<(), OpsError>;

    /// Snapshot the autoscaler's knobs and counters. Fails with a
    /// "no autoscaler running" [`OpsError::Failed`] when none was
    /// started.
    fn autoscaler_desc(&self) -> Result<AutoscalerDesc, OpsError>;

    /// Apply a partial update to the autoscaler; returns the post-update
    /// state.
    fn apply_autoscaler(&self, update: &AutoscalerUpdate) -> Result<AutoscalerDesc, OpsError>;
}

// ------------------------------------------------------- in process --

/// The in-process implementation of [`FleetOps`] + [`ControlOps`]: a
/// fleet, its controller, a backend factory for `add_member`, and
/// (optionally) the autoscaler handle — the same four things
/// [`NetServer`](crate::net::NetServer) holds, with the same verb
/// semantics.
pub struct LocalFleet {
    fleet: Arc<Fleet>,
    controller: FleetController,
    backends: BackendFactory,
    autoscaler: Option<AutoscalerHandle>,
}

impl LocalFleet {
    /// Wrap a fleet. `backends` builds the execution backend when
    /// [`ControlOps::add_member_by_id`] brings a registry device in.
    pub fn new(fleet: Arc<Fleet>, backends: BackendFactory) -> LocalFleet {
        let controller = fleet.controller();
        LocalFleet {
            fleet,
            controller,
            backends,
            autoscaler: None,
        }
    }

    /// Attach a running autoscaler so the autoscaler verbs resolve.
    pub fn with_autoscaler(mut self, handle: AutoscalerHandle) -> LocalFleet {
        self.autoscaler = Some(handle);
        self
    }

    /// The wrapped fleet.
    pub fn fleet(&self) -> &Arc<Fleet> {
        &self.fleet
    }

    /// The fleet's controller handle.
    pub fn controller(&self) -> &FleetController {
        &self.controller
    }

    fn autoscaler_handle(&self) -> Result<&AutoscalerHandle, OpsError> {
        self.autoscaler
            .as_ref()
            .ok_or_else(|| OpsError::Failed("no autoscaler running".into()))
    }
}

impl TicketOps for Ticket {
    fn ticket_id(&self) -> u64 {
        self.id
    }

    fn device_id(&self) -> Option<&str> {
        Ticket::device_id(self)
    }

    fn wait(self) -> Result<Image<f32>, OpsError> {
        Ticket::wait(self).map_err(local_err)
    }

    fn wait_timeout(&self, budget: Duration) -> Result<Option<Image<f32>>, OpsError> {
        Ticket::wait_timeout(self, budget).map_err(local_err)
    }

    fn try_wait(&self) -> Result<Option<Image<f32>>, OpsError> {
        Ticket::try_wait(self).map_err(local_err)
    }

    fn cancel(&self) -> Result<(), OpsError> {
        Ticket::cancel(self);
        Ok(())
    }
}

impl FleetOps for LocalFleet {
    type Ticket = Ticket;

    fn submit_request(&self, req: Request) -> Result<Ticket, OpsError> {
        self.fleet.submit(req).map_err(OpsError::Submit)
    }

    fn fleet_stats(&self) -> Result<WireStats, OpsError> {
        Ok(WireStats::of(&self.fleet.stats()))
    }
}

impl ControlOps for LocalFleet {
    fn topology_desc(&self) -> Result<TopologyDesc, OpsError> {
        Ok(TopologyDesc::of(&self.controller.topology()))
    }

    fn current_epoch(&self) -> Result<u64, OpsError> {
        Ok(self.controller.epoch())
    }

    fn add_member_by_id(&self, device: &str, policy: &TilePolicy) -> Result<(u64, u64), OpsError> {
        // Same lookup + message as the wire server's add_member verb.
        let desc = crate::device::find_device(device)
            .ok_or_else(|| OpsError::Failed(format!("no device '{device}' in the registry")))?;
        let backend = (self.backends)(&desc);
        let member = self
            .controller
            .add_member(desc, backend, policy.clone())
            .map_err(local_err)?;
        Ok((member, self.controller.epoch()))
    }

    fn remove_member_by_id(&self, device: &str, mode: DrainMode) -> Result<u64, OpsError> {
        self.controller
            .remove_member(device, mode)
            .map_err(local_err)?;
        Ok(self.controller.epoch())
    }

    fn drain_member(&self, device: &str) -> Result<u64, OpsError> {
        self.controller.drain(device).map_err(local_err)?;
        Ok(self.controller.epoch())
    }

    fn retune_member(
        &self,
        device: &str,
        outcome: &TuningOutcome,
    ) -> Result<Option<TileDim>, OpsError> {
        self.controller.retune(device, outcome).map_err(local_err)
    }

    fn set_scheduler_named(&self, name: &str) -> Result<(), OpsError> {
        self.controller.set_scheduler_by_name(name).map_err(local_err)
    }

    fn set_admission_named(&self, name: &str, timeout: Duration) -> Result<(), OpsError> {
        self.controller
            .set_admission_by_name(name, timeout)
            .map_err(local_err)
    }

    fn set_stealing(&self, enabled: bool, threshold: usize) -> Result<(), OpsError> {
        self.controller
            .set_steal_config(enabled, threshold)
            .map_err(local_err)
    }

    fn autoscaler_desc(&self) -> Result<AutoscalerDesc, OpsError> {
        Ok(AutoscalerDesc::of(&self.autoscaler_handle()?.view()))
    }

    fn apply_autoscaler(&self, update: &AutoscalerUpdate) -> Result<AutoscalerDesc, OpsError> {
        let handle = self.autoscaler_handle()?;
        handle.apply(update).map_err(local_err)?;
        Ok(AutoscalerDesc::of(&handle.view()))
    }
}

// ---------------------------------------------------------- remote --

impl TicketOps for RemoteTicket {
    fn ticket_id(&self) -> u64 {
        self.id()
    }

    fn device_id(&self) -> Option<&str> {
        RemoteTicket::device_id(self)
    }

    fn wait(self) -> Result<Image<f32>, OpsError> {
        RemoteTicket::wait(self).map_err(OpsError::from)
    }

    fn wait_timeout(&self, budget: Duration) -> Result<Option<Image<f32>>, OpsError> {
        RemoteTicket::wait_timeout(self, budget).map_err(OpsError::from)
    }

    fn try_wait(&self) -> Result<Option<Image<f32>>, OpsError> {
        RemoteTicket::try_wait(self).map_err(OpsError::from)
    }

    fn cancel(&self) -> Result<(), OpsError> {
        RemoteTicket::cancel(self).map_err(OpsError::from)
    }
}

impl FleetOps for FleetClient {
    type Ticket = RemoteTicket;

    fn submit_request(&self, req: Request) -> Result<RemoteTicket, OpsError> {
        self.submit(&req).map_err(OpsError::from)
    }

    fn fleet_stats(&self) -> Result<WireStats, OpsError> {
        self.stats().map_err(OpsError::from)
    }
}

impl ControlOps for FleetClient {
    fn topology_desc(&self) -> Result<TopologyDesc, OpsError> {
        self.topology().map_err(OpsError::from)
    }

    fn current_epoch(&self) -> Result<u64, OpsError> {
        self.epoch().map_err(OpsError::from)
    }

    fn add_member_by_id(&self, device: &str, policy: &TilePolicy) -> Result<(u64, u64), OpsError> {
        self.add_member(device, policy).map_err(OpsError::from)
    }

    fn remove_member_by_id(&self, device: &str, mode: DrainMode) -> Result<u64, OpsError> {
        self.remove_member(device, mode).map_err(OpsError::from)
    }

    fn drain_member(&self, device: &str) -> Result<u64, OpsError> {
        self.drain(device).map_err(OpsError::from)
    }

    fn retune_member(
        &self,
        device: &str,
        outcome: &TuningOutcome,
    ) -> Result<Option<TileDim>, OpsError> {
        self.retune(device, outcome).map_err(OpsError::from)
    }

    fn set_scheduler_named(&self, name: &str) -> Result<(), OpsError> {
        self.set_scheduler(name).map_err(OpsError::from)
    }

    fn set_admission_named(&self, name: &str, timeout: Duration) -> Result<(), OpsError> {
        self.set_admission(name, timeout).map_err(OpsError::from)
    }

    fn set_stealing(&self, enabled: bool, threshold: usize) -> Result<(), OpsError> {
        self.set_steal_config(enabled, threshold)
            .map_err(OpsError::from)
    }

    fn autoscaler_desc(&self) -> Result<AutoscalerDesc, OpsError> {
        self.autoscaler().map_err(OpsError::from)
    }

    fn apply_autoscaler(&self, update: &AutoscalerUpdate) -> Result<AutoscalerDesc, OpsError> {
        self.set_autoscaler(update).map_err(OpsError::from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::coordinator::FleetBuilder;
    use crate::device::{find_device, DeviceDescriptor};
    use crate::image::{generate, Interpolator};
    use crate::runtime::{Manifest, MockEngine, ResizeBackend};

    fn mock_factory() -> BackendFactory {
        Arc::new(|_d: &DeviceDescriptor| Arc::new(MockEngine::new()) as Arc<dyn ResizeBackend>)
    }

    fn local() -> LocalFleet {
        let serving = ServingConfig {
            workers: 1,
            batch_max: Some(4),
            batch_deadline_ms: 0.5,
            queue_cap: 64,
            ..ServingConfig::default()
        };
        let fleet = FleetBuilder::new(&serving, &Manifest::fleet_demo())
            .device(
                find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::Fixed(TileDim::new(16, 8)),
            )
            .build()
            .unwrap();
        LocalFleet::new(Arc::new(fleet), mock_factory())
    }

    #[test]
    fn local_fleet_serves_through_the_traits() {
        let ops = local();
        let img = generate::test_scene(64, 64, 1);
        let ticket = ops
            .submit_request(Request::new(Interpolator::Bilinear, img, 2))
            .unwrap();
        assert!(TicketOps::device_id(&ticket).is_some());
        let out = TicketOps::wait(ticket).unwrap();
        assert_eq!(out.width(), 128);
        assert!(ops.fleet_stats().unwrap().completed >= 1);
    }

    #[test]
    fn local_fleet_mirrors_the_wire_control_plane() {
        let ops = local();
        let before = ops.topology_desc().unwrap();
        assert_eq!(before.members.len(), 1);
        assert_eq!(ops.current_epoch().unwrap(), before.epoch);

        let (member, epoch) = ops
            .add_member_by_id("fermi", &TilePolicy::Fixed(TileDim::new(16, 8)))
            .unwrap();
        assert!(epoch > before.epoch, "membership bumps the epoch");
        let topo = ops.topology_desc().unwrap();
        assert!(topo.members.iter().any(|m| m.id == member));

        ops.drain_member("fermi").unwrap();
        let epoch2 = ops
            .remove_member_by_id("fermi", DrainMode::Graceful)
            .unwrap();
        assert!(epoch2 > epoch);

        ops.set_scheduler_named("least-loaded").unwrap();
        ops.set_admission_named("block", Duration::from_millis(50))
            .unwrap();
        ops.set_stealing(false, 4).unwrap();
        assert!(ops.set_scheduler_named("no-such-scheduler").is_err());
    }

    #[test]
    fn unknown_devices_and_missing_autoscaler_fail_like_the_server() {
        let ops = local();
        let err = ops
            .add_member_by_id("not-a-gpu", &TilePolicy::Fixed(TileDim::new(16, 8)))
            .unwrap_err();
        assert!(
            err.to_string().contains("no device 'not-a-gpu' in the registry"),
            "got: {err}"
        );
        let err = ops.autoscaler_desc().unwrap_err();
        assert!(err.to_string().contains("no autoscaler running"), "{err}");
        let err = ops
            .apply_autoscaler(&AutoscalerUpdate::default())
            .unwrap_err();
        assert!(err.to_string().contains("no autoscaler running"), "{err}");
    }

    #[test]
    fn typed_refusals_survive_the_trait_boundary() {
        let ops = local();
        // The demo manifest has no bicubic artifact.
        let img = generate::test_scene(64, 64, 2);
        let err = ops
            .submit_request(Request::new(Interpolator::Bicubic, img, 2))
            .unwrap_err();
        assert_eq!(err.submit_error(), Some(SubmitError::Unsupported));
        assert!(err.to_string().contains("fleet refused submit"));
    }
}
