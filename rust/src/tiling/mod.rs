//! Tiling: the paper's tuning knob.
//!
//! * [`dims`] — the [`TileDim`](dims::TileDim) type (a CUDA thread-block /
//!   Pallas output-tile shape) and validity rules per compute capability.
//! * [`enumerate`] — generation of candidate tile sets (the sweep axis of
//!   the paper's Fig. 3).
//! * [`occupancy`] — a CUDA occupancy calculator: resident blocks per SM
//!   limited by threads, warps, registers, shared memory, and the
//!   max-blocks cap; reproduces the §III.B 32×16 occupancy cliff.

pub mod dims;
pub mod enumerate;
pub mod occupancy;
pub mod thread_tile;

pub use dims::TileDim;
pub use enumerate::{paper_sweep_tiles, pow2_tiles, TileFilter};
pub use occupancy::{occupancy, KernelResources, Occupancy};
pub use thread_tile::{thread_tile_candidates, ThreadTile, Tiling};
