//! Candidate-tile enumeration: the x-axis of the paper's Fig. 3.
//!
//! The paper sweeps power-of-two block shapes between one warp (32
//! threads) and the 512-thread block cap. [`paper_sweep_tiles`] generates
//! that set in a deterministic order; [`pow2_tiles`] is the generic
//! generator with a thread-count window and shape filter.

use super::dims::TileDim;
use crate::device::ComputeCapability;

/// Shape filters for enumeration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileFilter {
    /// Every valid shape in the window.
    All,
    /// Only shapes with x ≥ y (row-friendly; excludes tall-narrow tiles).
    WideOrSquare,
    /// Only full-warp shapes (threads divisible by the warp size) — what a
    /// CUDA programmer would actually launch.
    FullWarps,
}

/// All power-of-two tiles `x`×`y` with `min_threads ≤ x·y ≤ max_threads`
/// that are valid on `cc` and pass `filter`. Sorted by total threads then
/// by descending aspect, so sweeps print in a stable, paper-like order
/// (… 32x4 before 4x32 …).
pub fn pow2_tiles(
    cc: &ComputeCapability,
    min_threads: u32,
    max_threads: u32,
    filter: TileFilter,
) -> Vec<TileDim> {
    let mut out = Vec::new();
    let mut x = 1u32;
    while x <= cc.max_block_dim.0 {
        let mut y = 1u32;
        while y <= cc.max_block_dim.1 {
            let t = TileDim::new(x, y);
            let n = t.threads();
            if n >= min_threads && n <= max_threads && t.is_valid(cc) {
                let keep = match filter {
                    TileFilter::All => true,
                    TileFilter::WideOrSquare => x >= y,
                    TileFilter::FullWarps => n % cc.warp_size == 0,
                };
                if keep {
                    out.push(t);
                }
            }
            y <<= 1;
        }
        x <<= 1;
    }
    out.sort_by(|a, b| {
        a.threads()
            .cmp(&b.threads())
            .then(b.aspect().partial_cmp(&a.aspect()).unwrap())
    });
    out
}

/// The tile set used for the Fig. 3 reproduction: every power-of-two
/// shape with both dimensions in 4..=32 and 32..=512 threads — the range
/// the paper's figures actually exercise (every tile the text names is a
/// member: 8×8, 32×16, 32×4, 4×8, 8×4; degenerate 1-wide/1-tall shapes
/// and >32 extents do not appear in the study). 14 tiles.
pub fn paper_sweep_tiles() -> Vec<TileDim> {
    pow2_tiles_dims(&ComputeCapability::CC_1_0, 32, 512, 4, 32, TileFilter::FullWarps)
}

/// As [`pow2_tiles`] but additionally bounding each dimension to
/// `[min_dim, max_dim]`.
pub fn pow2_tiles_dims(
    cc: &ComputeCapability,
    min_threads: u32,
    max_threads: u32,
    min_dim: u32,
    max_dim: u32,
    filter: TileFilter,
) -> Vec<TileDim> {
    pow2_tiles(cc, min_threads, max_threads, filter)
        .into_iter()
        .filter(|t| {
            t.x >= min_dim && t.x <= max_dim && t.y >= min_dim && t.y <= max_dim
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_tiles_include_named_shapes() {
        let tiles = paper_sweep_tiles();
        for name in ["8x8", "32x16", "32x4", "4x8", "8x4", "16x16"] {
            let t: TileDim = name.parse().unwrap();
            assert!(tiles.contains(&t), "{name} missing from sweep");
        }
    }

    #[test]
    fn paper_tiles_all_valid_on_both_devices() {
        use crate::device::paper_pair;
        let (gtx, gts) = paper_pair();
        for t in paper_sweep_tiles() {
            assert!(t.is_valid(&gtx.cc), "{t} invalid on gtx260");
            assert!(t.is_valid(&gts.cc), "{t} invalid on 8800gts");
        }
    }

    #[test]
    fn window_respected() {
        let tiles = pow2_tiles(&ComputeCapability::CC_1_3, 64, 128, TileFilter::All);
        assert!(!tiles.is_empty());
        for t in &tiles {
            assert!((64..=128).contains(&t.threads()), "{t}");
        }
    }

    #[test]
    fn full_warp_filter() {
        let tiles = pow2_tiles(&ComputeCapability::CC_1_0, 1, 512, TileFilter::FullWarps);
        for t in &tiles {
            assert_eq!(t.threads() % 32, 0, "{t} is not a whole-warp tile");
        }
        // 4x4 = 16 threads must be excluded, 8x4 = 32 included
        assert!(!tiles.contains(&TileDim::new(4, 4)));
        assert!(tiles.contains(&TileDim::new(8, 4)));
    }

    #[test]
    fn wide_or_square_filter() {
        let tiles = pow2_tiles(
            &ComputeCapability::CC_1_0,
            32,
            512,
            TileFilter::WideOrSquare,
        );
        for t in &tiles {
            assert!(t.x >= t.y, "{t} is taller than wide");
        }
    }

    #[test]
    fn deterministic_order() {
        let a = paper_sweep_tiles();
        let b = paper_sweep_tiles();
        assert_eq!(a, b);
        // stable order: ascending thread count
        for w in a.windows(2) {
            assert!(w[0].threads() <= w[1].threads());
        }
    }

    #[test]
    fn no_duplicates() {
        let tiles = paper_sweep_tiles();
        let mut sorted = tiles.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), tiles.len());
    }
}
