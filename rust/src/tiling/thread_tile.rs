//! Thread-level tiling — the "deeper" tiling the paper names but never
//! explores (§III.A: "There are, in fact, two kinds of tiling
//! techniques, block level tiling and the deeper thread level tiling").
//!
//! With a thread tile of (ty, tx), each thread computes `ty × tx` output
//! pixels instead of one. Consequences modeled here and in the
//! simulator:
//!
//! * the grid shrinks by `ty·tx` (fewer blocks → fewer scheduling
//!   rounds),
//! * each thread's instruction count multiplies by the pixels it owns
//!   (plus loop overhead unless fully unrolled),
//! * registers per thread grow with live pixel state (occupancy may
//!   drop — the classic ILP-vs-TLP trade),
//! * the block's data footprint grows: a (by,bx) block with (ty,tx)
//!   thread tiles covers `(by·ty) × (bx·tx)` output pixels, changing
//!   the row-crossing count exactly like a taller/wider block tile.

use super::dims::TileDim;
use crate::device::ComputeCapability;

/// A thread-level tile: pixels computed per thread along y and x.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ThreadTile {
    pub y: u32,
    pub x: u32,
}

impl ThreadTile {
    pub const ONE: ThreadTile = ThreadTile { y: 1, x: 1 };

    pub const fn new(y: u32, x: u32) -> ThreadTile {
        ThreadTile { y, x }
    }

    /// Pixels per thread.
    pub fn pixels(&self) -> u32 {
        self.x * self.y
    }

    pub fn label(&self) -> String {
        format!("{}x{}pt", self.x, self.y)
    }
}

/// A combined (block, thread) tiling: the full design point of §III.A.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tiling {
    /// Thread-block shape (threads).
    pub block: TileDim,
    /// Pixels per thread.
    pub per_thread: ThreadTile,
}

impl Tiling {
    /// Pure block-level tiling (the paper's experiments).
    pub fn block_only(block: TileDim) -> Tiling {
        Tiling {
            block,
            per_thread: ThreadTile::ONE,
        }
    }

    /// Output-pixel footprint of one block: block dims × thread tile.
    pub fn footprint(&self) -> TileDim {
        TileDim::new(
            self.block.x * self.per_thread.x,
            self.block.y * self.per_thread.y,
        )
    }

    /// Blocks needed to cover a w×h output.
    pub fn blocks_for(&self, w: u32, h: u32) -> u64 {
        self.footprint().blocks_for(w, h)
    }

    /// Launchable under `cc`? (Block validity; footprint is uncapped.)
    pub fn is_valid(&self, cc: &ComputeCapability) -> bool {
        self.block.is_valid(cc) && self.per_thread.pixels() >= 1
    }

    /// Registers per thread for a base kernel cost: each extra owned
    /// pixel keeps ~2 extra values live (accumulator + coordinate) on
    /// top of the shared address math.
    pub fn regs_per_thread(&self, base_regs: u32) -> u32 {
        base_regs + 2 * (self.per_thread.pixels().saturating_sub(1))
    }

    /// Instructions per thread: owned pixels × per-pixel cost, plus loop
    /// overhead of ~2 slots per non-unrolled iteration beyond the first.
    pub fn instrs_per_thread(&self, base_instrs: u32, unrolled: bool) -> u32 {
        let p = self.per_thread.pixels();
        let loop_overhead = if unrolled || p == 1 { 0 } else { 2 * p };
        base_instrs * p + loop_overhead
    }

    pub fn label(&self) -> String {
        if self.per_thread == ThreadTile::ONE {
            self.block.label()
        } else {
            format!("{}+{}", self.block.label(), self.per_thread.label())
        }
    }
}

/// Candidate thread tiles for the extension sweep: 1, 2 and 4 pixels
/// per thread in each axis arrangement.
pub fn thread_tile_candidates() -> Vec<ThreadTile> {
    vec![
        ThreadTile::new(1, 1),
        ThreadTile::new(1, 2),
        ThreadTile::new(2, 1),
        ThreadTile::new(2, 2),
        ThreadTile::new(1, 4),
        ThreadTile::new(4, 1),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ComputeCapability;

    #[test]
    fn footprint_multiplies() {
        let t = Tiling {
            block: TileDim::new(32, 4),
            per_thread: ThreadTile::new(2, 2),
        };
        assert_eq!(t.footprint(), TileDim::new(64, 8));
        assert_eq!(t.blocks_for(1600, 1600), (1600 / 64) * (1600 / 8));
    }

    #[test]
    fn block_only_is_identity() {
        let t = Tiling::block_only(TileDim::new(16, 8));
        assert_eq!(t.footprint(), TileDim::new(16, 8));
        assert_eq!(t.label(), "16x8");
    }

    #[test]
    fn regs_and_instrs_grow_with_pixels() {
        let t = Tiling {
            block: TileDim::new(32, 4),
            per_thread: ThreadTile::new(2, 2),
        };
        assert_eq!(t.regs_per_thread(10), 16);
        assert_eq!(t.instrs_per_thread(30, true), 120);
        assert_eq!(t.instrs_per_thread(30, false), 128); // + loop overhead
        let one = Tiling::block_only(TileDim::new(32, 4));
        assert_eq!(one.regs_per_thread(10), 10);
        assert_eq!(one.instrs_per_thread(30, false), 30);
    }

    #[test]
    fn validity_follows_block() {
        let cc = ComputeCapability::CC_1_3;
        assert!(Tiling {
            block: TileDim::new(32, 16),
            per_thread: ThreadTile::new(4, 4),
        }
        .is_valid(&cc));
        assert!(!Tiling {
            block: TileDim::new(32, 32),
            per_thread: ThreadTile::ONE,
        }
        .is_valid(&cc));
    }

    #[test]
    fn candidates_unique_and_start_at_one() {
        let c = thread_tile_candidates();
        assert_eq!(c[0], ThreadTile::ONE);
        let mut s = c.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), c.len());
    }

    #[test]
    fn label_format() {
        let t = Tiling {
            block: TileDim::new(32, 4),
            per_thread: ThreadTile::new(1, 2),
        };
        assert_eq!(t.label(), "32x4+2x1pt");
    }
}
