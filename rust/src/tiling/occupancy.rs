//! The CUDA occupancy calculator.
//!
//! Given a tile (block) shape, a kernel's per-thread register count and
//! per-block shared memory, and a device's compute capability, compute how
//! many blocks are simultaneously *resident* on one SM and the resulting
//! occupancy (resident warps / max warps). This is the spreadsheet NVIDIA
//! shipped as `CUDA_Occupancy_calculator.xls`, as a library.
//!
//! The paper's §III.B scenario falls out directly: a 32×16 tile (512
//! threads) gives 2 resident blocks = 1024 threads = 100% occupancy on the
//! GTX 260 (cc1.3) but only 1 block = 512/768 = 66% on the 8800 GTS
//! (cc1.0).

use super::dims::TileDim;
use crate::device::ComputeCapability;

/// Per-kernel resource usage that constrains residency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelResources {
    /// Registers per thread (as reported by `nvcc --ptxas-options=-v`).
    pub regs_per_thread: u32,
    /// Static + dynamic shared memory per block, bytes.
    pub smem_per_block: u32,
}

impl KernelResources {
    /// Resource profile of the bilinear-interpolation kernel: the paper's
    /// kernel is arithmetic-light (coordinate math + 4 loads + 1 store);
    /// nvcc for cc1.x allocates ~10 registers and no shared memory.
    pub const BILINEAR: KernelResources = KernelResources {
        regs_per_thread: 10,
        smem_per_block: 0,
    };

    /// Nearest-neighbour: fewer temporaries.
    pub const NEAREST: KernelResources = KernelResources {
        regs_per_thread: 6,
        smem_per_block: 0,
    };

    /// Bicubic (Catmull-Rom, 16 taps): register-hungry.
    pub const BICUBIC: KernelResources = KernelResources {
        regs_per_thread: 24,
        smem_per_block: 0,
    };
}

/// The outcome of the occupancy computation for one (tile, kernel, cc).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Occupancy {
    /// Blocks resident per SM (0 if the tile cannot launch at all).
    pub blocks_per_sm: u32,
    /// Resident warps per SM.
    pub warps_per_sm: u32,
    /// Resident threads per SM.
    pub threads_per_sm: u32,
    /// warps_per_sm / cc.max_warps_per_sm ∈ [0, 1].
    pub ratio: f64,
    /// Which resource clamped residency.
    pub limiter: Limiter,
}

/// The binding constraint on residency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Limiter {
    /// Tile invalid on this capability (too many threads / dim overflow).
    Invalid,
    /// max_threads_per_sm (or equivalently max warps).
    ThreadsOrWarps,
    /// Register file exhausted.
    Registers,
    /// Shared memory exhausted.
    SharedMem,
    /// The 8-blocks-per-SM architectural cap.
    BlockSlots,
}

impl Limiter {
    pub fn label(self) -> &'static str {
        match self {
            Limiter::Invalid => "invalid-tile",
            Limiter::ThreadsOrWarps => "threads/warps",
            Limiter::Registers => "registers",
            Limiter::SharedMem => "shared-mem",
            Limiter::BlockSlots => "block-slots",
        }
    }
}

/// Registers consumed by one block after the per-block allocation
/// granularity round-up (cc1.x allocates registers block-wise in units of
/// `register_alloc_unit`).
fn regs_per_block(tile: TileDim, res: &KernelResources, cc: &ComputeCapability) -> u32 {
    let raw = res.regs_per_thread * tile.threads();
    raw.div_ceil(cc.register_alloc_unit) * cc.register_alloc_unit
}

/// Compute occupancy of `tile` running `res` on capability `cc`.
pub fn occupancy(tile: TileDim, res: &KernelResources, cc: &ComputeCapability) -> Occupancy {
    if !tile.is_valid(cc) {
        return Occupancy {
            blocks_per_sm: 0,
            warps_per_sm: 0,
            threads_per_sm: 0,
            ratio: 0.0,
            limiter: Limiter::Invalid,
        };
    }
    let warps_per_block = tile.warps(cc.warp_size);

    // Candidate limits. Each is "how many blocks could fit considering
    // only this resource".
    let by_threads = cc.max_threads_per_sm / tile.threads();
    let by_warps = cc.max_warps_per_sm / warps_per_block;
    let rpb = regs_per_block(tile, res, cc);
    let by_regs = if rpb == 0 {
        u32::MAX
    } else {
        cc.registers_per_sm / rpb
    };
    let by_smem = if res.smem_per_block == 0 {
        u32::MAX
    } else {
        cc.shared_mem_per_sm / res.smem_per_block
    };
    let by_slots = cc.max_blocks_per_sm;

    let blocks = by_threads
        .min(by_warps)
        .min(by_regs)
        .min(by_smem)
        .min(by_slots);

    // Attribute the limiter (ties resolved in the order the hardware
    // documentation lists them; Invalid handled above, blocks==0 means
    // a single block over-subscribes a resource).
    let limiter = if blocks == by_threads.min(by_warps) && blocks < by_regs.min(by_smem).min(by_slots) {
        Limiter::ThreadsOrWarps
    } else if blocks == by_regs && by_regs < by_threads.min(by_warps).min(by_smem).min(by_slots) {
        Limiter::Registers
    } else if blocks == by_smem && by_smem < by_threads.min(by_warps).min(by_regs).min(by_slots) {
        Limiter::SharedMem
    } else if blocks == by_slots && by_slots < by_threads.min(by_warps).min(by_regs).min(by_smem) {
        Limiter::BlockSlots
    } else {
        // Multiple constraints tie; report the threads/warps family as the
        // canonical one (it is what the paper reasons about).
        Limiter::ThreadsOrWarps
    };

    let warps = blocks * warps_per_block;
    Occupancy {
        blocks_per_sm: blocks,
        warps_per_sm: warps,
        threads_per_sm: blocks * tile.threads(),
        ratio: warps as f64 / cc.max_warps_per_sm as f64,
        limiter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ComputeCapability;

    const CC13: ComputeCapability = ComputeCapability::CC_1_3;
    const CC10: ComputeCapability = ComputeCapability::CC_1_0;

    #[test]
    fn paper_section_3b_scenario() {
        // "he perhaps sets the tiling dimensions as 32x16 ... each SM can
        // have the maximum number of active threads of 1024 within 2
        // blocks. But ... on the GeForce 8800 GTS ... only one block which
        // includes 512 threads can be placed into each SM."
        let tile = TileDim::new(32, 16);
        let on_gtx = occupancy(tile, &KernelResources::BILINEAR, &CC13);
        assert_eq!(on_gtx.blocks_per_sm, 2);
        assert_eq!(on_gtx.threads_per_sm, 1024);
        assert!((on_gtx.ratio - 1.0).abs() < 1e-12);

        let on_gts = occupancy(tile, &KernelResources::BILINEAR, &CC10);
        assert_eq!(on_gts.blocks_per_sm, 1);
        assert_eq!(on_gts.threads_per_sm, 512);
        assert!((on_gts.ratio - 512.0 / 768.0).abs() < 1e-12);
    }

    #[test]
    fn tile_32x4_reaches_full_occupancy_on_both() {
        // 32x4 = 128 threads, 4 warps. GTX260: 8 blocks (slot cap) = 1024
        // threads = 100%. 8800GTS: 6 blocks = 768 threads = 100%.
        let tile = TileDim::new(32, 4);
        let a = occupancy(tile, &KernelResources::BILINEAR, &CC13);
        assert_eq!(a.blocks_per_sm, 8);
        assert!((a.ratio - 1.0).abs() < 1e-12);
        let b = occupancy(tile, &KernelResources::BILINEAR, &CC10);
        assert_eq!(b.blocks_per_sm, 6);
        assert!((b.ratio - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_warp_tile_hits_block_slot_cap() {
        // 8x4 = 32 threads. 8 blocks max ⇒ 8 warps of 32 possible ⇒ 25%
        // occupancy on cc1.3, 33% on cc1.0: small tiles under-fill SMs.
        let tile = TileDim::new(8, 4);
        let a = occupancy(tile, &KernelResources::BILINEAR, &CC13);
        assert_eq!(a.blocks_per_sm, 8);
        assert_eq!(a.limiter, Limiter::BlockSlots);
        assert!((a.ratio - 0.25).abs() < 1e-12);
        let b = occupancy(tile, &KernelResources::BILINEAR, &CC10);
        assert_eq!(b.blocks_per_sm, 8);
        assert!((b.ratio - 8.0 / 24.0).abs() < 1e-12);
    }

    #[test]
    fn register_pressure_limits_bicubic_on_cc10() {
        // Bicubic at 24 regs/thread, 16x16 tile = 256 threads ⇒ 6144 regs
        // + round-up ⇒ only 1 block on cc1.0 (8192 regs), vs threads would
        // allow 3 fitting warps-wise... registers bind.
        let tile = TileDim::new(16, 16);
        let occ = occupancy(tile, &KernelResources::BICUBIC, &CC10);
        assert_eq!(occ.blocks_per_sm, 1);
        assert_eq!(occ.limiter, Limiter::Registers);
        // Same tile+kernel on cc1.3 (16384 regs): 2 blocks.
        let occ13 = occupancy(tile, &KernelResources::BICUBIC, &CC13);
        assert_eq!(occ13.blocks_per_sm, 2);
    }

    #[test]
    fn smem_limit_binds_when_large() {
        let res = KernelResources {
            regs_per_thread: 4,
            smem_per_block: 9 * 1024, // two blocks would need 18K > 16K
        };
        let occ = occupancy(TileDim::new(16, 8), &res, &CC13);
        assert_eq!(occ.blocks_per_sm, 1);
        assert_eq!(occ.limiter, Limiter::SharedMem);
    }

    #[test]
    fn invalid_tile_is_zero() {
        let occ = occupancy(TileDim::new(32, 32), &KernelResources::BILINEAR, &CC13);
        assert_eq!(occ.blocks_per_sm, 0);
        assert_eq!(occ.ratio, 0.0);
        assert_eq!(occ.limiter, Limiter::Invalid);
    }

    #[test]
    fn occupancy_never_exceeds_caps() {
        // exhaustive check over the paper sweep on all builtin devices
        use crate::device::builtin_devices;
        use crate::tiling::enumerate::paper_sweep_tiles;
        for d in builtin_devices() {
            for t in paper_sweep_tiles() {
                for res in [
                    KernelResources::BILINEAR,
                    KernelResources::NEAREST,
                    KernelResources::BICUBIC,
                ] {
                    let o = occupancy(t, &res, &d.cc);
                    assert!(o.threads_per_sm <= d.cc.max_threads_per_sm);
                    assert!(o.warps_per_sm <= d.cc.max_warps_per_sm);
                    assert!(o.blocks_per_sm <= d.cc.max_blocks_per_sm);
                    assert!(o.ratio <= 1.0 + 1e-12);
                    let rpb = super::regs_per_block(t, &res, &d.cc);
                    assert!(o.blocks_per_sm * rpb <= d.cc.registers_per_sm);
                }
            }
        }
    }

    #[test]
    fn register_roundup_granularity() {
        // 10 regs × 128 threads = 1280 → rounds to 1536 on cc1.3 (unit 512)
        assert_eq!(
            super::regs_per_block(TileDim::new(32, 4), &KernelResources::BILINEAR, &CC13),
            1536
        );
        // and to 1280 on cc1.0 (unit 256)
        assert_eq!(
            super::regs_per_block(TileDim::new(32, 4), &KernelResources::BILINEAR, &CC10),
            1280
        );
    }
}
