//! Tile (thread-block) dimensions.
//!
//! The paper writes tiles as `WxH` (e.g. "32x4" = 32 threads along x /
//! image width, 4 along y / rows). We keep that convention: `x` is the
//! fast, row-contiguous axis; `y` counts rows covered by the block —
//! exactly the quantity Fig. 4 cares about (row crossings per block).

use crate::device::ComputeCapability;
use std::fmt;
use std::str::FromStr;

/// A 2-D tile shape (z fixed at 1; the paper only sweeps 2-D tiles, and
/// image kernels have no use for a depth axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TileDim {
    /// Extent along the image row (CUDA blockDim.x).
    pub x: u32,
    /// Extent across rows (CUDA blockDim.y).
    pub y: u32,
}

impl TileDim {
    pub const fn new(x: u32, y: u32) -> TileDim {
        TileDim { x, y }
    }

    /// Threads per block.
    pub fn threads(&self) -> u32 {
        self.x * self.y
    }

    /// Warps per block (ceil over the warp size).
    pub fn warps(&self, warp_size: u32) -> u32 {
        self.threads().div_ceil(warp_size)
    }

    /// Is this tile launchable under the given compute capability?
    pub fn is_valid(&self, cc: &ComputeCapability) -> bool {
        self.x >= 1
            && self.y >= 1
            && self.x <= cc.max_block_dim.0
            && self.y <= cc.max_block_dim.1
            && self.threads() <= cc.max_threads_per_block
    }

    /// Number of blocks needed to cover a `w`×`h` output image (the CUDA
    /// grid size, eq. (6) of the paper solved for block counts).
    pub fn grid_for(&self, w: u32, h: u32) -> (u32, u32) {
        (w.div_ceil(self.x), h.div_ceil(self.y))
    }

    /// Total blocks covering a `w`×`h` output.
    pub fn blocks_for(&self, w: u32, h: u32) -> u64 {
        let (gx, gy) = self.grid_for(w, h);
        gx as u64 * gy as u64
    }

    /// The paper's label format, `32x4`.
    pub fn label(&self) -> String {
        format!("{}x{}", self.x, self.y)
    }

    /// Aspect preference used in tie-breaks: wider-than-tall first (the
    /// row-friendly shapes the paper recommends).
    pub fn aspect(&self) -> f64 {
        self.x as f64 / self.y as f64
    }
}

impl fmt::Display for TileDim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.x, self.y)
    }
}

/// Parse `"32x4"` / `"32X4"` / `"32,4"`.
impl FromStr for TileDim {
    type Err = String;
    fn from_str(s: &str) -> Result<TileDim, String> {
        let norm = s.trim().to_ascii_lowercase().replace(',', "x");
        let (xs, ys) = norm
            .split_once('x')
            .ok_or_else(|| format!("tile '{s}' must look like 32x4"))?;
        let x: u32 = xs
            .trim()
            .parse()
            .map_err(|_| format!("bad tile width in '{s}'"))?;
        let y: u32 = ys
            .trim()
            .parse()
            .map_err(|_| format!("bad tile height in '{s}'"))?;
        if x == 0 || y == 0 {
            return Err(format!("tile dims must be positive in '{s}'"));
        }
        Ok(TileDim::new(x, y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ComputeCapability;

    #[test]
    fn threads_and_warps() {
        let t = TileDim::new(32, 4);
        assert_eq!(t.threads(), 128);
        assert_eq!(t.warps(32), 4);
        // partial warp rounds up
        assert_eq!(TileDim::new(8, 4).warps(32), 1);
        assert_eq!(TileDim::new(33, 1).warps(32), 2);
    }

    #[test]
    fn validity_per_cc() {
        let cc13 = ComputeCapability::CC_1_3;
        assert!(TileDim::new(32, 16).is_valid(&cc13)); // 512 = max
        assert!(!TileDim::new(32, 17).is_valid(&cc13)); // 544 > 512
        assert!(!TileDim::new(513, 1).is_valid(&cc13)); // x over dim cap
        assert!(TileDim::new(1, 512).is_valid(&cc13));
        let cc20 = ComputeCapability::CC_2_0;
        assert!(TileDim::new(32, 32).is_valid(&cc20)); // 1024 ok on Fermi
        assert!(!TileDim::new(32, 32).is_valid(&cc13));
    }

    #[test]
    fn grid_covering_paper_example() {
        // Fig. 2: 8x8 blocks over a 16-wide image put pixel (10,4) in
        // block (1,0) — grid must be at least 2 wide.
        let t = TileDim::new(8, 8);
        let (gx, gy) = t.grid_for(16, 8);
        assert_eq!((gx, gy), (2, 1));
        // 800x800 at scale 2 → 1600x1600 output with 32x4 tiles:
        let t = TileDim::new(32, 4);
        assert_eq!(t.grid_for(1600, 1600), (50, 400));
        assert_eq!(t.blocks_for(1600, 1600), 20_000);
        // non-divisible sizes round up
        assert_eq!(TileDim::new(32, 4).grid_for(33, 5), (2, 2));
    }

    #[test]
    fn parse_formats() {
        assert_eq!("32x4".parse::<TileDim>().unwrap(), TileDim::new(32, 4));
        assert_eq!("16X8".parse::<TileDim>().unwrap(), TileDim::new(16, 8));
        assert_eq!(" 8,8 ".parse::<TileDim>().unwrap(), TileDim::new(8, 8));
        assert!("32".parse::<TileDim>().is_err());
        assert!("0x4".parse::<TileDim>().is_err());
        assert!("axb".parse::<TileDim>().is_err());
    }

    #[test]
    fn display_round_trip() {
        let t = TileDim::new(32, 4);
        assert_eq!(t.to_string().parse::<TileDim>().unwrap(), t);
    }

    #[test]
    fn aspect_orders_wide_tiles_first() {
        assert!(TileDim::new(32, 4).aspect() > TileDim::new(4, 32).aspect());
    }
}
