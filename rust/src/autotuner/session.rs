//! The tuning façade: a builder that runs one search strategy over a
//! device set through a cost model and returns a durable
//! [`TuningOutcome`].
//!
//! ```no_run
//! use tilekit::autotuner::{CoordinateDescent, SimCostModel, TuningSession};
//! use tilekit::device::builtin_devices;
//!
//! let outcome = TuningSession::new(SimCostModel)
//!     .devices(builtin_devices())
//!     .scale(8)
//!     .strategy(CoordinateDescent::default())
//!     .run()?;
//! println!(
//!     "portable tile: {:?} after {} evaluations",
//!     outcome.portable_tile(),
//!     outcome.evaluations
//! );
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! Defaults reproduce the paper's setup exactly: the GTX 260 / 8800 GTS
//! pair, the Fig. 3 power-of-two tile set, bilinear, an 800×800 source,
//! scale 8, and the [`Exhaustive`] strategy.

use super::cost::{CostModel, SimCostModel};
use super::outcome::{DeviceTuning, TuningOutcome};
use super::portable::portable_over;
use super::strategy::{Exhaustive, SearchSpace, SearchStrategy};
use crate::device::{paper_pair, DeviceDescriptor};
use crate::image::Interpolator;
use crate::sim::{Launch, SimReport};
use crate::tiling::{paper_sweep_tiles, TileDim};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Internal per-device evaluation counter (the public, shareable variant
/// is [`CountingCostModel`](super::CountingCostModel)).
struct CountedRef<'a> {
    inner: &'a dyn CostModel,
    count: AtomicU64,
}

impl CostModel for CountedRef<'_> {
    fn evaluate(&self, launch: &Launch, dev: &DeviceDescriptor) -> SimReport {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.evaluate(launch, dev)
    }

    fn name(&self) -> String {
        self.inner.name()
    }
}

/// Builder for one tuning run. See the module docs for an example.
pub struct TuningSession {
    cost: Box<dyn CostModel>,
    devices: Vec<DeviceDescriptor>,
    tiles: Vec<TileDim>,
    kernel: Interpolator,
    scale: u32,
    src: (u32, u32),
    strategy: Box<dyn SearchStrategy>,
}

impl TuningSession {
    /// Start a session over `cost`, with the paper's defaults for
    /// everything else.
    pub fn new(cost: impl CostModel + 'static) -> TuningSession {
        let (gtx, gts) = paper_pair();
        TuningSession {
            cost: Box::new(cost),
            devices: vec![gtx, gts],
            tiles: paper_sweep_tiles(),
            kernel: Interpolator::Bilinear,
            scale: 8,
            src: (800, 800),
            strategy: Box::new(Exhaustive),
        }
    }

    /// Shorthand for a session over the timing simulator.
    pub fn sim() -> TuningSession {
        TuningSession::new(SimCostModel)
    }

    /// Replace the device set.
    pub fn devices(mut self, devs: impl IntoIterator<Item = DeviceDescriptor>) -> TuningSession {
        self.devices = devs.into_iter().collect();
        self
    }

    /// Add one device to the set.
    pub fn device(mut self, dev: DeviceDescriptor) -> TuningSession {
        self.devices.push(dev);
        self
    }

    /// Replace the candidate tile set.
    pub fn tiles(mut self, tiles: impl IntoIterator<Item = TileDim>) -> TuningSession {
        self.tiles = tiles.into_iter().collect();
        self
    }

    /// Kernel to tune.
    pub fn kernel(mut self, kernel: Interpolator) -> TuningSession {
        self.kernel = kernel;
        self
    }

    /// Upscaling factor of the tuned workload.
    pub fn scale(mut self, scale: u32) -> TuningSession {
        self.scale = scale;
        self
    }

    /// Source image size of the tuned workload.
    pub fn src(mut self, src: (u32, u32)) -> TuningSession {
        self.src = src;
        self
    }

    /// Replace the search strategy.
    pub fn strategy(mut self, strategy: impl SearchStrategy + 'static) -> TuningSession {
        self.strategy = Box::new(strategy);
        self
    }

    /// Run the strategy on every device and assemble the outcome (incl.
    /// the min-max-regret portable pick over the device set). Devices
    /// are topped up to the union of tiles any device's search visited,
    /// so portable regrets are always computed over a common pool.
    pub fn run(&self) -> Result<TuningOutcome> {
        if self.devices.is_empty() {
            bail!("tuning session has no devices");
        }
        if self.tiles.is_empty() {
            bail!("tuning session has no candidate tiles");
        }
        let mut per_device = Vec::with_capacity(self.devices.len());
        let mut total = 0u64;
        for dev in &self.devices {
            let counted = CountedRef {
                inner: &*self.cost,
                count: AtomicU64::new(0),
            };
            let space = SearchSpace {
                dev,
                kernel: self.kernel,
                tiles: &self.tiles,
                scale: self.scale,
                src: self.src,
            };
            let points = self.strategy.search(&space, &counted);
            let evaluations = counted.count.load(Ordering::Relaxed);
            total += evaluations;
            let Some(tuning) = DeviceTuning::from_points(dev.id.clone(), points, evaluations)
            else {
                bail!(
                    "no candidate tile is launchable on device '{}' for {} at scale {}",
                    dev.id,
                    self.kernel.label(),
                    self.scale
                );
            };
            per_device.push(tuning);
        }
        // Portable selection needs a common candidate pool with
        // comparable regrets. Path-based strategies (descent) may visit
        // different tiles per device, so top every device up to the
        // union of visited tiles before choosing; for exhaustive
        // searches this is a no-op. The extra evaluations are counted.
        let union: Vec<TileDim> = self
            .tiles
            .iter()
            .copied()
            .filter(|t| {
                per_device
                    .iter()
                    .any(|d| d.points.iter().any(|p| p.tile == *t))
            })
            .collect();
        for (dev, tuning) in self.devices.iter().zip(per_device.iter_mut()) {
            let missing: Vec<TileDim> = union
                .iter()
                .copied()
                .filter(|t| !tuning.points.iter().any(|p| p.tile == *t))
                .collect();
            if missing.is_empty() {
                continue;
            }
            let counted = CountedRef {
                inner: &*self.cost,
                count: AtomicU64::new(0),
            };
            let space = SearchSpace {
                dev,
                kernel: self.kernel,
                tiles: &self.tiles,
                scale: self.scale,
                src: self.src,
            };
            let mut points = std::mem::take(&mut tuning.points);
            for t in missing {
                points.push(space.evaluate(&counted, t));
            }
            let extra = counted.count.load(Ordering::Relaxed);
            total += extra;
            *tuning = DeviceTuning::from_points(
                tuning.device_id.clone(),
                points,
                tuning.evaluations + extra,
            )
            .expect("union includes this device's own launchable points");
        }
        let portable = portable_over(&per_device);
        Ok(TuningOutcome {
            kernel: self.kernel,
            scale: self.scale,
            src: self.src,
            strategy: self.strategy.name(),
            evaluations: total,
            per_device,
            portable,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotuner::sweep::sweep;
    use crate::device::paper_pair;

    #[test]
    fn defaults_reproduce_the_paper_setup() {
        let outcome = TuningSession::sim().run().unwrap();
        assert_eq!(outcome.kernel, Interpolator::Bilinear);
        assert_eq!(outcome.scale, 8);
        assert_eq!(outcome.src, (800, 800));
        assert_eq!(outcome.strategy, "exhaustive");
        assert_eq!(outcome.per_device.len(), 2);
        assert_eq!(outcome.per_device[0].device_id, "gtx260");
        assert_eq!(outcome.per_device[1].device_id, "8800gts");
    }

    #[test]
    fn exhaustive_session_matches_raw_sweep_exactly() {
        let (gtx, _) = paper_pair();
        let tiles = paper_sweep_tiles();
        let raw = sweep(&gtx, Interpolator::Bilinear, &tiles, 8, (800, 800));
        let outcome = TuningSession::sim().scale(8).run().unwrap();
        let dt = outcome.device("gtx260").unwrap();
        assert_eq!(dt.points.len(), raw.points.len());
        for (a, b) in dt.points.iter().zip(&raw.points) {
            assert_eq!(a.tile, b.tile);
            assert_eq!(a.ms, b.report.ms);
        }
        assert_eq!(dt.best, raw.best().unwrap().tile);
        assert_eq!(dt.evaluations, tiles.len() as u64);
    }

    #[test]
    fn empty_inputs_error_cleanly() {
        assert!(TuningSession::sim().devices([]).run().is_err());
        assert!(TuningSession::sim().tiles([]).run().is_err());
    }

    #[test]
    fn unlaunchable_everything_errors_with_device_name() {
        // A tile far over every block cap is unlaunchable everywhere.
        let err = TuningSession::sim()
            .tiles([TileDim::new(1024, 1024)])
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("gtx260"), "{err}");
    }

    #[test]
    fn portable_pool_is_topped_up_across_devices() {
        // Descent paths may diverge per device; the session must
        // evaluate the union of visited tiles on every device so
        // portable regrets compare like with like.
        use crate::autotuner::strategy::CoordinateDescent;
        for scale in [2u32, 4, 6, 8, 10] {
            let outcome = TuningSession::sim()
                .scale(scale)
                .strategy(CoordinateDescent::default())
                .run()
                .unwrap();
            let mut union: Vec<TileDim> = outcome
                .per_device
                .iter()
                .flat_map(|d| d.points.iter().map(|p| p.tile))
                .collect();
            union.sort();
            union.dedup();
            for d in &outcome.per_device {
                let mut mine: Vec<TileDim> = d.points.iter().map(|p| p.tile).collect();
                mine.sort();
                mine.dedup();
                assert_eq!(mine, union, "{} at scale {scale}", d.device_id);
            }
            assert!(outcome.portable.is_some(), "scale {scale}");
        }
    }

    #[test]
    fn device_builder_appends() {
        let (gtx, gts) = paper_pair();
        let outcome = TuningSession::sim()
            .devices([gtx])
            .device(gts)
            .run()
            .unwrap();
        assert_eq!(outcome.per_device.len(), 2);
    }
}
