//! Exhaustive tile sweeps — the machinery behind the paper's Fig. 3.

use crate::device::DeviceDescriptor;
use crate::image::Interpolator;
use crate::sim::{simulate, Launch, SimReport};
use crate::tiling::TileDim;
use crate::util::stats;

/// One point of a sweep: a tile and its simulated outcome.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub tile: TileDim,
    pub report: SimReport,
}

/// A full sweep of one (device, kernel, scale) combination.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub device_id: String,
    pub kernel: Interpolator,
    pub scale: u32,
    pub points: Vec<SweepPoint>,
}

impl SweepResult {
    /// The fastest launchable tile (ties broken toward wider tiles, the
    /// row-friendly shapes — matching how the paper reads its figures).
    /// NaN-safe: ordering uses `f64::total_cmp`, so a non-finite simulated
    /// time can never panic the tuner.
    pub fn best(&self) -> Option<&SweepPoint> {
        self.points
            .iter()
            .filter(|p| p.report.ms.is_finite())
            .min_by(|a, b| {
                a.report
                    .ms
                    .total_cmp(&b.report.ms)
                    .then_with(|| b.tile.aspect().total_cmp(&a.tile.aspect()))
            })
    }

    /// Times of all launchable tiles, in sweep order.
    pub fn times_ms(&self) -> Vec<f64> {
        self.points
            .iter()
            .filter(|p| p.report.ms.is_finite())
            .map(|p| p.report.ms)
            .collect()
    }

    /// Relative "jaggedness" of the curve: range/mean over launchable
    /// tiles.
    pub fn spread_ratio(&self) -> f64 {
        stats::spread_ratio(&self.times_ms())
    }

    /// Absolute curve range in milliseconds (max − min over launchable
    /// tiles). The paper's §IV.B observation — "the lower line [GTX 260]
    /// is smoother than the upper line [8800 GTS] ... the block size
    /// doesn't affect the performance on GTX 260 as significantly as on
    /// GeForce 8800 GTS" — reads off Fig. 3's ms axis: the 8800 curve
    /// moves through a larger ms band. (Relative spread is necessarily
    /// larger on the faster device; see the `smoothness` ablation bench.)
    pub fn range_ms(&self) -> f64 {
        let t = self.times_ms();
        match stats::Summary::of(&t) {
            Some(s) => s.max - s.min,
            None => 0.0,
        }
    }

    /// Time of a specific tile, if present and launchable.
    pub fn time_of(&self, tile: TileDim) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.tile == tile)
            .map(|p| p.report.ms)
            .filter(|ms| ms.is_finite())
    }
}

/// Sweep `tiles` for `kernel` at `scale` on `dev` over a `src`-sized
/// source image (the paper uses 800×800).
pub fn sweep(
    dev: &DeviceDescriptor,
    kernel: Interpolator,
    tiles: &[TileDim],
    scale: u32,
    src: (u32, u32),
) -> SweepResult {
    let points = tiles
        .iter()
        .map(|&tile| {
            let launch = Launch {
                kernel,
                tile,
                src_w: src.0,
                src_h: src.1,
                scale,
            };
            SweepPoint {
                tile,
                report: simulate(&launch, dev, None),
            }
        })
        .collect();
    SweepResult {
        device_id: dev.id.clone(),
        kernel,
        scale,
        points,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::paper_pair;
    use crate::tiling::paper_sweep_tiles;

    fn run(dev: &DeviceDescriptor, scale: u32) -> SweepResult {
        sweep(
            dev,
            Interpolator::Bilinear,
            &paper_sweep_tiles(),
            scale,
            (800, 800),
        )
    }

    #[test]
    fn best_exists_and_is_finite() {
        let (gtx, _) = paper_pair();
        let r = run(&gtx, 4);
        let best = r.best().unwrap();
        assert!(best.report.ms.is_finite());
        // everything else is no faster
        for p in &r.points {
            if p.report.ms.is_finite() {
                assert!(p.report.ms >= best.report.ms);
            }
        }
    }

    #[test]
    fn paper_finding_32x4_wins_at_large_scales_on_both() {
        // "the tiling dimensions which can provide the best performance
        // both on GTX 260 and GeForce 8800 GTS ... is the tiling
        // dimensions 32x4 in inset (c), (d) and (e)" — scales 6, 8, 10.
        let (gtx, gts) = paper_pair();
        for dev in [&gtx, &gts] {
            for scale in [6, 8, 10] {
                let r = run(dev, scale);
                let best = r.best().unwrap().tile;
                assert_eq!(
                    best,
                    TileDim::new(32, 4),
                    "{} at scale {scale}: best was {best}",
                    dev.id
                );
            }
        }
    }

    #[test]
    fn paper_finding_gtx_smoother_at_small_scales() {
        // §IV.B: "The lower line is smoother than the upper line. This
        // means the block size doesn't affect the performance on GTX 260
        // as significantly as on GeForce 8800 GTS." Fig. 3's axis is ms,
        // so the claim is about the absolute band the curve moves in.
        let (gtx, gts) = paper_pair();
        for scale in [2, 4, 6, 8, 10] {
            let sg = run(&gtx, scale).range_ms();
            let ss = run(&gts, scale).range_ms();
            assert!(
                sg < ss,
                "scale {scale}: gtx range {sg} ms should be < gts range {ss} ms"
            );
        }
    }

    #[test]
    fn best_is_nan_safe() {
        // A cost model gone wrong (NaN time) must lose quietly, not panic
        // the tuner mid-comparison.
        let (gtx, _) = paper_pair();
        let mut r = run(&gtx, 4);
        let want = r.best().unwrap().tile;
        // poison two non-winning points with NaN / infinity
        let mut poisoned = 0;
        for p in r.points.iter_mut() {
            if p.tile != want && poisoned < 2 {
                p.report.ms = if poisoned == 0 { f64::NAN } else { f64::INFINITY };
                poisoned += 1;
            }
        }
        assert_eq!(poisoned, 2);
        let best = r.best().unwrap();
        assert!(best.report.ms.is_finite());
        // NaN-ing non-winners leaves the winner unchanged
        assert_eq!(best.tile, want);
    }

    #[test]
    fn time_of_lookup() {
        let (gtx, _) = paper_pair();
        let r = run(&gtx, 2);
        assert!(r.time_of(TileDim::new(32, 4)).is_some());
        assert!(r.time_of(TileDim::new(7, 3)).is_none());
    }
}
