//! Tuning results: the durable record a [`TuningSession`](super::TuningSession)
//! produces.
//!
//! [`TuningOutcome`] subsumes the old `SweepResult`-per-device +
//! `PortableChoice` pair behind one value that (a) answers routing
//! questions (`best_for`, `portable_tile`) and (b) serializes losslessly
//! through [`crate::codec::json`] so it can live in a persistent tuning
//! cache (`tuning_cache.json`) or ship between processes. Keys follow the
//! paper's experimental axes: device id, kernel, scale, source size.

use super::portable::PortableChoice;
use super::sweep::SweepResult;
use crate::codec::json::Json;
use crate::image::Interpolator;
use crate::tiling::TileDim;
use crate::util::stats;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// One evaluated candidate: a tile and its (simulated or measured) time.
/// Non-finite `ms` marks an unlaunchable tile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunedPoint {
    pub tile: TileDim,
    pub ms: f64,
}

/// Everything tuning learned about one device: the evaluated points, the
/// winning tile, and how much work it took to find it.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceTuning {
    /// Registry id of the device (`gtx260`).
    pub device_id: String,
    /// The fastest launchable tile (ties broken toward wider tiles, the
    /// row-friendly shapes — matching how the paper reads its figures).
    pub best: TileDim,
    /// Time of `best` in milliseconds.
    pub best_ms: f64,
    /// `CostModel::evaluate` calls spent on this device (0 = cache hit).
    pub evaluations: u64,
    /// Every evaluated point, in evaluation/sweep order. May include
    /// non-finite (unlaunchable) entries; those are dropped when the
    /// tuning is persisted.
    pub points: Vec<TunedPoint>,
}

impl DeviceTuning {
    /// Build from evaluated points; `None` when no point is launchable.
    /// NaN-safe: ordering uses `f64::total_cmp`, so a non-finite time can
    /// never panic the tuner (it simply loses).
    pub fn from_points(
        device_id: String,
        points: Vec<TunedPoint>,
        evaluations: u64,
    ) -> Option<DeviceTuning> {
        let (best, best_ms) = {
            let b = points
                .iter()
                .filter(|p| p.ms.is_finite())
                .min_by(|a, b| {
                    a.ms.total_cmp(&b.ms)
                        .then_with(|| b.tile.aspect().total_cmp(&a.tile.aspect()))
                })?;
            (b.tile, b.ms)
        };
        Some(DeviceTuning {
            device_id,
            best,
            best_ms,
            evaluations,
            points,
        })
    }

    /// Project a full sweep down to a tuning record (one evaluation per
    /// swept tile).
    pub fn from_sweep(sweep: &SweepResult) -> Option<DeviceTuning> {
        let points: Vec<TunedPoint> = sweep
            .points
            .iter()
            .map(|p| TunedPoint {
                tile: p.tile,
                ms: p.report.ms,
            })
            .collect();
        let evaluations = points.len() as u64;
        Self::from_points(sweep.device_id.clone(), points, evaluations)
    }

    /// Time of a specific tile, if evaluated and launchable.
    pub fn time_of(&self, tile: TileDim) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.tile == tile)
            .map(|p| p.ms)
            .filter(|ms| ms.is_finite())
    }

    /// Times of all launchable evaluated tiles, in evaluation order.
    pub fn times_ms(&self) -> Vec<f64> {
        self.points
            .iter()
            .filter(|p| p.ms.is_finite())
            .map(|p| p.ms)
            .collect()
    }

    /// Absolute curve range in milliseconds (max − min over launchable
    /// tiles) — the §IV.B "smoothness" reading of Fig. 3.
    pub fn range_ms(&self) -> f64 {
        match stats::Summary::of(&self.times_ms()) {
            Some(s) => s.max - s.min,
            None => 0.0,
        }
    }

    /// JSON object for this tuning. Only launchable (finite-time) points
    /// are persisted.
    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .filter(|p| p.ms.is_finite())
            .map(|p| {
                Json::obj()
                    .set("tile", p.tile.label())
                    .set("ms", p.ms)
            })
            .collect();
        Json::obj()
            .set("device", self.device_id.as_str())
            .set("best", self.best.label())
            .set("best_ms", self.best_ms)
            .set("evaluations", self.evaluations)
            .set("points", Json::Arr(points))
    }

    /// Parse back what [`to_json`](Self::to_json) wrote.
    pub fn from_json(j: &Json) -> Result<DeviceTuning> {
        let device_id = str_field(j, "device")?;
        let best = tile_field(j, "best")?;
        let best_ms = num_field(j, "best_ms")?;
        let evaluations = u64_field(j, "evaluations")?;
        let mut points = Vec::new();
        for p in arr_field(j, "points")? {
            points.push(TunedPoint {
                tile: tile_field(p, "tile")?,
                ms: num_field(p, "ms")?,
            });
        }
        Ok(DeviceTuning {
            device_id,
            best,
            best_ms,
            evaluations,
            points,
        })
    }
}

/// The complete result of one tuning session over a device set.
#[derive(Debug, Clone, PartialEq)]
pub struct TuningOutcome {
    /// Kernel that was tuned.
    pub kernel: Interpolator,
    /// Upscaling factor of the tuned workload.
    pub scale: u32,
    /// Source image size of the tuned workload.
    pub src: (u32, u32),
    /// Name of the strategy that produced this outcome.
    pub strategy: String,
    /// Total `CostModel::evaluate` calls across all devices.
    pub evaluations: u64,
    /// Per-device results, in session device order.
    pub per_device: Vec<DeviceTuning>,
    /// The min-max-regret portable pick over the device set, when some
    /// tile is launchable everywhere (the paper's §V conclusion).
    pub portable: Option<PortableChoice>,
}

impl TuningOutcome {
    /// The tuning record for one device.
    pub fn device(&self, device_id: &str) -> Option<&DeviceTuning> {
        self.per_device.iter().find(|d| d.device_id == device_id)
    }

    /// The tuned best tile for one device.
    pub fn best_for(&self, device_id: &str) -> Option<TileDim> {
        self.device(device_id).map(|d| d.best)
    }

    /// The portable (min-max regret) tile, if any.
    pub fn portable_tile(&self) -> Option<TileDim> {
        self.portable.as_ref().map(|c| c.tile)
    }

    /// Worst-case relative slowdown of the portable tile across devices.
    pub fn worst_regret(&self) -> Option<f64> {
        self.portable.as_ref().map(|c| c.worst_regret)
    }

    /// A copy of this outcome with `device_id`'s cost curve reversed
    /// (each point's time mirrored across the min/max midpoint), so the
    /// device's tuned winner provably moves — the deterministic
    /// stand-in for "a re-tuning run under changed external conditions
    /// found a new best" used by retune demos and tests. The portable
    /// pick is recomputed over the flipped curves. `None` when the
    /// device is absent from the outcome or has no launchable points.
    pub fn with_flipped_winner(&self, device_id: &str) -> Option<TuningOutcome> {
        if self.device(device_id).is_none() {
            return None;
        }
        let per_device: Vec<DeviceTuning> = self
            .per_device
            .iter()
            .map(|dt| {
                if dt.device_id != device_id {
                    return Some(dt.clone());
                }
                // Mirror only the launchable points; a non-finite time
                // marks an unlaunchable tile and stays unlaunchable.
                let finite = || dt.points.iter().map(|p| p.ms).filter(|m| m.is_finite());
                let lo = finite().fold(f64::INFINITY, f64::min);
                let hi = finite().fold(f64::NEG_INFINITY, f64::max);
                let points: Vec<TunedPoint> = dt
                    .points
                    .iter()
                    .map(|p| TunedPoint {
                        tile: p.tile,
                        ms: if p.ms.is_finite() { (lo + hi) - p.ms } else { p.ms },
                    })
                    .collect();
                DeviceTuning::from_points(dt.device_id.clone(), points, dt.evaluations)
            })
            .collect::<Option<Vec<_>>>()?;
        let portable = super::portable::portable_over(&per_device);
        Some(TuningOutcome {
            kernel: self.kernel,
            scale: self.scale,
            src: self.src,
            strategy: format!("{}-flipped", self.strategy),
            evaluations: self.evaluations,
            per_device,
            portable,
        })
    }

    /// Serialize to a versioned JSON document.
    pub fn to_json(&self) -> Json {
        let devices: Vec<Json> = self.per_device.iter().map(|d| d.to_json()).collect();
        let mut j = Json::obj()
            .set("version", 1u64)
            .set("kernel", self.kernel.label())
            .set("scale", self.scale)
            .set("src", vec![self.src.0, self.src.1])
            .set("strategy", self.strategy.as_str())
            .set("evaluations", self.evaluations)
            .set("devices", Json::Arr(devices));
        if let Some(c) = &self.portable {
            let per: Vec<Json> = c
                .per_device
                .iter()
                .map(|(dev, best, regret)| {
                    Json::obj()
                        .set("device", dev.as_str())
                        .set("best", best.label())
                        .set("regret", *regret)
                })
                .collect();
            j = j.set(
                "portable",
                Json::obj()
                    .set("tile", c.tile.label())
                    .set("worst_regret", c.worst_regret)
                    .set("per_device", Json::Arr(per)),
            );
        }
        j
    }

    /// Parse back what [`to_json`](Self::to_json) wrote.
    pub fn from_json(j: &Json) -> Result<TuningOutcome> {
        match j.get("version").and_then(Json::as_u64) {
            Some(1) => {}
            Some(v) => bail!("unsupported tuning outcome version {v}"),
            None => bail!("tuning outcome is missing 'version'"),
        }
        let kernel_s = str_field(j, "kernel")?;
        let kernel = Interpolator::parse(&kernel_s)
            .ok_or_else(|| anyhow!("unknown kernel '{kernel_s}'"))?;
        let scale = u64_field(j, "scale")? as u32;
        let src_arr = arr_field(j, "src")?;
        if src_arr.len() != 2 {
            bail!("'src' must be a [w, h] pair");
        }
        let src = (
            src_arr[0].as_u64().context("src[0]")? as u32,
            src_arr[1].as_u64().context("src[1]")? as u32,
        );
        let strategy = str_field(j, "strategy")?;
        let evaluations = u64_field(j, "evaluations")?;
        let mut per_device = Vec::new();
        for d in arr_field(j, "devices")? {
            per_device.push(DeviceTuning::from_json(d)?);
        }
        let portable = match j.get("portable") {
            None | Some(Json::Null) => None,
            Some(p) => {
                let tile = tile_field(p, "tile")?;
                let worst_regret = num_field(p, "worst_regret")?;
                let mut per = Vec::new();
                for e in arr_field(p, "per_device")? {
                    per.push((
                        str_field(e, "device")?,
                        tile_field(e, "best")?,
                        num_field(e, "regret")?,
                    ));
                }
                Some(PortableChoice {
                    tile,
                    worst_regret,
                    per_device: per,
                })
            }
        };
        Ok(TuningOutcome {
            kernel,
            scale,
            src,
            strategy,
            evaluations,
            per_device,
            portable,
        })
    }

    /// Write the outcome as pretty JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing tuning outcome {}", path.display()))
    }

    /// Load an outcome written by [`save`](Self::save).
    pub fn load(path: &Path) -> Result<TuningOutcome> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tuning outcome {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("{e}"))
            .with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("in {}", path.display()))
    }
}

// ----- small JSON field accessors (shared with the tuning db) ------------

pub(crate) fn str_field(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing or non-string field '{key}'"))
}

pub(crate) fn num_field(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing or non-numeric field '{key}'"))
}

pub(crate) fn u64_field(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("missing or non-integer field '{key}'"))
}

pub(crate) fn arr_field<'a>(j: &'a Json, key: &str) -> Result<&'a [Json]> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing or non-array field '{key}'"))
}

pub(crate) fn tile_field(j: &Json, key: &str) -> Result<TileDim> {
    let s = str_field(j, key)?;
    s.parse::<TileDim>()
        .map_err(|e| anyhow!("field '{key}': {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tuning(id: &str, bias: f64) -> DeviceTuning {
        let points = vec![
            TunedPoint {
                tile: TileDim::new(8, 8),
                ms: 2.5 + bias,
            },
            TunedPoint {
                tile: TileDim::new(32, 4),
                ms: 1.25 + bias,
            },
            TunedPoint {
                tile: TileDim::new(32, 16),
                ms: f64::INFINITY,
            },
        ];
        DeviceTuning::from_points(id.to_string(), points, 3).unwrap()
    }

    #[test]
    fn with_flipped_winner_reverses_one_device_curve() {
        let per_device = vec![sample_tuning("gtx260", 0.0), sample_tuning("fermi", 0.5)];
        let outcome = TuningOutcome {
            kernel: Interpolator::Bilinear,
            scale: 2,
            src: (64, 64),
            strategy: "test".to_string(),
            evaluations: 6,
            portable: super::super::portable::portable_over(&per_device),
            per_device,
        };
        assert_eq!(outcome.best_for("gtx260"), Some(TileDim::new(32, 4)));
        let flipped = outcome.with_flipped_winner("gtx260").unwrap();
        // The mirrored curve makes the old loser the new winner...
        assert_eq!(flipped.best_for("gtx260"), Some(TileDim::new(8, 8)));
        // ...the unlaunchable point stays unlaunchable, the other
        // device is untouched, and the strategy records the flip.
        assert_eq!(
            flipped.device("gtx260").unwrap().time_of(TileDim::new(32, 16)),
            None
        );
        assert_eq!(flipped.best_for("fermi"), outcome.best_for("fermi"));
        assert!(flipped.strategy.ends_with("-flipped"));
        // Absent devices flip to nothing.
        assert!(outcome.with_flipped_winner("ghost").is_none());
    }

    #[test]
    fn best_ignores_non_finite() {
        let t = sample_tuning("gtx260", 0.0);
        assert_eq!(t.best, TileDim::new(32, 4));
        assert_eq!(t.best_ms, 1.25);
        assert_eq!(t.time_of(TileDim::new(32, 16)), None);
        assert_eq!(t.times_ms().len(), 2);
        assert!((t.range_ms() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn nan_points_cannot_panic_selection() {
        let points = vec![
            TunedPoint {
                tile: TileDim::new(8, 8),
                ms: f64::NAN,
            },
            TunedPoint {
                tile: TileDim::new(16, 8),
                ms: 3.0,
            },
        ];
        let t = DeviceTuning::from_points("d".into(), points, 2).unwrap();
        assert_eq!(t.best, TileDim::new(16, 8));
        // all-NaN input yields None rather than a panic
        let bad = vec![TunedPoint {
            tile: TileDim::new(8, 8),
            ms: f64::NAN,
        }];
        assert!(DeviceTuning::from_points("d".into(), bad, 1).is_none());
    }

    #[test]
    fn outcome_json_round_trip_drops_only_unlaunchable_points() {
        let a = sample_tuning("gtx260", 0.0);
        let b = sample_tuning("8800gts", 1.0);
        let portable = super::super::portable::portable_over(&[a.clone(), b.clone()]);
        let outcome = TuningOutcome {
            kernel: Interpolator::Bilinear,
            scale: 8,
            src: (800, 800),
            strategy: "exhaustive".to_string(),
            evaluations: 6,
            per_device: vec![a, b],
            portable,
        };
        let text = outcome.to_json().pretty();
        let back = TuningOutcome::from_json(&Json::parse(&text).unwrap()).unwrap();
        // the infinite 32x16 point is dropped in serialization; everything
        // else survives exactly
        assert_eq!(back.per_device[0].points.len(), 2);
        assert_eq!(back.per_device[0].best, outcome.per_device[0].best);
        assert_eq!(back.per_device[0].best_ms, outcome.per_device[0].best_ms);
        assert_eq!(back.portable, outcome.portable);
        assert_eq!(back.kernel, outcome.kernel);
        assert_eq!(back.scale, outcome.scale);
        assert_eq!(back.src, outcome.src);
        assert_eq!(back.strategy, outcome.strategy);
    }

    #[test]
    fn from_json_rejects_malformed() {
        for text in [
            "{}",
            r#"{"version": 2}"#,
            r#"{"version": 1, "kernel": "sinc", "scale": 2, "src": [1, 1],
                "strategy": "x", "evaluations": 0, "devices": []}"#,
            r#"{"version": 1, "kernel": "bilinear", "scale": 2, "src": [1],
                "strategy": "x", "evaluations": 0, "devices": []}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(TuningOutcome::from_json(&j).is_err(), "accepted {text}");
        }
    }
}
