//! Search strategies: how a [`TuningSession`](super::TuningSession)
//! explores the tile space of one device.
//!
//! * [`Exhaustive`] — evaluate every candidate (the seed crate's `sweep`
//!   behavior; the ground truth the other strategies are judged against).
//! * [`CoordinateDescent`] — hill-climb over the w×h tile lattice,
//!   evaluating only a path plus its neighbors. On large tile sets this
//!   needs roughly an order of magnitude fewer `CostModel::evaluate`
//!   calls than an exhaustive sweep while landing on (or next to) the
//!   same winner on tiling surfaces like the paper's Fig. 3 curves.
//! * [`Cached`] — decorator consulting a persistent [`TuningDb`] keyed by
//!   (device id, kernel, scale, source size); hits cost zero evaluations.
//!
//! Strategies are judged on `CostModel::evaluate` calls; wrap a model in
//! [`CountingCostModel`](super::CountingCostModel) to audit them.

use super::cost::CostModel;
use super::db::TuningDb;
use super::outcome::{DeviceTuning, TunedPoint};
use crate::device::DeviceDescriptor;
use crate::image::Interpolator;
use crate::sim::Launch;
use crate::tiling::TileDim;
use anyhow::{bail, Result};
use std::cell::{Ref, RefCell};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// One device's search problem: the candidate tiles and the workload they
/// will run.
pub struct SearchSpace<'a> {
    pub dev: &'a DeviceDescriptor,
    pub kernel: Interpolator,
    pub tiles: &'a [TileDim],
    pub scale: u32,
    pub src: (u32, u32),
}

impl SearchSpace<'_> {
    /// The launch a candidate tile corresponds to.
    pub fn launch(&self, tile: TileDim) -> Launch {
        Launch {
            kernel: self.kernel,
            tile,
            src_w: self.src.0,
            src_h: self.src.1,
            scale: self.scale,
        }
    }

    /// Evaluate one candidate through a cost model.
    pub fn evaluate(&self, cost: &dyn CostModel, tile: TileDim) -> TunedPoint {
        TunedPoint {
            tile,
            ms: cost.evaluate(&self.launch(tile), self.dev).ms,
        }
    }
}

/// How to explore a [`SearchSpace`]. Implementations return every point
/// they evaluated (or recalled from a cache), in discovery order; best-
/// tile extraction and portable selection happen in the session layer.
pub trait SearchStrategy {
    /// Strategy label recorded in [`TuningOutcome`](super::TuningOutcome)
    /// provenance.
    fn name(&self) -> String;

    /// Explore the space through `cost`.
    fn search(&self, space: &SearchSpace<'_>, cost: &dyn CostModel) -> Vec<TunedPoint>;
}

impl SearchStrategy for Box<dyn SearchStrategy> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn search(&self, space: &SearchSpace<'_>, cost: &dyn CostModel) -> Vec<TunedPoint> {
        (**self).search(space, cost)
    }
}

/// Evaluate every candidate tile — the seed `sweep` behavior.
#[derive(Debug, Clone, Copy, Default)]
pub struct Exhaustive;

impl SearchStrategy for Exhaustive {
    fn name(&self) -> String {
        "exhaustive".to_string()
    }

    fn search(&self, space: &SearchSpace<'_>, cost: &dyn CostModel) -> Vec<TunedPoint> {
        space
            .tiles
            .iter()
            .map(|&tile| space.evaluate(cost, tile))
            .collect()
    }
}

/// Hill-climb over the w×h tile lattice.
///
/// The candidate set is treated as a 2-D lattice over its distinct tile
/// widths and heights. From a start tile the search repeatedly evaluates
/// the four axis neighbors present in the candidate set and moves to the
/// strictest improvement, stopping at a local minimum. Every evaluation
/// is memoized, so the cost is the path length plus its frontier — far
/// below the full lattice on big tile sets.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinateDescent {
    /// Explicit start tile; `None` uses the midpoint rule (the candidate
    /// closest to the geometric mean thread count, widest aspect first —
    /// the row-friendly shapes the paper recommends).
    pub start: Option<TileDim>,
}

impl CoordinateDescent {
    /// Descend from an explicit start tile.
    pub fn from(start: TileDim) -> CoordinateDescent {
        CoordinateDescent { start: Some(start) }
    }

    fn default_start(tiles: &[TileDim]) -> Option<TileDim> {
        let min = tiles.iter().map(TileDim::threads).min()? as f64;
        let max = tiles.iter().map(TileDim::threads).max()? as f64;
        let target = (min.ln() + max.ln()) / 2.0;
        tiles.iter().copied().min_by(|a, b| {
            let da = ((a.threads() as f64).ln() - target).abs();
            let db = ((b.threads() as f64).ln() - target).abs();
            da.total_cmp(&db)
                .then_with(|| b.aspect().total_cmp(&a.aspect()))
        })
    }
}

fn eval_memo(
    space: &SearchSpace<'_>,
    cost: &dyn CostModel,
    tile: TileDim,
    seen: &mut BTreeMap<(u32, u32), f64>,
    order: &mut Vec<TunedPoint>,
) -> f64 {
    if let Some(&ms) = seen.get(&(tile.x, tile.y)) {
        return ms;
    }
    let p = space.evaluate(cost, tile);
    seen.insert((tile.x, tile.y), p.ms);
    order.push(p);
    p.ms
}

impl SearchStrategy for CoordinateDescent {
    fn name(&self) -> String {
        "descent".to_string()
    }

    fn search(&self, space: &SearchSpace<'_>, cost: &dyn CostModel) -> Vec<TunedPoint> {
        let tiles = space.tiles;
        let mut order = Vec::new();
        if tiles.is_empty() {
            return order;
        }
        let mut xs: Vec<u32> = tiles.iter().map(|t| t.x).collect();
        xs.sort_unstable();
        xs.dedup();
        let mut ys: Vec<u32> = tiles.iter().map(|t| t.y).collect();
        ys.sort_unstable();
        ys.dedup();
        let members: BTreeSet<(u32, u32)> = tiles.iter().map(|t| (t.x, t.y)).collect();

        let start = self
            .start
            .filter(|t| members.contains(&(t.x, t.y)))
            .or_else(|| Self::default_start(tiles));
        let Some(mut cur) = start else {
            return order;
        };
        let mut seen = BTreeMap::new();
        let mut cur_ms = eval_memo(space, cost, cur, &mut seen, &mut order);
        if !cur_ms.is_finite() {
            // Unlaunchable start: fall back to the first launchable
            // candidate (scanning is still bounded by the tile set).
            let mut found = false;
            for &t in tiles {
                let ms = eval_memo(space, cost, t, &mut seen, &mut order);
                if ms.is_finite() {
                    cur = t;
                    cur_ms = ms;
                    found = true;
                    break;
                }
            }
            if !found {
                return order;
            }
        }

        for _ in 0..tiles.len() {
            let ix = xs.iter().position(|&v| v == cur.x).expect("member x");
            let iy = ys.iter().position(|&v| v == cur.y).expect("member y");
            let mut neighbors = Vec::with_capacity(4);
            if ix > 0 {
                neighbors.push((xs[ix - 1], cur.y));
            }
            if ix + 1 < xs.len() {
                neighbors.push((xs[ix + 1], cur.y));
            }
            if iy > 0 {
                neighbors.push((cur.x, ys[iy - 1]));
            }
            if iy + 1 < ys.len() {
                neighbors.push((cur.x, ys[iy + 1]));
            }
            let mut best_step: Option<(TileDim, f64)> = None;
            for (x, y) in neighbors {
                if !members.contains(&(x, y)) {
                    continue;
                }
                let t = TileDim::new(x, y);
                let ms = eval_memo(space, cost, t, &mut seen, &mut order);
                if !ms.is_finite() {
                    continue;
                }
                let take = match best_step {
                    None => true,
                    Some((bt, bms)) => {
                        ms.total_cmp(&bms).is_lt()
                            || (ms.total_cmp(&bms).is_eq() && t.aspect() > bt.aspect())
                    }
                };
                if take {
                    best_step = Some((t, ms));
                }
            }
            match best_step {
                Some((t, ms)) if ms < cur_ms => {
                    cur = t;
                    cur_ms = ms;
                }
                _ => break, // local minimum
            }
        }
        order
    }
}

/// Decorator: consult a persistent [`TuningDb`] before searching, and
/// write-through results so the next session (or process) gets them for
/// free. Cache keys are (device id, kernel, scale, source size) plus the
/// producing strategy and a fingerprint of the candidate tile set, so a
/// descent path never masquerades as an exhaustive sweep and a changed
/// tile set is a clean miss, never a stale hit.
pub struct Cached<S: SearchStrategy> {
    inner: S,
    db: RefCell<TuningDb>,
}

impl<S: SearchStrategy> Cached<S> {
    /// Wrap `inner` over an already-opened database.
    pub fn new(inner: S, db: TuningDb) -> Cached<S> {
        Cached {
            inner,
            db: RefCell::new(db),
        }
    }

    /// Wrap `inner` over the database at `path` (created on first write).
    pub fn open(inner: S, path: &Path) -> Result<Cached<S>> {
        Ok(Cached::new(inner, TuningDb::open(path)?))
    }

    /// Inspect the underlying database.
    pub fn db(&self) -> Ref<'_, TuningDb> {
        self.db.borrow()
    }

    /// Take the database back out.
    pub fn into_db(self) -> TuningDb {
        self.db.into_inner()
    }
}

impl<S: SearchStrategy> SearchStrategy for Cached<S> {
    fn name(&self) -> String {
        format!("cached+{}", self.inner.name())
    }

    fn search(&self, space: &SearchSpace<'_>, cost: &dyn CostModel) -> Vec<TunedPoint> {
        let strategy = self.inner.name();
        let tiles_fp = TuningDb::tiles_fingerprint(space.tiles);
        if let Some(hit) = self.db.borrow().get(
            &space.dev.id,
            space.kernel,
            space.scale,
            space.src,
            &strategy,
            &tiles_fp,
        ) {
            return hit.points.clone();
        }
        let points = self.inner.search(space, cost);
        if let Some(tuning) = DeviceTuning::from_points(
            space.dev.id.clone(),
            points.clone(),
            points.len() as u64,
        ) {
            let mut db = self.db.borrow_mut();
            db.insert(
                space.kernel,
                space.scale,
                space.src,
                &strategy,
                &tiles_fp,
                tuning,
            );
            if let Err(e) = db.persist() {
                eprintln!("tilekit: warning: could not persist tuning cache: {e:#}");
            }
        }
        points
    }
}

/// Valid `--strategy` names on the CLI.
pub const STRATEGY_NAMES: &[&str] = &["exhaustive", "descent", "cached"];

/// Resolve a CLI strategy name (optionally wrapped in a [`Cached`]
/// decorator when `cache` names a database file). Unknown names produce a
/// friendly error listing the valid options.
pub fn strategy_by_name(name: &str, cache: Option<&Path>) -> Result<Box<dyn SearchStrategy>> {
    let base: Box<dyn SearchStrategy> = match name {
        "exhaustive" | "sweep" => Box::new(Exhaustive),
        "descent" | "coordinate-descent" => Box::new(CoordinateDescent::default()),
        "cached" => Box::new(Exhaustive),
        other => bail!(
            "unknown strategy '{other}' — valid strategies: {}",
            STRATEGY_NAMES.join(", ")
        ),
    };
    Ok(match (name == "cached", cache) {
        (false, None) => base,
        (_, Some(path)) => Box::new(Cached::open(base, path)?),
        (true, None) => Box::new(Cached::open(base, Path::new("tuning_cache.json"))?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotuner::cost::{CountingCostModel, SimCostModel};
    use crate::device::paper_pair;
    use crate::tiling::paper_sweep_tiles;

    fn space<'a>(
        dev: &'a DeviceDescriptor,
        tiles: &'a [TileDim],
        scale: u32,
    ) -> SearchSpace<'a> {
        SearchSpace {
            dev,
            kernel: Interpolator::Bilinear,
            tiles,
            scale,
            src: (800, 800),
        }
    }

    #[test]
    fn exhaustive_evaluates_every_tile_once() {
        let (gtx, _) = paper_pair();
        let tiles = paper_sweep_tiles();
        let model = CountingCostModel::new(SimCostModel);
        let points = Exhaustive.search(&space(&gtx, &tiles, 6), &model);
        assert_eq!(points.len(), tiles.len());
        assert_eq!(model.count(), tiles.len() as u64);
        for (p, t) in points.iter().zip(&tiles) {
            assert_eq!(p.tile, *t);
        }
    }

    #[test]
    fn descent_midpoint_rule_prefers_wide_tiles() {
        let tiles = paper_sweep_tiles();
        // 32..512 threads → geometric mean 128; widest 128-thread member
        // is 32x4
        assert_eq!(
            CoordinateDescent::default_start(&tiles),
            Some(TileDim::new(32, 4))
        );
    }

    #[test]
    fn descent_finds_near_optimal_with_fewer_evaluations() {
        let (gtx, gts) = paper_pair();
        let tiles = paper_sweep_tiles();
        for dev in [&gtx, &gts] {
            for scale in [6, 8, 10] {
                let exhaustive = CountingCostModel::new(SimCostModel);
                let all = Exhaustive.search(&space(dev, &tiles, scale), &exhaustive);
                let best_all =
                    DeviceTuning::from_points(dev.id.clone(), all, tiles.len() as u64)
                        .unwrap();

                let counted = CountingCostModel::new(SimCostModel);
                let found =
                    CoordinateDescent::default().search(&space(dev, &tiles, scale), &counted);
                let evals = counted.count();
                let best_found =
                    DeviceTuning::from_points(dev.id.clone(), found, evals).unwrap();

                assert!(
                    evals < exhaustive.count(),
                    "{} scale {scale}: descent used {evals} >= {}",
                    dev.id,
                    exhaustive.count()
                );
                assert!(
                    best_found.best_ms <= best_all.best_ms * 1.05,
                    "{} scale {scale}: {} vs {}",
                    dev.id,
                    best_found.best_ms,
                    best_all.best_ms
                );
            }
        }
    }

    #[test]
    fn descent_memoizes_repeat_visits() {
        let (gtx, _) = paper_pair();
        let tiles = paper_sweep_tiles();
        let model = CountingCostModel::new(SimCostModel);
        let points = CoordinateDescent::default().search(&space(&gtx, &tiles, 8), &model);
        // every returned point is distinct and each evaluation produced
        // exactly one point
        let mut seen: Vec<TileDim> = points.iter().map(|p| p.tile).collect();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), points.len());
        assert_eq!(model.count(), points.len() as u64);
    }

    #[test]
    fn descent_explicit_start_is_honored() {
        let (gtx, _) = paper_pair();
        let tiles = paper_sweep_tiles();
        let model = CountingCostModel::new(SimCostModel);
        let start = TileDim::new(8, 8);
        let points = CoordinateDescent::from(start).search(&space(&gtx, &tiles, 8), &model);
        assert_eq!(points[0].tile, start);
    }

    #[test]
    fn strategy_by_name_resolves_and_rejects() {
        assert_eq!(
            strategy_by_name("exhaustive", None).unwrap().name(),
            "exhaustive"
        );
        assert_eq!(strategy_by_name("descent", None).unwrap().name(), "descent");
        let err = strategy_by_name("annealing", None).unwrap_err().to_string();
        assert!(err.contains("unknown strategy 'annealing'"), "{err}");
        assert!(err.contains("exhaustive"), "{err}");
        assert!(err.contains("descent"), "{err}");
        assert!(err.contains("cached"), "{err}");
    }

    #[test]
    fn cached_decorator_hits_skip_the_inner_strategy() {
        let (gtx, _) = paper_pair();
        let tiles = paper_sweep_tiles();
        let strat = Cached::new(Exhaustive, TuningDb::in_memory());
        let model = CountingCostModel::new(SimCostModel);
        let first = strat.search(&space(&gtx, &tiles, 8), &model);
        let after_first = model.count();
        assert_eq!(after_first, tiles.len() as u64);
        let second = strat.search(&space(&gtx, &tiles, 8), &model);
        assert_eq!(model.count(), after_first, "hit must not evaluate");
        assert_eq!(first, second);
        // a different scale is a different key
        strat.search(&space(&gtx, &tiles, 6), &model);
        assert!(model.count() > after_first);
        assert_eq!(strat.db().len(), 2);
    }

    #[test]
    fn cached_entries_do_not_cross_strategies() {
        // A descent-populated cache must not serve an exhaustive request:
        // descent stores only its path, not the full sweep.
        let dir = std::env::temp_dir().join("tilekit_strategy_cross_cache");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        std::fs::remove_file(&path).ok();
        let (gtx, _) = paper_pair();
        let tiles = paper_sweep_tiles();

        let descent = Cached::open(CoordinateDescent::default(), &path).unwrap();
        let model = CountingCostModel::new(SimCostModel);
        let path_points = descent.search(&space(&gtx, &tiles, 8), &model);
        assert!(path_points.len() < tiles.len());

        let exhaustive = Cached::open(Exhaustive, &path).unwrap();
        let model2 = CountingCostModel::new(SimCostModel);
        let all_points = exhaustive.search(&space(&gtx, &tiles, 8), &model2);
        assert_eq!(all_points.len(), tiles.len(), "must re-evaluate, not hit");
        assert_eq!(model2.count(), tiles.len() as u64);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cached_entries_do_not_cross_tile_sets() {
        // A cache populated with the paper tile set must not answer a
        // request over a different candidate set.
        let (gtx, _) = paper_pair();
        let strat = Cached::new(Exhaustive, TuningDb::in_memory());
        let model = CountingCostModel::new(SimCostModel);
        let tiles = paper_sweep_tiles();
        strat.search(&space(&gtx, &tiles, 8), &model);
        let after_paper = model.count();
        let small = [TileDim::new(8, 8), TileDim::new(16, 16)];
        let points = strat.search(&space(&gtx, &small, 8), &model);
        assert_eq!(points.len(), 2, "different tile set must miss the cache");
        assert_eq!(model.count(), after_paper + 2);
        // both entries coexist; re-requesting either is a hit
        assert_eq!(strat.db().len(), 2);
        strat.search(&space(&gtx, &tiles, 8), &model);
        strat.search(&space(&gtx, &small, 8), &model);
        assert_eq!(model.count(), after_paper + 2);
    }
}
