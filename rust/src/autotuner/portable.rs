//! Portable tile selection — the paper's §V takeaway operationalized:
//! "consider more about the performance on the worst-case GPU in order to
//! let the program get better performance on most GPUs".
//!
//! For each candidate tile we compute its *relative slowdown* vs the best
//! tile on every device; the portable tile minimizes the worst-case
//! slowdown (min-max regret). This is exactly the decision rule under
//! which the paper's data picks 32×4.
//!
//! The min-max core ([`portable_over`]) operates on [`DeviceTuning`]
//! records, so it serves both the low-level sweep API
//! ([`portable_tile`]) and [`TuningSession`](super::TuningSession)
//! outcomes, whatever strategy produced them.

use super::outcome::DeviceTuning;
use super::sweep::SweepResult;
use crate::tiling::TileDim;

/// The outcome of portable selection over a device set.
#[derive(Debug, Clone, PartialEq)]
pub struct PortableChoice {
    /// The selected tile.
    pub tile: TileDim,
    /// Worst-case relative slowdown of `tile` across devices
    /// (1.0 = best everywhere).
    pub worst_regret: f64,
    /// (device id, best tile there, regret of `tile` there).
    pub per_device: Vec<(String, TileDim, f64)>,
}

/// Choose the min-max-regret tile over per-device tuning records.
/// Candidates are the first device's evaluated tiles; a tile missing (or
/// unlaunchable) on any device is skipped. Returns `None` if no tile is
/// launchable on every device.
pub fn portable_over(tunings: &[DeviceTuning]) -> Option<PortableChoice> {
    let first = tunings.first()?;
    let mut best: Option<PortableChoice> = None;
    for p in &first.points {
        let tile = p.tile;
        let mut worst = 0f64;
        let mut per_device = Vec::with_capacity(tunings.len());
        let mut ok = true;
        for t in tunings {
            let t_tile = match t.time_of(tile) {
                Some(ms) => ms,
                None => {
                    ok = false;
                    break;
                }
            };
            let regret = t_tile / t.best_ms;
            if regret > worst {
                worst = regret;
            }
            per_device.push((t.device_id.clone(), t.best, regret));
        }
        if !ok {
            continue;
        }
        let better = match &best {
            None => true,
            Some(b) => {
                worst < b.worst_regret - 1e-12
                    || ((worst - b.worst_regret).abs() <= 1e-12
                        && tile.aspect() > b.tile.aspect())
            }
        };
        if better {
            best = Some(PortableChoice {
                tile,
                worst_regret: worst,
                per_device,
            });
        }
    }
    best
}

/// Choose the min-max-regret tile over one full sweep per device (all
/// sweeps should cover the same tile set). Returns `None` if no tile is
/// launchable on every device.
pub fn portable_tile(sweeps: &[SweepResult]) -> Option<PortableChoice> {
    if sweeps.is_empty() {
        return None;
    }
    let mut tunings = Vec::with_capacity(sweeps.len());
    for s in sweeps {
        tunings.push(DeviceTuning::from_sweep(s)?);
    }
    portable_over(&tunings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotuner::session::TuningSession;
    use crate::autotuner::sweep::sweep;
    use crate::device::{builtin_devices, paper_pair};
    use crate::image::Interpolator;
    use crate::tiling::paper_sweep_tiles;

    #[test]
    fn portable_pick_matches_paper_conclusion() {
        // Over the paper pair at the large scales, the portable tile is
        // 32x4 ("the tiling dimensions 32x4 seems to be a better choice
        // which can offer better performance in general") — asserted
        // through the TuningSession API, whose defaults are exactly the
        // paper's setup (paper pair, paper tiles, bilinear, 800×800).
        for scale in [6, 8, 10] {
            let outcome = TuningSession::sim().scale(scale).run().unwrap();
            let choice = outcome.portable.as_ref().unwrap();
            assert_eq!(choice.tile, "32x4".parse().unwrap(), "scale {scale}");
            assert!(choice.worst_regret < 1.05, "regret {}", choice.worst_regret);
        }
    }

    #[test]
    fn session_portable_agrees_with_sweep_portable() {
        let (gtx, gts) = paper_pair();
        let tiles = paper_sweep_tiles();
        let sweeps = vec![
            sweep(&gtx, Interpolator::Bilinear, &tiles, 8, (800, 800)),
            sweep(&gts, Interpolator::Bilinear, &tiles, 8, (800, 800)),
        ];
        let legacy = portable_tile(&sweeps).unwrap();
        let outcome = TuningSession::sim().scale(8).run().unwrap();
        assert_eq!(outcome.portable.unwrap(), legacy);
    }

    #[test]
    fn regret_at_least_one() {
        let (gtx, gts) = paper_pair();
        let tiles = paper_sweep_tiles();
        let sweeps = vec![
            sweep(&gtx, Interpolator::Bilinear, &tiles, 2, (800, 800)),
            sweep(&gts, Interpolator::Bilinear, &tiles, 2, (800, 800)),
        ];
        let choice = portable_tile(&sweeps).unwrap();
        assert!(choice.worst_regret >= 1.0 - 1e-12);
        for (_, _, r) in &choice.per_device {
            assert!(*r >= 1.0 - 1e-12);
        }
    }

    #[test]
    fn works_across_whole_registry() {
        // Portable selection over every builtin device (including the
        // synthetic pair and Fermi) still returns a launchable tile.
        let tiles = paper_sweep_tiles();
        let sweeps: Vec<_> = builtin_devices()
            .iter()
            .map(|d| sweep(d, Interpolator::Bilinear, &tiles, 6, (800, 800)))
            .collect();
        let choice = portable_tile(&sweeps).unwrap();
        assert_eq!(choice.per_device.len(), builtin_devices().len());
        assert!(choice.worst_regret < 2.0);
    }

    #[test]
    fn empty_input_is_none() {
        assert!(portable_tile(&[]).is_none());
        assert!(portable_over(&[]).is_none());
    }
}
