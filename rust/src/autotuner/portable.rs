//! Portable tile selection — the paper's §V takeaway operationalized:
//! "consider more about the performance on the worst-case GPU in order to
//! let the program get better performance on most GPUs".
//!
//! For each candidate tile we compute its *relative slowdown* vs the best
//! tile on every device; the portable tile minimizes the worst-case
//! slowdown (min-max regret). This is exactly the decision rule under
//! which the paper's data picks 32×4.

use super::sweep::SweepResult;
use crate::tiling::TileDim;

/// The outcome of portable selection over a device set.
#[derive(Debug, Clone)]
pub struct PortableChoice {
    /// The selected tile.
    pub tile: TileDim,
    /// Worst-case relative slowdown of `tile` across devices
    /// (1.0 = best everywhere).
    pub worst_regret: f64,
    /// (device id, best tile there, regret of `tile` there).
    pub per_device: Vec<(String, TileDim, f64)>,
}

/// Choose the min-max-regret tile over one sweep per device (all sweeps
/// must cover the same tile set). Returns `None` if no tile is launchable
/// on every device.
pub fn portable_tile(sweeps: &[SweepResult]) -> Option<PortableChoice> {
    let first = sweeps.first()?;
    let mut best: Option<PortableChoice> = None;
    for p in &first.points {
        let tile = p.tile;
        let mut worst = 0f64;
        let mut per_device = Vec::with_capacity(sweeps.len());
        let mut ok = true;
        for s in sweeps {
            let t_tile = match s.time_of(tile) {
                Some(t) => t,
                None => {
                    ok = false;
                    break;
                }
            };
            let best_point = s.best().expect("non-empty sweep");
            let regret = t_tile / best_point.report.ms;
            worst = worst.max(regret);
            per_device.push((s.device_id.clone(), best_point.tile, regret));
        }
        if !ok {
            continue;
        }
        let better = match &best {
            None => true,
            Some(b) => {
                worst < b.worst_regret - 1e-12
                    || ((worst - b.worst_regret).abs() <= 1e-12
                        && tile.aspect() > b.tile.aspect())
            }
        };
        if better {
            best = Some(PortableChoice {
                tile,
                worst_regret: worst,
                per_device,
            });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotuner::sweep::sweep;
    use crate::device::{builtin_devices, paper_pair};
    use crate::image::Interpolator;
    use crate::tiling::paper_sweep_tiles;

    #[test]
    fn portable_pick_matches_paper_conclusion() {
        // Over the paper pair at the large scales, the portable tile is
        // 32x4 ("the tiling dimensions 32x4 seems to be a better choice
        // which can offer better performance in general").
        let (gtx, gts) = paper_pair();
        let tiles = paper_sweep_tiles();
        for scale in [6, 8, 10] {
            let sweeps = vec![
                sweep(&gtx, Interpolator::Bilinear, &tiles, scale, (800, 800)),
                sweep(&gts, Interpolator::Bilinear, &tiles, scale, (800, 800)),
            ];
            let choice = portable_tile(&sweeps).unwrap();
            assert_eq!(choice.tile, "32x4".parse().unwrap(), "scale {scale}");
            assert!(choice.worst_regret < 1.05, "regret {}", choice.worst_regret);
        }
    }

    #[test]
    fn regret_at_least_one() {
        let (gtx, gts) = paper_pair();
        let tiles = paper_sweep_tiles();
        let sweeps = vec![
            sweep(&gtx, Interpolator::Bilinear, &tiles, 2, (800, 800)),
            sweep(&gts, Interpolator::Bilinear, &tiles, 2, (800, 800)),
        ];
        let choice = portable_tile(&sweeps).unwrap();
        assert!(choice.worst_regret >= 1.0 - 1e-12);
        for (_, _, r) in &choice.per_device {
            assert!(*r >= 1.0 - 1e-12);
        }
    }

    #[test]
    fn works_across_whole_registry() {
        // Portable selection over every builtin device (including the
        // synthetic pair and Fermi) still returns a launchable tile.
        let tiles = paper_sweep_tiles();
        let sweeps: Vec<_> = builtin_devices()
            .iter()
            .map(|d| sweep(d, Interpolator::Bilinear, &tiles, 6, (800, 800)))
            .collect();
        let choice = portable_tile(&sweeps).unwrap();
        assert_eq!(choice.per_device.len(), builtin_devices().len());
        assert!(choice.worst_regret < 2.0);
    }

    #[test]
    fn empty_input_is_none() {
        assert!(portable_tile(&[]).is_none());
    }
}
