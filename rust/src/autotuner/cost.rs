//! Cost models: the pluggable "how long does this launch take?" oracle
//! behind every search strategy.
//!
//! The paper's experiments measure wall-clock time on physical GPUs; this
//! repository's substrate is the timing simulator ([`crate::sim`]). The
//! [`CostModel`] trait is the seam between the two: [`SimCostModel`] wraps
//! the simulator, and a measured backend (PJRT timings, an on-device
//! microbenchmark, a learned model) can plug in later without touching any
//! search code. [`CountingCostModel`] wraps any model and counts
//! `evaluate` calls — the currency search strategies compete on.

use crate::device::DeviceDescriptor;
use crate::sim::{simulate, Launch, SimReport};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Anything that can predict (or measure) the execution of one kernel
/// launch on one device.
pub trait CostModel {
    /// Evaluate `launch` on `dev`. Unlaunchable configurations report a
    /// non-finite `ms` (matching the simulator's convention).
    fn evaluate(&self, launch: &Launch, dev: &DeviceDescriptor) -> SimReport;

    /// Short label for reports and tuning-cache provenance.
    fn name(&self) -> String {
        "cost-model".to_string()
    }
}

/// The default cost model: the compute-capability-aware timing simulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimCostModel;

impl CostModel for SimCostModel {
    fn evaluate(&self, launch: &Launch, dev: &DeviceDescriptor) -> SimReport {
        simulate(launch, dev, None)
    }

    fn name(&self) -> String {
        "sim".to_string()
    }
}

/// Decorator counting `evaluate` calls on an inner model. The counter is
/// shared through an `Arc`, so a handle obtained via [`counter`]
/// (`CountingCostModel::counter`) stays readable after the model moves
/// into a [`TuningSession`](super::TuningSession).
pub struct CountingCostModel {
    inner: Box<dyn CostModel>,
    count: Arc<AtomicU64>,
}

impl CountingCostModel {
    /// Wrap `inner`.
    pub fn new(inner: impl CostModel + 'static) -> CountingCostModel {
        CountingCostModel {
            inner: Box::new(inner),
            count: Arc::new(AtomicU64::new(0)),
        }
    }

    /// A shared handle to the call counter.
    pub fn counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.count)
    }

    /// Calls observed so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

impl CostModel for CountingCostModel {
    fn evaluate(&self, launch: &Launch, dev: &DeviceDescriptor) -> SimReport {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.evaluate(launch, dev)
    }

    fn name(&self) -> String {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::paper_pair;
    use crate::image::Interpolator;
    use crate::tiling::TileDim;

    #[test]
    fn sim_cost_model_matches_simulate() {
        let (gtx, _) = paper_pair();
        let l = Launch::paper(Interpolator::Bilinear, TileDim::new(32, 4), 6);
        let a = SimCostModel.evaluate(&l, &gtx);
        let b = simulate(&l, &gtx, None);
        assert_eq!(a.ms, b.ms);
        assert_eq!(a.rounds, b.rounds);
    }

    #[test]
    fn counting_model_counts() {
        let (gtx, gts) = paper_pair();
        let model = CountingCostModel::new(SimCostModel);
        let handle = model.counter();
        let l = Launch::paper(Interpolator::Bilinear, TileDim::new(16, 8), 4);
        let want = SimCostModel.evaluate(&l, &gtx).ms;
        assert_eq!(model.evaluate(&l, &gtx).ms, want);
        model.evaluate(&l, &gts);
        assert_eq!(model.count(), 2);
        assert_eq!(handle.load(Ordering::Relaxed), 2);
        assert_eq!(model.name(), "sim");
    }
}
