//! The tiling autotuner: a strategy-driven tuning API over pluggable
//! cost models, with persistent caching and portable (worst-case-GPU)
//! selection — the paper's §V recommendation to "consider more about the
//! performance on the worst-case GPU in order to let the program get
//! better performance on most GPUs", made re-runnable per device.
//!
//! # Architecture
//!
//! Three seams compose into a session:
//!
//! * [`CostModel`] ([`cost`]) — "how long does this launch take?".
//!   [`SimCostModel`] wraps the timing simulator; measured backends plug
//!   in later. [`CountingCostModel`] audits evaluation spend.
//! * [`SearchStrategy`] ([`strategy`]) — how the tile space is explored:
//!   [`Exhaustive`] (every candidate, the ground truth),
//!   [`CoordinateDescent`] (lattice hill-climb, far fewer evaluations),
//!   and [`Cached`] (decorator over a persistent [`TuningDb`], zero
//!   evaluations on a hit).
//! * [`TuningSession`] ([`session`]) — the builder façade tying a cost
//!   model, a device set, a tile set, and a strategy together, producing
//!   a [`TuningOutcome`] ([`outcome`]) that serializes losslessly to JSON
//!   (`tuning_cache.json`, keyed by device id / kernel / scale / size).
//!
//! Downstream, [`crate::coordinator::TilePolicy`] routes serving traffic
//! straight off an outcome (`PerDevice`), so a freshly tuned device gets
//! its own tile without touching the serving code — exactly the failure
//! mode the paper warns about ("an optimized tiling strategy on one GPU
//! model is not always a good solution ... on other GPU models").
//!
//! # Migrating from `sweep` / `portable_tile`
//!
//! The free functions remain as the low-level primitives, but callers
//! should move to the session:
//!
//! ```text
//! // before                                   // after
//! let sweeps = vec![                          let outcome = TuningSession::new(SimCostModel)
//!     sweep(&gtx, k, &tiles, 8, src),             .devices([gtx, gts])
//!     sweep(&gts, k, &tiles, 8, src),             .kernel(k).scale(8).src(src)
//! ];                                              .tiles(tiles)
//! let best = sweeps[0].best();                    .run()?;
//! let choice = portable_tile(&sweeps);        let best = outcome.best_for("gtx260");
//!                                             let choice = &outcome.portable;
//! ```
//!
//! What maps where:
//!
//! * `SweepResult` per device → [`DeviceTuning`] (in
//!   `outcome.per_device`), including `best`, `time_of`, `range_ms`.
//! * `PortableChoice` → `outcome.portable` (same type, same min-max
//!   regret rule, now NaN-safe via `f64::total_cmp`).
//! * New capabilities: swap [`CoordinateDescent`] in via
//!   [`TuningSession::strategy`], persist results with [`Cached`] /
//!   [`TuningDb`], serialize via [`TuningOutcome::to_json`], and count
//!   evaluations with [`CountingCostModel`].

pub mod cost;
pub mod db;
pub mod outcome;
pub mod portable;
pub mod session;
pub mod strategy;
pub mod sweep;

pub use cost::{CostModel, CountingCostModel, SimCostModel};
pub use db::{DbEntry, TuningDb};
pub use outcome::{DeviceTuning, TunedPoint, TuningOutcome};
pub use portable::{portable_over, portable_tile, PortableChoice};
pub use session::TuningSession;
pub use strategy::{
    strategy_by_name, Cached, CoordinateDescent, Exhaustive, SearchSpace, SearchStrategy,
    STRATEGY_NAMES,
};
pub use sweep::{sweep, SweepPoint, SweepResult};
