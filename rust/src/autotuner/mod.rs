//! The tiling autotuner: sweep candidate tiles on one or more devices,
//! extract the best tile per device, and compute a *portable* tile — the
//! paper's §V recommendation to "consider more about the performance on
//! the worst-case GPU in order to let the program get better performance
//! on most GPUs".

pub mod portable;
pub mod sweep;

pub use portable::{portable_tile, PortableChoice};
pub use sweep::{sweep, SweepPoint, SweepResult};
