//! The persistent tuning database behind the [`Cached`](super::Cached)
//! strategy and the CLI's `--cache` flag.
//!
//! A [`TuningDb`] is a flat map from (device id, kernel, scale, source
//! size) to the [`DeviceTuning`] found there, serialized as one versioned
//! JSON document (`tuning_cache.json` by convention). The file format is
//! stable and diff-friendly: sorted keys, pretty-printed, one entry per
//! tuned combination — re-tuning when a new GPU model appears is an
//! append, exactly the re-runnable workflow the paper's "not always a
//! good solution ... on other GPU models" finding demands.

use super::outcome::{arr_field, str_field, u64_field, DeviceTuning, TuningOutcome};
use super::portable::portable_over;
use crate::codec::json::Json;
use crate::image::Interpolator;
use crate::tiling::TileDim;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One stored tuning with its full key.
#[derive(Debug, Clone, PartialEq)]
pub struct DbEntry {
    pub kernel: Interpolator,
    pub scale: u32,
    pub src: (u32, u32),
    /// Name of the strategy that produced the points. Part of the key: a
    /// coordinate-descent path (a handful of points) must never
    /// masquerade as an exhaustive sweep, and entries from different
    /// strategies coexist in one file.
    pub strategy: String,
    /// Fingerprint of the candidate tile set the search ran over. Part
    /// of the key: results for different candidate sets are not
    /// interchangeable.
    pub tiles: String,
    pub tuning: DeviceTuning,
}

/// A persistent map of tuning results.
#[derive(Debug, Clone, Default)]
pub struct TuningDb {
    path: Option<PathBuf>,
    entries: BTreeMap<String, DbEntry>,
}

impl TuningDb {
    /// An unbacked database (nothing is persisted).
    pub fn in_memory() -> TuningDb {
        TuningDb::default()
    }

    /// Open (or start) the database at `path`. A missing file is an empty
    /// database; the file is created on the first [`persist`](Self::persist).
    pub fn open(path: &Path) -> Result<TuningDb> {
        let mut db = TuningDb {
            path: Some(path.to_path_buf()),
            entries: BTreeMap::new(),
        };
        if path.exists() {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading tuning cache {}", path.display()))?;
            db.entries = Self::from_json_str(&text)
                .with_context(|| format!("in tuning cache {}", path.display()))?
                .entries;
        }
        Ok(db)
    }

    /// Parse a database from JSON text, unbacked by a file
    /// ([`persist`](Self::persist) is a no-op). The
    /// [`RetuneDaemon`](crate::coordinator::RetuneDaemon) uses this to
    /// parse the bytes it already read for change detection, instead of
    /// re-reading the file.
    pub fn from_json_str(text: &str) -> Result<TuningDb> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        Ok(TuningDb {
            path: None,
            entries: Self::entries_from_json(&j)?,
        })
    }

    /// Stable fingerprint of a candidate tile set (FNV-1a over the
    /// SORTED labels — the key is about the set, so the order the tiles
    /// were listed in must not matter): results searched over different
    /// candidate sets must not be served for one another.
    pub fn tiles_fingerprint(tiles: &[TileDim]) -> String {
        let mut labels: Vec<String> = tiles.iter().map(|t| t.label()).collect();
        labels.sort();
        let h = crate::util::fnv1a64(
            labels
                .iter()
                .flat_map(|l| l.bytes().chain([b';'])),
        );
        format!("{h:016x}")
    }

    /// The canonical cache key for one tuned combination. The paper's
    /// experimental axes (device, kernel, scale, size) plus the two
    /// facts that make results non-interchangeable: the strategy and the
    /// candidate tile set.
    pub fn key(
        device_id: &str,
        kernel: Interpolator,
        scale: u32,
        src: (u32, u32),
        strategy: &str,
        tiles_fp: &str,
    ) -> String {
        format!(
            "{device_id}|{}|{scale}|{}x{}|{strategy}|{tiles_fp}",
            kernel.label(),
            src.0,
            src.1
        )
    }

    /// Look up a stored tuning.
    pub fn get(
        &self,
        device_id: &str,
        kernel: Interpolator,
        scale: u32,
        src: (u32, u32),
        strategy: &str,
        tiles_fp: &str,
    ) -> Option<&DeviceTuning> {
        self.entries
            .get(&Self::key(device_id, kernel, scale, src, strategy, tiles_fp))
            .map(|e| &e.tuning)
    }

    /// Insert (or replace) a tuning; the device id comes from the tuning
    /// record itself.
    pub fn insert(
        &mut self,
        kernel: Interpolator,
        scale: u32,
        src: (u32, u32),
        strategy: &str,
        tiles_fp: &str,
        tuning: DeviceTuning,
    ) {
        let key = Self::key(&tuning.device_id, kernel, scale, src, strategy, tiles_fp);
        self.entries.insert(
            key,
            DbEntry {
                kernel,
                scale,
                src,
                strategy: strategy.to_string(),
                tiles: tiles_fp.to_string(),
                tuning,
            },
        );
    }

    /// Assemble a routable [`TuningOutcome`] for `device_ids` from the
    /// stored tunings of one (kernel, scale, src, strategy, tile-set)
    /// key — the bridge from a refreshed cache to
    /// [`FleetController::retune`](crate::coordinator::FleetController::retune):
    /// reload the db, call `outcome_for`, hand the outcome to `retune`
    /// and the member hot-swaps to the new winner (the
    /// [`RetuneDaemon`](crate::coordinator::RetuneDaemon) automates
    /// exactly this). Returns `None` when any of
    /// the requested devices has no stored tuning (a partial fleet
    /// outcome would silently fall back to the portable tile for the
    /// missing members, hiding the staleness this API exists to fix).
    pub fn outcome_for(
        &self,
        kernel: Interpolator,
        scale: u32,
        src: (u32, u32),
        strategy: &str,
        tiles_fp: &str,
        device_ids: &[&str],
    ) -> Option<TuningOutcome> {
        let per_device: Vec<DeviceTuning> = device_ids
            .iter()
            .map(|id| {
                self.get(id, kernel, scale, src, strategy, tiles_fp)
                    .cloned()
            })
            .collect::<Option<Vec<_>>>()?;
        let evaluations = per_device.iter().map(|d| d.evaluations).sum();
        let portable = portable_over(&per_device);
        Some(TuningOutcome {
            kernel,
            scale,
            src,
            strategy: strategy.to_string(),
            evaluations,
            per_device,
            portable,
        })
    }

    /// Number of stored tunings.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the database empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The backing file, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Stored entries in key order.
    pub fn entries(&self) -> impl Iterator<Item = (&String, &DbEntry)> {
        self.entries.iter()
    }

    /// Write the database to its backing file (no-op when in-memory).
    pub fn persist(&self) -> Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing tuning cache {}", path.display()))
    }

    /// Serialize to a versioned JSON document.
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .values()
            .map(|e| {
                e.tuning
                    .to_json()
                    .set("kernel", e.kernel.label())
                    .set("scale", e.scale)
                    .set("src", vec![e.src.0, e.src.1])
                    .set("strategy", e.strategy.as_str())
                    .set("tiles", e.tiles.as_str())
            })
            .collect();
        Json::obj()
            .set("version", 1u64)
            .set("entries", Json::Arr(entries))
    }

    fn entries_from_json(j: &Json) -> Result<BTreeMap<String, DbEntry>> {
        match j.get("version").and_then(Json::as_u64) {
            Some(1) => {}
            Some(v) => bail!("unsupported tuning cache version {v}"),
            None => bail!("tuning cache is missing 'version'"),
        }
        let mut entries = BTreeMap::new();
        for e in arr_field(j, "entries")? {
            let kernel_s = str_field(e, "kernel")?;
            let kernel = Interpolator::parse(&kernel_s)
                .ok_or_else(|| anyhow!("unknown kernel '{kernel_s}'"))?;
            let scale = u64_field(e, "scale")? as u32;
            let src_arr = arr_field(e, "src")?;
            if src_arr.len() != 2 {
                bail!("'src' must be a [w, h] pair");
            }
            let src = (
                src_arr[0].as_u64().context("src[0]")? as u32,
                src_arr[1].as_u64().context("src[1]")? as u32,
            );
            let strategy = str_field(e, "strategy")?;
            let tiles = str_field(e, "tiles")?;
            let tuning = DeviceTuning::from_json(e)?;
            let key = Self::key(&tuning.device_id, kernel, scale, src, &strategy, &tiles);
            entries.insert(
                key,
                DbEntry {
                    kernel,
                    scale,
                    src,
                    strategy,
                    tiles,
                    tuning,
                },
            );
        }
        Ok(entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotuner::outcome::TunedPoint;
    use crate::tiling::TileDim;

    fn tuning(id: &str) -> DeviceTuning {
        DeviceTuning::from_points(
            id.to_string(),
            vec![
                TunedPoint {
                    tile: TileDim::new(32, 4),
                    ms: 1.5,
                },
                TunedPoint {
                    tile: TileDim::new(8, 8),
                    ms: 2.25,
                },
            ],
            2,
        )
        .unwrap()
    }

    fn fp() -> String {
        TuningDb::tiles_fingerprint(&[TileDim::new(32, 4), TileDim::new(8, 8)])
    }

    #[test]
    fn insert_get_and_key_axes() {
        let mut db = TuningDb::in_memory();
        let fp = fp();
        db.insert(
            Interpolator::Bilinear,
            8,
            (800, 800),
            "exhaustive",
            &fp,
            tuning("gtx260"),
        );
        assert_eq!(db.len(), 1);
        let hit = db
            .get("gtx260", Interpolator::Bilinear, 8, (800, 800), "exhaustive", &fp)
            .unwrap();
        assert_eq!(hit.best, TileDim::new(32, 4));
        // every key axis matters
        for (dev, k, s, src) in [
            ("8800gts", Interpolator::Bilinear, 8, (800, 800)),
            ("gtx260", Interpolator::Nearest, 8, (800, 800)),
            ("gtx260", Interpolator::Bilinear, 6, (800, 800)),
            ("gtx260", Interpolator::Bilinear, 8, (400, 400)),
        ] {
            assert!(db.get(dev, k, s, src, "exhaustive", &fp).is_none());
        }
        // a descent run must not be served an exhaustive entry (the point
        // sets are not interchangeable) ...
        assert!(db
            .get("gtx260", Interpolator::Bilinear, 8, (800, 800), "descent", &fp)
            .is_none());
        // ... nor a run over a different candidate tile set
        let other_fp = TuningDb::tiles_fingerprint(&[TileDim::new(16, 16)]);
        assert_ne!(fp, other_fp);
        assert!(db
            .get(
                "gtx260",
                Interpolator::Bilinear,
                8,
                (800, 800),
                "exhaustive",
                &other_fp
            )
            .is_none());
        // entries for both strategies coexist under one (device, kernel,
        // scale, size)
        db.insert(
            Interpolator::Bilinear,
            8,
            (800, 800),
            "descent",
            &fp,
            tuning("gtx260"),
        );
        assert_eq!(db.len(), 2);
        assert!(db
            .get("gtx260", Interpolator::Bilinear, 8, (800, 800), "exhaustive", &fp)
            .is_some());
    }

    #[test]
    fn tiles_fingerprint_is_order_insensitive_but_set_sensitive() {
        let a = TuningDb::tiles_fingerprint(&[TileDim::new(32, 4), TileDim::new(8, 8)]);
        let b = TuningDb::tiles_fingerprint(&[TileDim::new(8, 8), TileDim::new(32, 4)]);
        assert_eq!(a, b, "two listings of the same set share one key");
        let c = TuningDb::tiles_fingerprint(&[TileDim::new(8, 8)]);
        assert_ne!(a, c, "different sets stay distinct");
    }

    #[test]
    fn from_json_str_parses_unbacked() {
        let mut db = TuningDb::in_memory();
        db.insert(
            Interpolator::Bilinear,
            8,
            (800, 800),
            "exhaustive",
            &fp(),
            tuning("gtx260"),
        );
        let back = TuningDb::from_json_str(&db.to_json().pretty()).unwrap();
        assert_eq!(back.len(), 1);
        assert!(back.path().is_none());
        assert!(TuningDb::from_json_str("not json").is_err());
    }

    #[test]
    fn outcome_for_assembles_fleet_outcomes() {
        let mut db = TuningDb::in_memory();
        let fp = fp();
        db.insert(
            Interpolator::Bilinear,
            8,
            (800, 800),
            "exhaustive",
            &fp,
            tuning("gtx260"),
        );
        // Missing member -> None (a partial outcome would hide staleness).
        assert!(db
            .outcome_for(
                Interpolator::Bilinear,
                8,
                (800, 800),
                "exhaustive",
                &fp,
                &["gtx260", "8800gts"]
            )
            .is_none());
        db.insert(
            Interpolator::Bilinear,
            8,
            (800, 800),
            "exhaustive",
            &fp,
            tuning("8800gts"),
        );
        let outcome = db
            .outcome_for(
                Interpolator::Bilinear,
                8,
                (800, 800),
                "exhaustive",
                &fp,
                &["gtx260", "8800gts"],
            )
            .unwrap();
        assert_eq!(outcome.per_device.len(), 2);
        assert_eq!(outcome.best_for("gtx260"), Some(TileDim::new(32, 4)));
        assert_eq!(outcome.evaluations, 4);
        assert!(outcome.portable_tile().is_some());
        // Wrong key axes still miss.
        assert!(db
            .outcome_for(Interpolator::Nearest, 8, (800, 800), "exhaustive", &fp, &["gtx260"])
            .is_none());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("tilekit_tuning_db_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        std::fs::remove_file(&path).ok();
        let fp = fp();

        let mut db = TuningDb::open(&path).unwrap();
        assert!(db.is_empty());
        db.insert(
            Interpolator::Bilinear,
            8,
            (800, 800),
            "exhaustive",
            &fp,
            tuning("gtx260"),
        );
        db.insert(
            Interpolator::Bilinear,
            6,
            (800, 800),
            "descent",
            &fp,
            tuning("8800gts"),
        );
        db.persist().unwrap();

        let back = TuningDb::open(&path).unwrap();
        assert_eq!(back.len(), 2);
        let hit = back
            .get("gtx260", Interpolator::Bilinear, 8, (800, 800), "exhaustive", &fp)
            .unwrap();
        assert_eq!(hit.points, tuning("gtx260").points);
        assert!(back
            .get("8800gts", Interpolator::Bilinear, 6, (800, 800), "descent", &fp)
            .is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_malformed_cache() {
        let dir = std::env::temp_dir().join("tilekit_tuning_db_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{\"version\": 99, \"entries\": []}").unwrap();
        assert!(TuningDb::open(&path).is_err());
        std::fs::write(&path, "not json").unwrap();
        assert!(TuningDb::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
