//! A miniature property-based testing framework (the offline environment
//! has no `proptest`). It provides the subset the test suite needs:
//! seeded generators, `forall`-style runners with a configurable case
//! count, and failure reports that print the reproducing seed.
//!
//! ```no_run
//! # // no_run: rustdoc test binaries don't inherit the xla rpath flags
//! use tilekit::prop::{forall, prop_assert};
//! forall("addition commutes", 200, |g| {
//!     let a = g.u32(0, 1000);
//!     let b = g.u32(0, 1000);
//!     prop_assert(a + b == b + a, format!("{a} {b}"))
//! });
//! ```

use crate::util::Pcg32;

/// Generator handle passed to property bodies.
pub struct Gen {
    rng: Pcg32,
    /// Trace of drawn values, printed on failure for reproduction.
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen {
            rng: Pcg32::new(seed, 0xF00D),
            trace: Vec::new(),
        }
    }

    pub fn u32(&mut self, lo: u32, hi: u32) -> u32 {
        let v = self.rng.range_u32(lo, hi);
        self.trace.push(format!("u32[{lo},{hi}]={v}"));
        v
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range_usize(lo, hi);
        self.trace.push(format!("usize[{lo},{hi}]={v}"));
        v
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + self.rng.f64() * (hi - lo);
        self.trace.push(format!("f64[{lo},{hi}]={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.trace.push(format!("bool={v}"));
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T: std::fmt::Debug>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len() as u32) as usize;
        self.trace.push(format!("choose[{}]={:?}", i, xs[i]));
        &xs[i]
    }

    /// A power of two in `[2^lo_exp, 2^hi_exp]`.
    pub fn pow2(&mut self, lo_exp: u32, hi_exp: u32) -> u32 {
        let e = self.rng.range_u32(lo_exp, hi_exp);
        let v = 1u32 << e;
        self.trace.push(format!("pow2[{lo_exp},{hi_exp}]={v}"));
        v
    }

    /// A vector of `n` values drawn by `f`.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }
}

/// Property outcome: `Ok(())` or a failure message.
pub type PropResult = Result<(), String>;

/// Assert inside a property body.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality helper for property bodies.
pub fn prop_close(a: f64, b: f64, tol: f64, label: &str) -> PropResult {
    prop_assert(
        (a - b).abs() <= tol,
        format!("{label}: {a} vs {b} (tol {tol})"),
    )
}

/// Run `body` for `cases` seeded cases. Panics with the seed and the
/// drawn-value trace on the first failure. The base seed is fixed for
/// reproducibility; set `TILEKIT_PROP_SEED` to explore other streams.
pub fn forall(name: &str, cases: u32, mut body: impl FnMut(&mut Gen) -> PropResult) {
    let base: u64 = std::env::var("TILEKIT_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xB10C_5EED);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut g = Gen::new(seed);
        if let Err(msg) = body(&mut g) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed}):\n  {msg}\n  trace: {}",
                g.trace.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("count", 50, |g| {
            let _ = g.u32(0, 10);
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        forall("fails", 10, |g| {
            let v = g.u32(0, 100);
            prop_assert(v < 1000 && false, format!("v={v}"))
        });
    }

    #[test]
    fn generators_respect_bounds() {
        forall("bounds", 200, |g| {
            let a = g.u32(5, 9);
            prop_assert((5..=9).contains(&a), format!("u32 {a}"))?;
            let f = g.f64(-1.0, 1.0);
            prop_assert((-1.0..1.0).contains(&f), format!("f64 {f}"))?;
            let p = g.pow2(2, 5);
            prop_assert([4, 8, 16, 32].contains(&p), format!("pow2 {p}"))
        });
    }

    #[test]
    fn choose_covers_all() {
        let mut seen = [false; 3];
        forall("choose", 100, |g| {
            let v = *g.choose(&[0usize, 1, 2]);
            seen[v] = true;
            Ok(())
        });
        assert!(seen.iter().all(|&s| s));
    }
}
