//! HLO-text inspection: lightweight structural statistics over the AOT
//! artifacts — the L2 "profiler" of this stack. XLA's own cost analysis
//! lives behind the C++ API; for the perf story we need exactly the
//! structure the tile choice changes: module size, instruction count,
//! control flow (while loops = Pallas grid steps after interpret
//! lowering), gathers/dynamic-slices (the interpolation taps), and
//! fusion count.

use anyhow::{Context, Result};
use std::path::Path;

/// Structural statistics of one HLO text module.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct HloStats {
    /// File size in bytes.
    pub bytes: u64,
    /// Total instruction lines (assignments inside computations).
    pub instructions: u64,
    /// `while` ops — the Pallas grid loop(s); fewer/larger tiles shrink
    /// the trip count, not this number, but a whole-image tile removes
    /// the loop entirely.
    pub whiles: u64,
    /// gather + dynamic-slice ops (the interpolation taps / windows).
    pub gathers: u64,
    /// dynamic-update-slice ops (output tile writes).
    pub dus: u64,
    /// fusion ops (XLA's fused kernels).
    pub fusions: u64,
    /// Named computations in the module.
    pub computations: u64,
}

/// Parse statistics out of HLO text.
pub fn stats_of_text(text: &str) -> HloStats {
    let mut s = HloStats {
        bytes: text.len() as u64,
        ..Default::default()
    };
    for line in text.lines() {
        let t = line.trim_start();
        // computation headers look like `%name (args) -> type {` or
        // `ENTRY %name ...`
        if (t.starts_with('%') || t.starts_with("ENTRY")) && t.contains(") ->") {
            s.computations += 1;
            continue;
        }
        // instruction lines: `%x = type op(...)` / `x.1 = type op(...)`
        let Some(eq) = t.find(" = ") else { continue };
        if !t.starts_with('%') && !t
            .chars()
            .next()
            .map(|c| c.is_ascii_alphanumeric())
            .unwrap_or(false)
        {
            continue;
        }
        s.instructions += 1;
        let rhs = &t[eq + 3..];
        // The op name appears as ` op-name(` after the result type; the
        // type may be a tuple containing spaces, so match substrings
        // (checking dynamic-update-slice before dynamic-slice).
        if rhs.contains(" while(") {
            s.whiles += 1;
        } else if rhs.contains(" gather(") {
            s.gathers += 1;
        } else if rhs.contains(" dynamic-update-slice(") {
            s.dus += 1;
        } else if rhs.contains(" dynamic-slice(") {
            s.gathers += 1;
        } else if rhs.contains(" fusion(") {
            s.fusions += 1;
        }
    }
    s
}

/// Load + analyze one artifact file.
pub fn stats_of_file(path: &Path) -> Result<HloStats> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Ok(stats_of_text(&text))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_resize_batch

%region_0.20 (arg_tuple.1: (s32[], f32[64,64], f32[128,128])) -> (s32[], f32[64,64], f32[128,128]) {
  %arg_tuple.1 = (s32[], f32[64,64], f32[128,128]) parameter(0)
  %gte = s32[] get-tuple-element((s32[], f32[64,64], f32[128,128]) %arg_tuple.1), index=0
  %g.1 = f32[4,32]{1,0} gather(f32[64,64]{1,0} %p, s32[4,32,2]{2,1,0} %idx), offset_dims={}
  %ds.1 = f32[1,32]{1,0} dynamic-slice(f32[64,64]{1,0} %p, s32[] %a, s32[] %b), dynamic_slice_sizes={1,32}
  %dus.1 = f32[128,128]{1,0} dynamic-update-slice(f32[128,128]{1,0} %acc, f32[4,32]{1,0} %t, s32[] %a, s32[] %b)
}

ENTRY %main.42 (Arg_0.1: f32[4,64,64]) -> (f32[4,128,128]) {
  %Arg_0.1 = f32[4,64,64]{2,1,0} parameter(0)
  %w.1 = (s32[], f32[64,64], f32[128,128]) while((s32[], f32[64,64], f32[128,128]) %init), condition=%cond, body=%region_0.20
  %f.1 = f32[4,128,128]{2,1,0} fusion(f32[4,64,64]{2,1,0} %Arg_0.1), kind=kLoop, calls=%fused
  ROOT %tuple.1 = (f32[4,128,128]{2,1,0}) tuple(f32[4,128,128]{2,1,0} %f.1)
}
"#;

    #[test]
    fn counts_ops() {
        let s = stats_of_text(SAMPLE);
        assert_eq!(s.whiles, 1);
        assert_eq!(s.gathers, 2); // gather + dynamic-slice
        assert_eq!(s.dus, 1);
        assert_eq!(s.fusions, 1);
        assert_eq!(s.computations, 2);
        assert!(s.instructions >= 8);
        assert_eq!(s.bytes, SAMPLE.len() as u64);
    }

    #[test]
    fn empty_module() {
        let s = stats_of_text("HloModule empty\n");
        assert_eq!(s.instructions, 0);
        assert_eq!(s.whiles, 0);
    }

    #[test]
    fn real_artifacts_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let Ok(manifest) = crate::runtime::Manifest::load(&dir) else {
            eprintln!("SKIP: no artifacts");
            return;
        };
        // Whole-image tiles must have strictly fewer instructions than
        // the 32x4 grid variant of the same shape (the §Perf L2 claim).
        let small = manifest
            .entries
            .iter()
            .find(|e| e.name == "bilinear_s2_b4_t32x4_64x64");
        let whole = manifest
            .entries
            .iter()
            .find(|e| e.name == "bilinear_s2_b4_t128x128_64x64");
        let (Some(a), Some(b)) = (small, whole) else {
            eprintln!("SKIP: variants missing");
            return;
        };
        let sa = stats_of_file(&manifest.hlo_path(a)).unwrap();
        let sb = stats_of_file(&manifest.hlo_path(b)).unwrap();
        assert!(sa.instructions > 0 && sb.instructions > 0);
        assert!(
            sb.whiles < sa.whiles || sb.instructions < sa.instructions,
            "whole-image tile should simplify the module: {sa:?} vs {sb:?}"
        );
    }
}
