//! A mock backend implementing [`ResizeBackend`] with the CPU reference
//! interpolators. Used by coordinator unit/property tests (no artifacts
//! needed) and by `tilekit serve --mock`. Optionally injects failures and
//! artificial latency for resilience tests.

use super::artifact::ArtifactEntry;
use super::ResizeBackend;
use crate::image::{Image, Interpolator};
use crate::metrics::Counter;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// CPU-reference backend with optional fault injection.
#[derive(Default)]
pub struct MockEngine {
    /// Fail every Nth batch (0 = never) — exercises the coordinator's
    /// error propagation.
    pub fail_every: u64,
    /// Artificial per-batch latency.
    pub delay: Option<Duration>,
    batches: AtomicU64,
    /// Executed batch counter (observable by tests).
    pub executed: Counter,
}

impl MockEngine {
    pub fn new() -> MockEngine {
        MockEngine::default()
    }

    pub fn failing_every(n: u64) -> MockEngine {
        MockEngine {
            fail_every: n,
            ..MockEngine::default()
        }
    }

    pub fn with_delay(d: Duration) -> MockEngine {
        MockEngine {
            delay: Some(d),
            ..MockEngine::default()
        }
    }
}

impl ResizeBackend for MockEngine {
    fn run_batch(&self, entry: &ArtifactEntry, batch: &[Image<f32>]) -> Result<Vec<Image<f32>>> {
        if batch.is_empty() || batch.len() > entry.batch as usize {
            bail!(
                "batch size {} out of range for '{}' (max {})",
                batch.len(),
                entry.name,
                entry.batch
            );
        }
        let n = self.batches.fetch_add(1, Ordering::Relaxed) + 1;
        if self.fail_every > 0 && n % self.fail_every == 0 {
            bail!("injected failure on batch {n}");
        }
        if let Some(d) = self.delay {
            std::thread::sleep(d);
        }
        let (sh, sw) = (entry.src.0 as usize, entry.src.1 as usize);
        let mut out = Vec::with_capacity(batch.len());
        for img in batch {
            if img.width() != sw || img.height() != sh {
                bail!(
                    "image {}x{} does not match artifact src {sw}x{sh}",
                    img.width(),
                    img.height()
                );
            }
            out.push(run_reference(entry.kernel, img, entry.scale));
        }
        self.executed.inc();
        Ok(out)
    }
}

fn run_reference(kernel: Interpolator, img: &Image<f32>, scale: u32) -> Image<f32> {
    kernel.run(img, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::generate;
    use crate::tiling::TileDim;

    fn entry(batch: u32) -> ArtifactEntry {
        ArtifactEntry {
            name: format!("mock_b{batch}"),
            kernel: Interpolator::Bilinear,
            src: (16, 16),
            scale: 2,
            batch,
            tile: TileDim::new(32, 4),
            path: "unused".into(),
        }
    }

    #[test]
    fn resizes_via_reference() {
        let m = MockEngine::new();
        let img = generate::test_scene(16, 16, 3);
        let out = m.run_batch(&entry(4), &[img.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].width(), 32);
        let want = crate::image::bilinear(&img, 2);
        assert!(out[0].max_abs_diff(&want) < 1e-6);
        assert_eq!(m.executed.get(), 1);
    }

    #[test]
    fn rejects_oversized_batch() {
        let m = MockEngine::new();
        let img = generate::gradient(16, 16);
        let batch: Vec<_> = (0..5).map(|_| img.clone()).collect();
        assert!(m.run_batch(&entry(4), &batch).is_err());
        assert!(m.run_batch(&entry(4), &[]).is_err());
    }

    #[test]
    fn rejects_wrong_shape() {
        let m = MockEngine::new();
        let img = generate::gradient(8, 8);
        assert!(m.run_batch(&entry(1), &[img]).is_err());
    }

    #[test]
    fn fault_injection_fires() {
        let m = MockEngine::failing_every(2);
        let img = generate::gradient(16, 16);
        assert!(m.run_batch(&entry(1), &[img.clone()]).is_ok());
        assert!(m.run_batch(&entry(1), &[img.clone()]).is_err());
        assert!(m.run_batch(&entry(1), &[img]).is_ok());
    }
}
