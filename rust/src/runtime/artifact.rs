//! The artifact manifest: the contract between `python/compile/aot.py`
//! (which writes `artifacts/manifest.json` + one `.hlo.txt` per entry)
//! and the rust runtime (which loads and executes them).

use crate::codec::json::Json;
use crate::image::Interpolator;
use crate::tiling::TileDim;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Unique name, e.g. `bilinear_s2_b4_t32x4_64x64`.
    pub name: String,
    /// Interpolation kernel.
    pub kernel: Interpolator,
    /// Source image size (h, w).
    pub src: (u32, u32),
    /// Integer scale factor.
    pub scale: u32,
    /// Static batch size of the compiled executable.
    pub batch: u32,
    /// Pallas output-tile shape baked into the kernel (y, x order in the
    /// manifest; exposed as a TileDim).
    pub tile: TileDim,
    /// Path to the HLO text, relative to the manifest's directory.
    pub path: String,
}

impl ArtifactEntry {
    /// Output image size (h, w).
    pub fn dst(&self) -> (u32, u32) {
        (self.src.0 * self.scale, self.src.1 * self.scale)
    }

    fn from_json(j: &Json) -> Result<ArtifactEntry> {
        let s = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("artifact entry missing '{k}'"))
        };
        let n = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("artifact entry missing numeric '{k}'"))
        };
        let pair = |k: &str| -> Result<(u32, u32)> {
            let arr = j
                .get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact entry missing pair '{k}'"))?;
            if arr.len() != 2 {
                bail!("'{k}' must have 2 entries");
            }
            Ok((
                arr[0].as_u64().ok_or_else(|| anyhow!("bad '{k}'"))? as u32,
                arr[1].as_u64().ok_or_else(|| anyhow!("bad '{k}'"))? as u32,
            ))
        };
        let kernel_s = s("kernel")?;
        let kernel = Interpolator::parse(&kernel_s)
            .ok_or_else(|| anyhow!("unknown kernel '{kernel_s}'"))?;
        let (ty, tx) = pair("tile")?;
        Ok(ArtifactEntry {
            name: s("name")?,
            kernel,
            src: pair("src")?,
            scale: n("scale")? as u32,
            batch: n("batch")? as u32,
            tile: TileDim::new(tx, ty),
            path: s("path")?,
        })
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub version: u64,
    pub entries: Vec<ArtifactEntry>,
    /// Directory the manifest was loaded from (artifact paths resolve
    /// against it).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir.to_path_buf())
    }

    /// Parse manifest JSON.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = j
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("manifest missing 'version'"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let arr = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let entries = arr
            .iter()
            .map(ArtifactEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        if names.len() != n {
            bail!("duplicate artifact names in manifest");
        }
        Ok(Manifest {
            version,
            entries,
            dir,
        })
    }

    /// The built-in fleet demo manifest: one bilinear 64x64/s2 shape
    /// compiled (notionally) at the two tile variants whose preference
    /// flips between GPU models in the simulator — 16x8 (best on cc1.3
    /// segmented coalescing, e.g. GTX 260) vs 32x16 (best on cc2.0's
    /// cached-warp Fermi). Shared by `tilekit serve --mock` (when no
    /// artifacts exist), `examples/fleet_serving.rs`, and the fleet
    /// acceptance tests, so their tile assertions stay in lockstep.
    /// `tilekit serve --mock --tiles` swaps the tile list via
    /// [`fleet_demo_with_tiles`](Manifest::fleet_demo_with_tiles).
    /// Mock-only: the HLO paths do not exist.
    pub fn fleet_demo() -> Manifest {
        Self::fleet_demo_with_tiles(&[TileDim::new(16, 8), TileDim::new(32, 16)])
            .expect("builtin fleet demo tile set is valid")
    }

    /// The fleet demo manifest over an explicit tile set: one bilinear
    /// 64x64/s2 shape, each tile "compiled" at static batch 1 and 4.
    /// Errors on an empty or duplicated tile list, so demos fail loudly
    /// instead of silently depending on a baked-in set.
    pub fn fleet_demo_with_tiles(tiles: &[TileDim]) -> Result<Manifest> {
        if tiles.is_empty() {
            bail!("fleet demo needs at least one tile");
        }
        let mut seen: Vec<TileDim> = Vec::new();
        let mut entries = Vec::with_capacity(tiles.len() * 2);
        for &tile in tiles {
            if seen.contains(&tile) {
                bail!("duplicate tile {tile} in fleet demo tile set");
            }
            seen.push(tile);
            for batch in [1u32, 4] {
                entries.push(ArtifactEntry {
                    name: format!("bl_s2_b{batch}_t{tile}"),
                    kernel: Interpolator::Bilinear,
                    src: (64, 64),
                    scale: 2,
                    batch,
                    tile,
                    path: "x".into(),
                });
            }
        }
        Ok(Manifest {
            version: 1,
            entries,
            dir: PathBuf::from("."),
        })
    }

    /// Drop every entry whose tile is not in `tiles` (the `--tiles`
    /// restriction applied to a loaded artifact set).
    pub fn retain_tiles(&mut self, tiles: &[TileDim]) {
        self.entries.retain(|e| tiles.contains(&e.tile));
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.path)
    }

    /// Find entries matching a request shape, preferring `tile_pref` and
    /// then the smallest batch ≥ `min_batch` (the router's lookup).
    pub fn select(
        &self,
        kernel: Interpolator,
        src: (u32, u32),
        scale: u32,
        min_batch: u32,
        tile_pref: Option<TileDim>,
    ) -> Option<&ArtifactEntry> {
        let mut cands: Vec<&ArtifactEntry> = self
            .entries
            .iter()
            .filter(|e| e.kernel == kernel && e.src == src && e.scale == scale)
            .collect();
        cands.sort_by_key(|e| {
            let batch_ok = e.batch >= min_batch;
            let tile_match = tile_pref.map(|t| e.tile == t).unwrap_or(true);
            // prefer: batch big enough, tile match, then smallest batch
            (
                !batch_ok,
                !tile_match,
                if batch_ok { e.batch } else { u32::MAX - e.batch },
            )
        });
        cands.into_iter().next()
    }

    /// All (kernel, src, scale) combos available.
    pub fn shapes(&self) -> Vec<(Interpolator, (u32, u32), u32)> {
        let mut v: Vec<_> = self
            .entries
            .iter()
            .map(|e| (e.kernel, e.src, e.scale))
            .collect();
        v.sort_by_key(|&(k, s, sc)| (k.label(), s, sc));
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "bilinear_s2_b1_t32x4_64x64", "kernel": "bilinear",
         "src": [64, 64], "scale": 2, "batch": 1, "tile": [4, 32],
         "path": "bilinear_s2_b1_t32x4_64x64.hlo.txt"},
        {"name": "bilinear_s2_b4_t32x4_64x64", "kernel": "bilinear",
         "src": [64, 64], "scale": 2, "batch": 4, "tile": [4, 32],
         "path": "bilinear_s2_b4_t32x4_64x64.hlo.txt"},
        {"name": "nearest_s4_b1_t8x8_64x64", "kernel": "nearest",
         "src": [64, 64], "scale": 4, "batch": 1, "tile": [8, 8],
         "path": "nearest_s4_b1_t8x8_64x64.hlo.txt"}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.entries.len(), 3);
        let e = &m.entries[0];
        assert_eq!(e.kernel, Interpolator::Bilinear);
        assert_eq!(e.src, (64, 64));
        assert_eq!(e.tile, TileDim::new(32, 4));
        assert_eq!(e.dst(), (128, 128));
        assert_eq!(
            m.hlo_path(e),
            PathBuf::from("/tmp/bilinear_s2_b1_t32x4_64x64.hlo.txt")
        );
    }

    #[test]
    fn select_prefers_sufficient_batch() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        let e = m
            .select(Interpolator::Bilinear, (64, 64), 2, 3, None)
            .unwrap();
        assert_eq!(e.batch, 4);
        let e1 = m
            .select(Interpolator::Bilinear, (64, 64), 2, 1, None)
            .unwrap();
        assert_eq!(e1.batch, 1, "smallest sufficient batch preferred");
        assert!(m.select(Interpolator::Bicubic, (64, 64), 2, 1, None).is_none());
    }

    #[test]
    fn shapes_deduped() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert_eq!(m.shapes().len(), 2);
    }

    #[test]
    fn fleet_demo_with_tiles_generates_and_validates() {
        // The default demo is the two-tile instance of the generator.
        let demo = Manifest::fleet_demo();
        assert_eq!(demo.entries.len(), 4);
        assert!(demo.entries.iter().any(|e| e.name == "bl_s2_b4_t16x8"));
        assert!(demo.entries.iter().any(|e| e.name == "bl_s2_b1_t32x16"));
        // Custom tile sets generate batch-1 and batch-4 variants each.
        let custom =
            Manifest::fleet_demo_with_tiles(&[TileDim::new(32, 4), TileDim::new(8, 8)]).unwrap();
        assert_eq!(custom.entries.len(), 4);
        assert!(custom.entries.iter().all(|e| e.scale == 2 && e.src == (64, 64)));
        // Empty and duplicated tile lists fail loudly.
        assert!(Manifest::fleet_demo_with_tiles(&[]).is_err());
        assert!(
            Manifest::fleet_demo_with_tiles(&[TileDim::new(8, 8), TileDim::new(8, 8)]).is_err()
        );
    }

    #[test]
    fn retain_tiles_filters_entries() {
        let mut m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        m.retain_tiles(&[TileDim::new(32, 4)]);
        assert_eq!(m.entries.len(), 2);
        assert!(m.entries.iter().all(|e| e.tile == TileDim::new(32, 4)));
        m.retain_tiles(&[TileDim::new(2, 2)]);
        assert!(m.entries.is_empty());
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse("{}", PathBuf::from(".")).is_err());
        assert!(Manifest::parse(r#"{"version": 2, "artifacts": []}"#, ".".into()).is_err());
        let dup = SAMPLE.replace("nearest_s4_b1_t8x8_64x64", "bilinear_s2_b1_t32x4_64x64");
        assert!(Manifest::parse(&dup, ".".into()).is_err());
    }
}
