//! PJRT execution engine: compile-once / execute-many over the AOT HLO
//! artifacts, with `Image<f32>` ⇄ `Literal` marshaling.
//!
//! Thread-model note: the `xla` crate's `PjRtClient` is `Rc`-based (not
//! `Send`), so an [`Engine`] is thread-local by construction. The
//! coordinator gives each worker thread its own `Engine` (compilation of
//! these small modules is cheap and happens once per worker at startup);
//! see `coordinator::worker`.

use super::artifact::{ArtifactEntry, Manifest};
use super::ResizeBackend;
use crate::image::Image;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;

/// A compiled artifact ready to execute.
pub struct Executable {
    entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// The manifest entry this executable implements.
    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Execute on a batch of images. The batch is zero-padded up to the
    /// artifact's static batch size; `batch.len()` outputs are returned.
    pub fn run(&self, batch: &[Image<f32>]) -> Result<Vec<Image<f32>>> {
        let e = &self.entry;
        let (sh, sw) = (e.src.0 as usize, e.src.1 as usize);
        let b = e.batch as usize;
        if batch.is_empty() || batch.len() > b {
            bail!(
                "batch size {} out of range for artifact '{}' (max {b})",
                batch.len(),
                e.name
            );
        }
        for (i, img) in batch.iter().enumerate() {
            if img.width() != sw || img.height() != sh {
                bail!(
                    "request {i} is {}x{} but artifact '{}' expects {sw}x{sh}",
                    img.width(),
                    img.height(),
                    e.name
                );
            }
        }
        // Stack into [B, H, W], zero-padding the tail.
        let mut data = vec![0f32; b * sh * sw];
        for (i, img) in batch.iter().enumerate() {
            let dense = img.to_dense();
            data[i * sh * sw..(i + 1) * sh * sw].copy_from_slice(&dense);
        }
        let lit = xla::Literal::vec1(&data)
            .reshape(&[b as i64, sh as i64, sw as i64])
            .context("reshape input literal")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .with_context(|| format!("executing '{}'", e.name))?[0][0]
            .to_literal_sync()
            .context("fetching output literal")?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping output tuple")?;
        let vals: Vec<f32> = out.to_vec().context("reading output values")?;
        let (dh, dw) = (e.dst().0 as usize, e.dst().1 as usize);
        if vals.len() != b * dh * dw {
            bail!(
                "artifact '{}' returned {} values, expected {}",
                e.name,
                vals.len(),
                b * dh * dw
            );
        }
        Ok((0..batch.len())
            .map(|i| {
                Image::from_vec(dw, dh, vals[i * dh * dw..(i + 1) * dh * dw].to_vec())
            })
            .collect())
    }
}

/// A thread-local PJRT engine: one CPU client plus a cache of compiled
/// executables keyed by artifact name.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<Executable>>>,
}

impl Engine {
    /// Create a CPU engine over a loaded manifest.
    pub fn cpu(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) the executable for an entry.
    pub fn load(&self, entry: &ArtifactEntry) -> Result<std::rc::Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(&entry.name) {
            return Ok(std::rc::Rc::clone(exe));
        }
        let path = self.manifest.hlo_path(entry);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling '{}'", entry.name))?;
        let executable = std::rc::Rc::new(Executable {
            entry: entry.clone(),
            exe,
        });
        self.cache
            .borrow_mut()
            .insert(entry.name.clone(), std::rc::Rc::clone(&executable));
        Ok(executable)
    }

    /// Compile every artifact up front (worker startup).
    pub fn warm_all(&self) -> Result<usize> {
        let entries = self.manifest.entries.clone();
        for e in &entries {
            self.load(e)?;
        }
        Ok(entries.len())
    }
}

impl ResizeBackend for EngineHandle {
    fn run_batch(&self, entry: &ArtifactEntry, batch: &[Image<f32>]) -> Result<Vec<Image<f32>>> {
        ENGINE.with(|slot| {
            let mut slot = slot.borrow_mut();
            if slot.is_none() {
                *slot = Some(Engine::cpu(self.manifest.clone())?);
            }
            let engine = slot.as_ref().unwrap();
            let exe = engine.load(entry)?;
            exe.run(batch)
        })
    }

    /// Compile every artifact on this thread's engine — called by each
    /// worker at spawn so the request path never compiles.
    fn warm(&self) -> Result<usize> {
        ENGINE.with(|slot| {
            let mut slot = slot.borrow_mut();
            if slot.is_none() {
                *slot = Some(Engine::cpu(self.manifest.clone())?);
            }
            slot.as_ref().unwrap().warm_all()
        })
    }
}

thread_local! {
    static ENGINE: RefCell<Option<Engine>> = const { RefCell::new(None) };
}

/// A `Send + Sync` handle that materializes a thread-local [`Engine`] on
/// every thread that executes through it — the bridge between the
/// non-`Send` PJRT client and the threaded coordinator.
#[derive(Clone)]
pub struct EngineHandle {
    manifest: Manifest,
}

impl EngineHandle {
    pub fn new(manifest: Manifest) -> EngineHandle {
        EngineHandle { manifest }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}
