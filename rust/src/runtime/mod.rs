//! The AOT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client via
//! the `xla` crate. Python never runs on this path.
//!
//! * [`artifact`] — the `manifest.json` schema: which (kernel, source
//!   size, scale, batch, tile) each `.hlo.txt` implements.
//! * [`executor`] — compile-once/execute-many wrapper around
//!   `PjRtClient`, with image ⇄ literal marshaling.
//! * [`mock`] — a CPU-reference executor with the same interface, used by
//!   coordinator tests and as a fallback when artifacts are absent.

pub mod artifact;
pub mod executor;
pub mod hlostats;
pub mod mock;

pub use artifact::{ArtifactEntry, Manifest};
pub use executor::{Engine, Executable};
pub use hlostats::{stats_of_file, HloStats};
pub use mock::MockEngine;

use crate::image::Image;
use anyhow::Result;

/// Anything that can run a resize batch: the PJRT engine or the mock.
/// Batches are `[B, H, W]` stacked images; the executor returns `B`
/// output images of `[H*scale, W*scale]`.
pub trait ResizeBackend: Send + Sync {
    /// Execute one batch through the artifact keyed by `entry`.
    fn run_batch(&self, entry: &ArtifactEntry, batch: &[Image<f32>]) -> Result<Vec<Image<f32>>>;

    /// Prepare this backend on the CALLING thread (compile artifacts,
    /// allocate clients). Workers invoke it once at spawn so nothing
    /// compiles on the request path. Returns the number of artifacts
    /// prepared; the default no-op suits stateless backends.
    fn warm(&self) -> Result<usize> {
        Ok(0)
    }
}
