//! `tilekit` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   devices      print the device registry (incl. the paper's Table I)
//!   occupancy    occupancy calculator for a tile on one/all devices
//!   sweep        Fig. 3 tile sweeps (TuningSession, per-device tables)
//!   simulate     single-launch simulation / Fig. 4 / §IV.C experiments
//!   tune         strategy-driven tuning session (exhaustive / descent /
//!                cached) with a persistent tuning cache
//!   autotune     best-tile + portable (min-max regret) selection
//!   resize       resize a PGM/PPM file through an AOT artifact
//!   serve        run the serving demo workload and print stats
//!                (--watch-db adds the background retune daemon)
//!   fleet        drive the typed control plane (topology/drain/retune)
//!                against a live demo fleet
//!   init-config  write an example tilekit.toml
//!   analyze      self-hosted invariant analyzer (wire-safety, lock
//!                order, atomics pairing); nonzero exit on findings
//!
//! Run `tilekit help` for the full flag list, or `tilekit tune --help` /
//! `tilekit sweep --help` for the tuning flags.

use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;
use std::sync::Arc;
use tilekit::autotuner::{strategy_by_name, SearchStrategy, SimCostModel, TuningSession};
use tilekit::bench::figures;
use tilekit::cli::Args;
use tilekit::config::Config;
use tilekit::coordinator::{
    Autoscaler, AutoscalerUpdate, FleetBuilder, FleetController, Priority, Request, RetuneDaemon,
    RetuneSpec, StandbyMember, SubmitError, TilePolicy,
};
use tilekit::ops::{ControlOps, FleetOps, LocalFleet, TicketOps};
use tilekit::device::DeviceDescriptor;
use tilekit::image::{generate, pnm, Interpolator};
use tilekit::net::protocol::saturating_duration_from_ms;
use tilekit::runtime::executor::EngineHandle;
use tilekit::runtime::{Manifest, MockEngine, ResizeBackend};
use tilekit::sim::{simulate, KernelCost, Launch, Straggler};
use tilekit::tiling::occupancy::occupancy;
use tilekit::tiling::{paper_sweep_tiles, TileDim};
use tilekit::util::text::fmt_ms;

const VALUE_FLAGS: &[&str] = &[
    "config", "device", "devices", "tile", "tiles", "scale", "scales", "kernel", "src",
    "artifacts", "out", "requests", "workers", "batch-max", "straggler-speed", "input",
    "output", "seed", "strategy", "cache", "scheduler", "policy", "baseline", "max-regress",
    "watch-db", "watch-poll-ms", "watch-strategy", "listen", "listen-for-ms", "connect",
    "shards", "outcome", "deadline-ms", "priority", "mode", "steal", "steal-threshold",
    "timeout-ms", "standby-devices", "low", "high", "cooldown-ms",
];

fn main() {
    let args = match Args::from_env(VALUE_FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(path) => Config::load(Path::new(path))?,
        None => Config::builtin(),
    };
    match args.command.as_deref() {
        Some("devices") => cmd_devices(args, &cfg),
        Some("occupancy") => cmd_occupancy(args, &cfg),
        Some("sweep") => cmd_sweep(args, &cfg),
        Some("simulate") => cmd_simulate(args, &cfg),
        Some("tune") => cmd_tune(args, &cfg),
        Some("autotune") => cmd_autotune(args, &cfg),
        Some("resize") => cmd_resize(args, &cfg),
        Some("serve") => cmd_serve(args, &cfg),
        Some("fleet") => cmd_fleet(args, &cfg),
        Some("submit") => cmd_submit(args, &cfg),
        Some("front") => cmd_front(args, &cfg),
        Some("bench") => cmd_bench(args),
        Some("artifacts") => cmd_artifacts(args, &cfg),
        Some("analyze") => cmd_analyze(args),
        Some("init-config") => {
            let path = args.get_or("out", "tilekit.toml");
            std::fs::write(path, tilekit::config::EXAMPLE_CONFIG)?;
            println!("wrote {path}");
            Ok(())
        }
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (try 'tilekit help')"),
    }
}

const HELP: &str = r#"tilekit — tiling-for-performance-tuning reproduction (Xu/Kirk/Jenkins 2010)

USAGE: tilekit <command> [flags]

COMMANDS
  devices [--table1]                    device registry / the paper's Table I
  occupancy --tile 32x16 [--device id]  occupancy calculator (all devices default)
  sweep [--fig3] [--device id] [--scale N] [--kernel k] [--csv]
        [--strategy s] [--cache f]      tile sweep; --fig3 = all five insets
                                        (see 'tilekit sweep --help')
  simulate [--fig4|--extreme] [--device id --tile WxH --scale N]
                                        memory-model / straggler experiments
  tune [--strategy s] [--cache f] [--scale N] [--src WxH]
       [--devices a,b,c|all] [--tiles t1,t2] [--out f.json]
                                        tuning session: per-device best +
                                        portable pick (see 'tilekit tune --help')
  autotune [--scale N] [--devices a,b,c]
                                        best & portable tile selection
  resize <in.pgm> <out.pgm> --scale N [--kernel bilinear] [--artifacts dir] [--mock]
                                        run a real resize through an AOT artifact
  serve [--requests N] [--workers N] [--artifacts dir] [--mock] [--tile WxH]
        [--tiles t1,t2] [--batch-max N] [--no-steal]
        [--devices a,b] [--scheduler s] [--policy p]
        [--autoscale] [--standby-devices c,d]
        [--watch-db f.json] [--watch-poll-ms N] [--watch-strategy s]
        [--listen host:port|unix:/p.sock] [--listen-for-ms N]
                                        serving demo: batched requests + stats.
                                        --listen serves the fleet over the wire
                                        protocol instead of running the demo
                                        workload (port 0 = ephemeral; prints
                                        the bound address; --listen-for-ms
                                        bounds the lifetime, default forever);
                                        --devices starts a simulated fleet with
                                        per-device tuned tiles; --scheduler is
                                        round-robin|least-loaded|cost-eta
                                        (cost-eta declines infeasible deadlines);
                                        --policy is reject|block|shed-batch;
                                        --tiles restricts the tile set (and the
                                        --mock demo manifest) to these variants;
                                        --batch-max overrides the per-member
                                        capability-derived batch cap; --no-steal
                                        disables work-stealing between members;
                                        --watch-db runs a RetuneDaemon that
                                        hot-swaps tuned tiles when the tuning
                                        database file changes (fleet only;
                                        --watch-strategy names the strategy
                                        key the refresh runs write, default
                                        exhaustive);
                                        --autoscale closes the capacity loop:
                                        a watermark policy over live stats
                                        engages/parks --standby-devices (or
                                        the [autoscaler] pool) through the
                                        control plane (fleet only; knobs come
                                        from the [autoscaler] config table)
  fleet <topology|drain|retune|autoscaler> [--devices a,b] [--device id]
        [--requests N] [--connect addr ...]
                                        drive the typed control plane against a
                                        live demo fleet — or, with --connect,
                                        against a remote `serve --listen` fleet
                                        (more actions: stats, add-member,
                                        remove-member, set-scheduler,
                                        set-admission, set-steal,
                                        autoscaler <status|enable|disable|set>)
                                        (see 'tilekit fleet --help')
  submit --connect addr [--kernel k] [--src WxH] [--scale N] [--requests N]
         [--priority interactive|batch] [--deadline-ms N] [--seed N]
                                        submit requests to a remote fleet over
                                        the wire and wait for the results
  front --shards a:p1,b:p2 [--requests N] [--drain-owner] [--seed N]
                                        consistent-hash front tier over N fleet
                                        servers: shape-stable routing, health
                                        polling, merged fleet-of-fleets stats;
                                        --drain-owner drains+removes the shard
                                        owning the demo shape mid-run to prove
                                        zero-loss failover
  bench [--out f.json] [--baseline f.json] [--max-regress PCT]
        [--update-baseline] [--full]    hot-path smoke benchmarks; with
                                        --baseline, fails on >PCT% regression
                                        of calibration-normalized scores
                                        (see 'tilekit bench --help')
  artifacts [--artifacts dir] [--verify]
                                        list AOT artifacts with HLO stats;
                                        --verify compiles + checks numerics
  init-config [--out tilekit.toml]      write an example config
  analyze [--strict] [paths…]           run the invariant analyzer over
                                        rust/src + rust/tests (or the given
                                        files/dirs); exits nonzero on any
                                        unsuppressed finding; --strict also
                                        reports unused analyze::allow
                                        annotations

GLOBAL FLAGS
  --config path.toml                    load configuration
"#;

fn cmd_analyze(args: &Args) -> Result<()> {
    let strict = args.has("strict");
    let mut paths: Vec<std::path::PathBuf> =
        args.positional.iter().map(std::path::PathBuf::from).collect();
    if paths.is_empty() {
        let defaults: &[&str] = if Path::new("rust/src").is_dir() {
            &["rust/src", "rust/tests"]
        } else {
            &["src", "tests"]
        };
        paths = defaults
            .iter()
            .map(std::path::PathBuf::from)
            .filter(|p| p.is_dir())
            .collect();
        if paths.is_empty() {
            bail!("analyze: no rust/src (or src) directory here; pass paths explicitly");
        }
    }
    let report = tilekit::analysis::analyze_paths(&paths, strict)?;
    for f in &report.findings {
        println!("{f}");
    }
    println!(
        "analyze: {} file(s), {} finding(s), {} suppressed{}",
        report.files,
        report.findings.len(),
        report.suppressed,
        if strict { " (strict)" } else { "" },
    );
    if !report.clean() {
        bail!("analyze found {} issue(s)", report.findings.len());
    }
    Ok(())
}

fn cmd_devices(args: &Args, cfg: &Config) -> Result<()> {
    if args.has("table1") {
        println!("TABLE I. COMPUTE CAPABILITY OF GTX260 AND GEFORCE 8800\n");
        print!("{}", figures::table1_figure().render());
        return Ok(());
    }
    let mut t = tilekit::util::text::Table::new(vec![
        "id", "name", "cc", "SMs", "SPs", "clk MHz", "mem MiB", "coalescing",
    ]);
    for d in &cfg.devices {
        t.row(vec![
            d.id.clone(),
            d.name.clone(),
            d.cc.version(),
            d.sm_count.to_string(),
            d.total_sps().to_string(),
            format!("{:.0}", d.sp_clock_mhz),
            d.global_mem_mib.to_string(),
            d.cc.coalescing.label().to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn parse_kernel(args: &Args) -> Result<Interpolator> {
    let k = args.get_or("kernel", "bilinear");
    Interpolator::parse(k).ok_or_else(|| anyhow!("unknown kernel '{k}'"))
}

fn cmd_occupancy(args: &Args, cfg: &Config) -> Result<()> {
    let tile: TileDim = args
        .get_or("tile", "32x16")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let kernel = parse_kernel(args)?;
    let res = KernelCost::of(kernel).resources;
    let devices: Vec<_> = match args.get("device") {
        Some(id) => vec![cfg.device(id)?.clone()],
        None => cfg.devices.clone(),
    };
    let mut t = tilekit::util::text::Table::new(vec![
        "device", "tile", "blocks/SM", "warps/SM", "threads/SM", "occupancy", "limiter",
    ]);
    for d in devices {
        let o = occupancy(tile, &res, &d.cc);
        t.row(vec![
            d.id.clone(),
            tile.label(),
            o.blocks_per_sm.to_string(),
            o.warps_per_sm.to_string(),
            o.threads_per_sm.to_string(),
            format!("{:.0}%", o.ratio * 100.0),
            o.limiter.label().to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

const SWEEP_HELP: &str = r#"tilekit sweep — tile sweep over one or more devices (Fig. 3)

USAGE: tilekit sweep [flags]

FLAGS
  --fig3               print all five paper insets (scales 2/4/6/8/10)
  --device id          sweep one device (default: config sweep.devices)
  --scale N            upscaling factor (default 4)
  --kernel k           nearest | bilinear | bicubic (default bilinear)
  --csv                CSV instead of aligned tables
  --strategy NAME      search strategy: exhaustive (default) | descent | cached
                         exhaustive  evaluate every candidate tile
                         descent     coordinate descent over the w x h lattice
                                     (fewer evaluations, near-optimal best)
                         cached      exhaustive behind the persistent cache
  --cache FILE         persistent tuning database (JSON); any strategy wrapped
                       in the cache decorator: hits cost zero evaluations

Sweeps run through the TuningSession API; 'tilekit tune' additionally
prints the portable (min-max regret) pick and can save the outcome.
"#;

fn cmd_sweep(args: &Args, cfg: &Config) -> Result<()> {
    if args.has("help") {
        print!("{SWEEP_HELP}");
        return Ok(());
    }
    let kernel = parse_kernel(args)?;
    let src = cfg.sweep.src;
    if args.has("fig3") {
        // Validate the flags even though the figure is always exhaustive:
        // a typo'd --strategy must still fail loudly, and ignored flags
        // must say so rather than pretend they took effect.
        strategy_from_args(args)?;
        if args.get("strategy").is_some() || args.get("cache").is_some() {
            eprintln!(
                "note: --fig3 regenerates the full figure exhaustively; \
                 --strategy/--cache are ignored here"
            );
        }
        let (insets, summary) = figures::fig3_summary(kernel, src);
        for (scale, table) in &insets {
            println!(
                "\nFig. 3 inset (scale {scale}): {} {}x{} -> {}x{}",
                kernel.label(),
                src.0,
                src.1,
                src.0 * scale,
                src.1 * scale,
            );
            if args.has("csv") {
                print!("{}", table.to_csv());
            } else {
                print!("{}", table.render());
            }
        }
        println!("\nSummary (best tile + curve spread per device):");
        print!("{}", summary.render());
        return Ok(());
    }
    let scale: u32 = args.get_parsed_or("scale", 4)?;
    let tiles = if cfg.sweep.tiles.is_empty() {
        paper_sweep_tiles()
    } else {
        cfg.sweep.tiles.clone()
    };
    let device_ids: Vec<String> = match args.get("device") {
        Some(id) => vec![id.to_string()],
        None => cfg.sweep.devices.clone(),
    };
    let devices: Vec<DeviceDescriptor> = device_ids
        .iter()
        .map(|id| cfg.device(id).cloned())
        .collect::<Result<_>>()?;
    let outcome = TuningSession::new(SimCostModel)
        .devices(devices.clone())
        .kernel(kernel)
        .scale(scale)
        .src(src)
        .tiles(tiles)
        .strategy(strategy_from_args(args)?)
        .run()?;
    for dt in &outcome.per_device {
        let d = devices
            .iter()
            .find(|d| d.id == dt.device_id)
            .expect("outcome device came from the session's device set");
        println!(
            "\n{} — {} scale {scale} ('{}' strategy, {} evaluations):",
            d.name,
            kernel.label(),
            outcome.strategy,
            dt.evaluations
        );
        let res = KernelCost::of(kernel).resources;
        let mut t = tilekit::util::text::Table::new(vec!["tile", "ms", "occupancy"]);
        for p in &dt.points {
            let o = occupancy(p.tile, &res, &d.cc);
            t.row(vec![
                p.tile.label(),
                fmt_ms(p.ms),
                format!("{:.0}%", o.ratio * 100.0),
            ]);
        }
        if args.has("csv") {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.render());
        }
        println!("best: {} at {} ms", dt.best, fmt_ms(dt.best_ms));
    }
    Ok(())
}

const TUNE_HELP: &str = r#"tilekit tune — strategy-driven tuning session with a persistent cache

USAGE: tilekit tune [flags]

FLAGS
  --strategy NAME      search strategy: exhaustive (default) | descent | cached
                         exhaustive  evaluate every candidate tile (ground truth)
                         descent     coordinate descent over the w x h tile
                                     lattice — near-optimal with far fewer
                                     CostModel evaluations
                         cached      exhaustive behind the persistent cache
                                     (default file tuning_cache.json)
  --cache FILE         persistent tuning database (JSON). Combines with any
                       strategy: results are written through, and later runs
                       with the same (device, kernel, scale, size) key cost
                       zero evaluations.
  --devices a,b,c|all  device ids to tune (default: config sweep.devices;
                       'all' = every configured device)
  --scale N            upscaling factor (default 8)
  --src WxH            source image size (default: config sweep.src,
                       800x800). Cache entries are keyed by it — when
                       refreshing a cache a `serve --watch-db` fleet
                       watches, tune at the SERVED shape (the mock fleet
                       demo serves 64x64 at scale 2)
  --kernel k           nearest | bilinear | bicubic (default bilinear)
  --tiles t1,t2,...    explicit candidate tiles (default: the paper sweep
                       set; the cache key fingerprints the SET, order
                       does not matter)
  --out FILE           save the full TuningOutcome as JSON

Prints each device's tuned best tile and the portable (min-max regret)
pick across the device set — the paper's worst-case-GPU rule.
"#;

fn strategy_from_args(args: &Args) -> Result<Box<dyn SearchStrategy>> {
    let name = args.get_or("strategy", "exhaustive");
    let cache = args.get("cache").map(Path::new);
    strategy_by_name(name, cache)
}

/// Parse a `--src WxH` source-size flag.
fn parse_src(s: &str) -> Result<(u32, u32)> {
    let (w, h) = s
        .split_once('x')
        .ok_or_else(|| anyhow!("--src must be WxH (e.g. 64x64), got '{s}'"))?;
    let w: u32 = w
        .trim()
        .parse()
        .map_err(|_| anyhow!("--src width '{w}' is not a number"))?;
    let h: u32 = h
        .trim()
        .parse()
        .map_err(|_| anyhow!("--src height '{h}' is not a number"))?;
    if w == 0 || h == 0 {
        bail!("--src must be positive, got {w}x{h}");
    }
    Ok((w, h))
}

fn cmd_tune(args: &Args, cfg: &Config) -> Result<()> {
    if args.has("help") {
        print!("{TUNE_HELP}");
        return Ok(());
    }
    let kernel = parse_kernel(args)?;
    let scale: u32 = args.get_parsed_or("scale", 8)?;
    let ids: Vec<String> = {
        let list = args.get_list("devices");
        if list.is_empty() {
            cfg.sweep.devices.clone()
        } else if list.len() == 1 && list[0] == "all" {
            cfg.devices.iter().map(|d| d.id.clone()).collect()
        } else {
            list
        }
    };
    let devices: Vec<DeviceDescriptor> = ids
        .iter()
        .map(|id| cfg.device(id).cloned())
        .collect::<Result<_>>()?;
    let tiles: Vec<TileDim> = match args.get("tiles") {
        Some(_) => args
            .get_list("tiles")
            .iter()
            .map(|s| s.parse::<TileDim>().map_err(|e| anyhow!(e)))
            .collect::<Result<_>>()?,
        None if cfg.sweep.tiles.is_empty() => paper_sweep_tiles(),
        None => cfg.sweep.tiles.clone(),
    };
    // --src retargets the tuned shape (default: the config's sweep
    // source). Cache entries are keyed by it, so a refresh meant for a
    // serving fleet must tune at the SERVED shape (e.g. --src 64x64 for
    // the mock fleet demo behind `serve --watch-db`).
    let src: (u32, u32) = match args.get("src") {
        Some(s) => parse_src(s)?,
        None => cfg.sweep.src,
    };
    let outcome = TuningSession::new(SimCostModel)
        .devices(devices)
        .kernel(kernel)
        .scale(scale)
        .src(src)
        .tiles(tiles)
        .strategy(strategy_from_args(args)?)
        .run()?;
    println!(
        "Tuning — {} {}x{} at scale {scale} over {:?} via '{}' ({} evaluations):\n",
        kernel.label(),
        src.0,
        src.1,
        ids,
        outcome.strategy,
        outcome.evaluations
    );
    let mut t = tilekit::util::text::Table::new(vec![
        "device",
        "best tile",
        "best ms",
        "evaluations",
        "portable regret",
    ]);
    for dt in &outcome.per_device {
        let regret = outcome
            .portable
            .as_ref()
            .and_then(|c| c.per_device.iter().find(|(d, _, _)| d == &dt.device_id))
            .map(|(_, _, r)| format!("{r:.3}x"))
            .unwrap_or_else(|| "-".to_string());
        t.row(vec![
            dt.device_id.clone(),
            dt.best.label(),
            fmt_ms(dt.best_ms),
            dt.evaluations.to_string(),
            regret,
        ]);
    }
    print!("{}", t.render());
    match &outcome.portable {
        Some(c) => println!(
            "\nportable tile (min-max regret): {} (worst-case {:.3}x)",
            c.tile, c.worst_regret
        ),
        None => println!("\nno evaluated tile is launchable on every device"),
    }
    if let Some(path) = args.get("out") {
        outcome.save(Path::new(path))?;
        println!("wrote tuning outcome to {path}");
    }
    if let Some(cache) = args.get("cache") {
        println!("tuning cache: {cache}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args, cfg: &Config) -> Result<()> {
    if args.has("fig4") {
        let scale: u32 = args.get_parsed_or("scale", 6)?;
        println!("Fig. 4 — 4x8 vs 8x4 access patterns (scale {scale}):\n");
        print!("{}", figures::fig4_access(scale).render());
        return Ok(());
    }
    if args.has("extreme") {
        println!("§IV.C extreme example — straggler dilution G1 (2 SM) vs G2 (20 SM):\n");
        print!("{}", figures::extreme_example().render());
        return Ok(());
    }
    let d = cfg.device(args.get_or("device", "gtx260"))?;
    let tile: TileDim = args
        .get_or("tile", "32x4")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let scale: u32 = args.get_parsed_or("scale", 4)?;
    let kernel = parse_kernel(args)?;
    let l = Launch {
        kernel,
        tile,
        src_w: cfg.sweep.src.0,
        src_h: cfg.sweep.src.1,
        scale,
    };
    let straggler = args
        .get_parsed::<f64>("straggler-speed")?
        .map(|speed| Straggler { sm: 0, speed });
    let r = simulate(&l, d, straggler);
    println!("{} | {} tile {} scale {}", d.name, kernel.label(), tile, scale);
    println!(
        "  blocks={} rounds={} occupancy={:.0}% ({})",
        r.total_blocks,
        r.rounds,
        r.occupancy.ratio * 100.0,
        r.occupancy.limiter.label()
    );
    println!(
        "  traffic/block: {} load tx, {} store tx, {} row crossings, {:.0} penalty cyc",
        r.traffic.load_transactions,
        r.traffic.store_transactions,
        r.traffic.row_crossings,
        r.traffic.row_penalty_cycles
    );
    println!("  time: {} ms  ({:.1} Mpix/s)", fmt_ms(r.ms), r.mpix_per_s(&l));
    Ok(())
}

fn cmd_autotune(args: &Args, cfg: &Config) -> Result<()> {
    let kernel = parse_kernel(args)?;
    let scale: u32 = args.get_parsed_or("scale", 8)?;
    let ids: Vec<String> = {
        let list = args.get_list("devices");
        if list.is_empty() {
            cfg.sweep.devices.clone()
        } else {
            list
        }
    };
    let devices: Vec<_> = ids
        .iter()
        .map(|id| cfg.device(id).cloned())
        .collect::<Result<_>>()?;
    let (table, choice) = figures::portable_selection(&devices, kernel, scale, cfg.sweep.src);
    println!(
        "Autotune — {} at scale {scale} over {:?}:\n",
        kernel.label(),
        ids
    );
    print!("{}", table.render());
    match choice {
        Some(tile) => println!("\nportable tile (min-max regret): {tile}"),
        None => println!("\nno tile is launchable on every device"),
    }
    Ok(())
}

fn backend_from_args(args: &Args, cfg: &Config) -> Result<(Arc<dyn ResizeBackend>, Manifest)> {
    let dir = args.get_or("artifacts", &cfg.serving.artifacts_dir);
    let manifest = Manifest::load(Path::new(dir))
        .with_context(|| format!("loading artifacts from '{dir}' (run `make artifacts`?)"))?;
    let backend: Arc<dyn ResizeBackend> = if args.has("mock") {
        Arc::new(MockEngine::new())
    } else {
        Arc::new(EngineHandle::new(manifest.clone()))
    };
    Ok((backend, manifest))
}

fn cmd_resize(args: &Args, cfg: &Config) -> Result<()> {
    let input = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: tilekit resize <in.pgm> <out.pgm> --scale N"))?;
    let output = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: tilekit resize <in.pgm> <out.pgm> --scale N"))?;
    let scale: u32 = args.get_parsed_or("scale", 2)?;
    let kernel = parse_kernel(args)?;
    let img = pnm::read_pnm(Path::new(input))?;
    let (backend, manifest) = backend_from_args(args, cfg)?;
    let entry = manifest
        .select(
            kernel,
            (img.height() as u32, img.width() as u32),
            scale,
            1,
            None,
        )
        .ok_or_else(|| {
            anyhow!(
                "no artifact for {} {}x{} scale {} — available: {:?}",
                kernel.label(),
                img.width(),
                img.height(),
                scale,
                manifest.shapes()
            )
        })?;
    let t0 = std::time::Instant::now();
    let out = backend.run_batch(entry, &[img])?.remove(0);
    let dt = t0.elapsed();
    pnm::write_pgm(Path::new(output), &out)?;
    println!(
        "{} -> {} ({}x{}, {} via '{}', {:.2} ms)",
        input,
        output,
        out.width(),
        out.height(),
        kernel.label(),
        entry.name,
        dt.as_secs_f64() * 1e3
    );
    Ok(())
}

const BENCH_HELP: &str = r#"tilekit bench — hot-path smoke benchmarks + the CI regression gate

USAGE: tilekit bench [flags]

FLAGS
  --json               also print the report JSON to stdout
  --out FILE           write the report JSON (CI uploads BENCH_PR.json)
  --baseline FILE      compare against a baseline report; exits non-zero
                       when any bench's normalized score regressed more
                       than the threshold. A baseline marked
                       "provisional": true reports but never fails.
  --max-regress PCT    regression threshold in percent (default 15)
  --update-baseline    measure and overwrite the --baseline file
                       (default BENCH_BASELINE.json) with a fresh,
                       non-provisional baseline
  --full               slower full measurement profile (more samples)
  --serving            also run the open-loop serving benchmark: a mock
                       fleet driven closed-loop (submit latency
                       mean/p50/p99) and open-loop via a phased Poisson
                       trace (e2e p99, us/req), appended to the report
                       behind the same gate
  --wire               also run the loopback wire benchmark: one mock
                       fleet behind a NetServer, driven through a v1
                       (JSON pixels) and a v2 (binary pixels, pipelined)
                       FleetClient; records us/req and bytes/req for
                       both protocol versions behind the same gate
  --quick              with --serving/--wire: the small CI profile
                       instead of the full default

Scores are normalized by an in-run integer-spin calibration workload,
so they transfer across machines far better than raw wall-clock us.
"#;

fn cmd_bench(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{BENCH_HELP}");
        return Ok(());
    }
    if args.has("quick") && !args.has("serving") && !args.has("wire") {
        bail!("--quick only applies to the serving/wire benchmarks; add --serving or --wire");
    }
    let full = args.has("full");
    let profile = if full {
        tilekit::bench::Bench::default()
    } else {
        tilekit::bench::gate::gate_profile()
    };
    println!(
        "tilekit bench — smoke suite ({} profile):\n",
        if full { "full" } else { "gate" }
    );
    let mut report = tilekit::bench::smoke_suite(&profile);
    if args.has("serving") {
        let quick = args.has("quick");
        println!(
            "\nserving benchmark ({} profile):\n",
            if quick { "quick" } else { "full" }
        );
        let calib_us = report
            .record(tilekit::bench::gate::CALIBRATION)
            .map(|r| r.mean_us)
            .unwrap_or(1.0);
        let records = tilekit::bench::serving_suite(calib_us, quick)?;
        report.records.extend(records);
    }
    if args.has("wire") {
        let quick = args.has("quick");
        println!(
            "\nwire loopback benchmark ({} profile):\n",
            if quick { "quick" } else { "full" }
        );
        let calib_us = report
            .record(tilekit::bench::gate::CALIBRATION)
            .map(|r| r.mean_us)
            .unwrap_or(1.0);
        let records = tilekit::bench::wire_suite(calib_us, quick)?;
        report.records.extend(records);
    }
    if args.has("json") {
        println!("\n{}", report.to_json().pretty());
    }
    if let Some(out) = args.get("out") {
        report.save(Path::new(out))?;
        println!("\nwrote report {out}");
    }
    if args.has("update-baseline") {
        // Refresh the baseline from this run; comparing it against
        // itself would be meaningless, so the gate is skipped.
        let path = args.get_or("baseline", "BENCH_BASELINE.json");
        report.save(Path::new(path))?;
        println!("\nwrote baseline {path}");
        return Ok(());
    }
    if let Some(basepath) = args.get("baseline") {
        let baseline = tilekit::bench::BenchReport::load(Path::new(basepath))?;
        let max: f64 = args.get_parsed_or("max-regress", 15.0)?;
        let gate = tilekit::bench::compare(&baseline, &report, max);
        println!("\nregression gate vs {basepath} (limit {max:.0}%):");
        for line in &gate.lines {
            println!("  {line}");
        }
        if gate.provisional_baseline {
            println!(
                "note: baseline is PROVISIONAL — reporting only; refresh it with \
                 `tilekit bench --update-baseline` on a measuring machine"
            );
            if !gate.failures.is_empty() {
                println!("would have failed: {}", gate.failures.join("; "));
            }
        } else if !gate.failures.is_empty() {
            bail!(
                "bench regression gate failed:\n  {}",
                gate.failures.join("\n  ")
            );
        } else {
            println!("gate passed: no bench regressed more than {max:.0}%");
        }
    }
    Ok(())
}

fn cmd_artifacts(args: &Args, cfg: &Config) -> Result<()> {
    let dir = args.get_or("artifacts", &cfg.serving.artifacts_dir);
    let manifest = Manifest::load(Path::new(dir))
        .with_context(|| format!("loading artifacts from '{dir}' (run `make artifacts`?)"))?;
    let verify = args.has("verify");
    let engine = if verify {
        Some(tilekit::runtime::Engine::cpu(manifest.clone())?)
    } else {
        None
    };
    let mut t = tilekit::util::text::Table::new(vec![
        "artifact", "kernel", "src", "scale", "batch", "tile", "KiB", "instrs", "whiles",
        "gathers", "fusions", if verify { "verified" } else { "" },
    ]);
    for e in &manifest.entries {
        let s = tilekit::runtime::stats_of_file(&manifest.hlo_path(e))?;
        let verdict = match &engine {
            None => String::new(),
            Some(eng) => {
                let exe = eng.load(e)?;
                let imgs: Vec<_> = (0..e.batch as usize)
                    .map(|i| {
                        generate::test_scene(e.src.1 as usize, e.src.0 as usize, i as u64)
                    })
                    .collect();
                let outs = exe.run(&imgs)?;
                let want = e.kernel.run(&imgs[0], e.scale);
                let err = outs[0].max_abs_diff(&want);
                if err < 2e-5 {
                    format!("ok ({err:.1e})")
                } else {
                    format!("FAIL ({err:.1e})")
                }
            }
        };
        t.row(vec![
            e.name.clone(),
            e.kernel.label().to_string(),
            format!("{}x{}", e.src.1, e.src.0),
            e.scale.to_string(),
            e.batch.to_string(),
            e.tile.label(),
            format!("{:.0}", s.bytes as f64 / 1024.0),
            s.instructions.to_string(),
            s.whiles.to_string(),
            s.gathers.to_string(),
            s.fusions.to_string(),
            verdict,
        ]);
    }
    print!("{}", t.render());
    println!("\n{} artifacts in {dir}", manifest.entries.len());
    Ok(())
}

/// The manifest group (kernel, src, scale) with the most tile variants —
/// the shape worth tuning the fleet on — plus its candidate tiles.
fn fleet_tuning_target(m: &Manifest) -> (Interpolator, (u32, u32), u32, Vec<TileDim>) {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<(Interpolator, (u32, u32), u32), Vec<TileDim>> = BTreeMap::new();
    for e in &m.entries {
        let tiles = groups.entry((e.kernel, e.src, e.scale)).or_default();
        if !tiles.contains(&e.tile) {
            tiles.push(e.tile);
        }
    }
    let ((kernel, src, scale), tiles) = groups
        .into_iter()
        .max_by_key(|(_, tiles)| tiles.len())
        .expect("manifest has entries");
    (kernel, src, scale, tiles)
}

fn cmd_serve(args: &Args, cfg: &Config) -> Result<()> {
    let n_requests: usize = args.get_parsed_or("requests", 64)?;
    let mut serving = cfg.serving.clone();
    if let Some(w) = args.get_parsed::<usize>("workers")? {
        serving.workers = w;
    }
    if let Some(b) = args.get_parsed::<usize>("batch-max")? {
        serving.batch_max = Some(b);
    }
    if args.has("no-steal") {
        serving.work_stealing = false;
    }
    if let Some(s) = args.get("scheduler") {
        serving.scheduler = s.to_string();
    }
    // Admission precedence: --policy, else the config's admission
    // verbatim (default "reject" — under overload the demo records
    // rejections instead of blocking; pass --policy block for the old
    // submit_blocking behavior).
    if let Some(p) = args.get("policy") {
        serving.admission = p.to_string();
    }

    // --tiles WxH,WxH restricts the tile set: the demo manifest is
    // generated over exactly these tiles, and loaded artifact sets are
    // filtered to them — so a demo's tile list is visible on the command
    // line instead of baked into `Manifest::fleet_demo`.
    let tile_set: Option<Vec<TileDim>> = match args.get("tiles") {
        None => None,
        Some(_) => {
            let tiles = args
                .get_list("tiles")
                .iter()
                .map(|s| s.parse::<TileDim>().map_err(|e: String| anyhow!("--tiles: {e}")))
                .collect::<Result<Vec<_>>>()?;
            if tiles.is_empty() {
                bail!("--tiles needs at least one WxH entry");
            }
            let mut dedup = tiles.clone();
            dedup.sort_by_key(|t| (t.x, t.y));
            dedup.dedup();
            if dedup.len() != tiles.len() {
                bail!("--tiles has duplicate entries");
            }
            Some(tiles)
        }
    };

    let mock = args.has("mock");
    let dir = args.get_or("artifacts", &serving.artifacts_dir);
    let mut manifest = match Manifest::load(Path::new(dir)) {
        Ok(m) => m,
        Err(e) if mock => {
            eprintln!("note: no artifacts in '{dir}' ({e:#}); using the built-in demo manifest");
            match &tile_set {
                Some(tiles) => Manifest::fleet_demo_with_tiles(tiles)?,
                None => Manifest::fleet_demo(),
            }
        }
        Err(e) => {
            return Err(e).with_context(|| format!("loading artifacts from '{dir}' (run `make artifacts`?)"))
        }
    };
    if let Some(tiles) = &tile_set {
        let before = manifest.entries.len();
        manifest.retain_tiles(tiles);
        if manifest.entries.is_empty() {
            bail!(
                "--tiles {} matches none of the {before} artifacts",
                tiles.iter().map(|t| t.label()).collect::<Vec<_>>().join(",")
            );
        }
    }
    if manifest.entries.is_empty() {
        bail!("manifest has no artifacts");
    }
    let make_backend = || -> Arc<dyn ResizeBackend> {
        if mock {
            Arc::new(MockEngine::new())
        } else {
            Arc::new(EngineHandle::new(manifest.clone()))
        }
    };
    let fixed: Option<TileDim> = match args.get("tile") {
        Some(t) => Some(t.parse().map_err(|e: String| anyhow!(e))?),
        None => None,
    };

    // Fleet members: --devices overrides the config's serving.devices;
    // empty = one anonymous single-backend member.
    let device_ids: Vec<String> = {
        let list = args.get_list("devices");
        if list.is_empty() {
            serving.devices.clone()
        } else {
            list
        }
    };
    // --autoscale (or [autoscaler] enabled=true) closes the capacity
    // loop: a background policy engages/parks standby members through
    // the control plane. The pool comes from --standby-devices, else
    // the config's [autoscaler] standby_devices.
    let autoscale = args.has("autoscale") || cfg.autoscaler.enabled;
    let standby_ids: Vec<String> = {
        let list = args.get_list("standby-devices");
        if list.is_empty() {
            cfg.autoscaler.standby_devices.clone()
        } else {
            list
        }
    };
    if !args.get_list("standby-devices").is_empty() && !autoscale {
        bail!("--standby-devices needs --autoscale (or [autoscaler] enabled=true)");
    }
    if autoscale {
        if device_ids.is_empty() {
            bail!("--autoscale needs a device fleet: pass --devices a,b");
        }
        if standby_ids.is_empty() {
            bail!(
                "--autoscale needs a standby pool: pass --standby-devices c,d or set \
                 [autoscaler] standby_devices in the config"
            );
        }
        for (i, id) in standby_ids.iter().enumerate() {
            // Scale-down removes by label, so a collision with a serving
            // member would take the base fleet down with the burst
            // capacity.
            if device_ids.contains(id) {
                bail!("standby device '{id}' is already a fleet member");
            }
            if standby_ids[..i].contains(id) {
                bail!("--standby-devices lists '{id}' twice");
            }
        }
    }
    let standby_descs: Vec<DeviceDescriptor> = if autoscale {
        standby_ids
            .iter()
            .map(|id| cfg.device(id).cloned())
            .collect::<Result<_>>()?
    } else {
        Vec::new()
    };
    // Set when the fleet serves per-device tuned tiles: the key the
    // --watch-db daemon watches in the tuning database.
    let mut watch_spec: Option<RetuneSpec> = None;
    // The policy standby members resolve through when engaged — tuned
    // alongside the base fleet so scale-up routes straight to the new
    // member's best tile.
    let mut standby_policy: Option<TilePolicy> = None;
    let mut builder = FleetBuilder::new(&serving, &manifest);
    if device_ids.is_empty() {
        let policy = match fixed {
            Some(t) => TilePolicy::Fixed(t),
            // Largest-tile (CPU-optimal) variant preference; a fleet
            // deployment gets TilePolicy::PerDevice below.
            None => TilePolicy::PortableFallback,
        };
        builder = builder.backend(make_backend(), policy);
    } else {
        let devices: Vec<DeviceDescriptor> = device_ids
            .iter()
            .map(|id| cfg.device(id).cloned())
            .collect::<Result<_>>()?;
        let policy = match fixed {
            Some(t) => TilePolicy::Fixed(t),
            None => {
                // Tune the fleet on the manifest's richest shape so each
                // device routes through its own best tile. Standby
                // devices tune alongside: they serve the same shapes the
                // moment the autoscaler engages them.
                let (kernel, src, scale, tiles) = fleet_tuning_target(&manifest);
                let mut tuned_devices = devices.clone();
                tuned_devices.extend(standby_descs.iter().cloned());
                let outcome = TuningSession::new(SimCostModel)
                    .devices(tuned_devices)
                    .kernel(kernel)
                    .scale(scale)
                    .src((src.1, src.0)) // entry src is (h, w)
                    .tiles(tiles.clone())
                    .run()?;
                // The same key a `tilekit tune --cache` refresh writes:
                // the daemon watches it for new winners. The cache keys
                // entries by the strategy that produced them, so
                // --watch-strategy must name the strategy the refresh
                // runs use (`cached` stores under its inner strategy's
                // name — the default `tune --cache` flow writes
                // "exhaustive" entries).
                watch_spec = Some(RetuneSpec {
                    kernel,
                    scale,
                    src: (src.1, src.0),
                    strategy: args.get_or("watch-strategy", "exhaustive").to_string(),
                    tiles_fp: tilekit::autotuner::TuningDb::tiles_fingerprint(&tiles),
                });
                println!(
                    "fleet tuning ({} {}x{} s{scale}): {}",
                    kernel.label(),
                    src.1,
                    src.0,
                    outcome
                        .per_device
                        .iter()
                        .map(|d| format!("{} -> {}", d.device_id, d.best))
                        .collect::<Vec<_>>()
                        .join(", ")
                );
                TilePolicy::PerDevice(outcome)
            }
        };
        standby_policy = Some(policy.clone());
        for d in devices {
            builder = builder.device(d, make_backend(), policy.clone());
        }
    }
    let svc = builder.build()?;
    let keys = svc.keys();
    if keys.is_empty() {
        bail!("no member can serve any manifest shape");
    }
    // Start the capacity loop before any workload so the first burst
    // already has the standby pool behind it.
    let autoscaler = if autoscale {
        let standby: Vec<StandbyMember> = standby_descs
            .iter()
            .map(|d| StandbyMember {
                device: d.clone(),
                backend: make_backend(),
                policy: standby_policy
                    .clone()
                    .expect("autoscale requires a device fleet, validated above"),
            })
            .collect();
        let mut opts = cfg.autoscaler.opts();
        // Reaching this point means autoscaling was requested (flag or
        // config table), so never start the loop parked just because the
        // flag was given while the config says enabled = false.
        opts.start_disabled = false;
        let a = Autoscaler::spawn(svc.controller(), standby, opts)?;
        println!("autoscaler: {}", a.handle().view().summary());
        Some(a)
    } else {
        None
    };
    // --watch-db: a RetuneDaemon polls the tuning database and drives
    // the control plane when a refresh flips a member's winner.
    let daemon = match args.get("watch-db") {
        None => None,
        Some(db_path) => {
            let spec = watch_spec.ok_or_else(|| {
                anyhow!(
                    "--watch-db needs a tuned device fleet: pass --devices and drop --tile"
                )
            })?;
            let poll_ms: f64 = args.get_parsed_or("watch-poll-ms", serving.retune_poll_ms)?;
            if poll_ms.is_nan() || poll_ms <= 0.0 {
                bail!("--watch-poll-ms must be > 0 (got {poll_ms})");
            }
            println!(
                "watching tuning db {db_path} (poll {poll_ms:.0} ms): a refresh hot-swaps \
                 tuned tiles through the control plane, no fleet drain"
            );
            Some(RetuneDaemon::spawn(
                svc.controller(),
                std::path::PathBuf::from(db_path),
                spec,
                saturating_duration_from_ms(poll_ms),
            ))
        }
    };
    // --listen (or a configured serving.listen) swaps the demo workload
    // for the wire protocol: the same fleet, served to remote clients.
    let listen = args
        .get("listen")
        .map(str::to_string)
        .or_else(|| serving.listen.clone());
    if let Some(addr_s) = listen {
        let addr = tilekit::net::ListenAddr::parse(&addr_s)
            .with_context(|| format!("--listen '{addr_s}'"))?;
        let factory: tilekit::net::BackendFactory = {
            let manifest = manifest.clone();
            Arc::new(move |_d: &DeviceDescriptor| -> Arc<dyn ResizeBackend> {
                if mock {
                    Arc::new(MockEngine::new())
                } else {
                    Arc::new(EngineHandle::new(manifest.clone()))
                }
            })
        };
        let fleet = Arc::new(svc);
        let server = tilekit::net::NetServer::bind_with(
            &addr,
            Arc::clone(&fleet),
            factory,
            autoscaler.as_ref().map(|a| a.handle()),
            cfg.net.server_config(),
        )?;
        println!(
            "listening on {} ({} member(s), scheduler {}, admission {})",
            server.local_addr(),
            fleet.member_count(),
            fleet.scheduler_name(),
            fleet.admission_name(),
        );
        // The loopback smoke test reads the bound address from a piped
        // stdout; without the flush it sits in the pipe buffer.
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        match args.get_parsed::<f64>("listen-for-ms")? {
            Some(ms) => {
                if ms.is_nan() || ms < 0.0 {
                    bail!("--listen-for-ms must be >= 0 (got {ms})");
                }
                std::thread::sleep(saturating_duration_from_ms(ms));
            }
            None => loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            },
        }
        server.shutdown();
        if let Some(d) = daemon {
            d.stop();
        }
        if let Some(a) = autoscaler {
            println!("autoscaler: {}", a.handle().view().summary());
            a.stop();
        }
        println!("served: {}", fleet.stats().summary());
        // Reclaim the fleet for a clean worker join; connection threads
        // release their handles shortly after server shutdown.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let mut fleet = fleet;
        loop {
            match Arc::try_unwrap(fleet) {
                Ok(f) => {
                    f.shutdown();
                    break;
                }
                Err(arc) => {
                    if std::time::Instant::now() > deadline {
                        break; // process exit reaps the threads
                    }
                    fleet = arc;
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
            }
        }
        return Ok(());
    }

    let batch_max_label = match serving.batch_max {
        Some(b) => b.to_string(),
        None => "auto (per compute capability)".to_string(),
    };
    println!(
        "serving demo: {} requests over {} artifact shapes, {} member(s), {} workers each, \
         batch_max {}, scheduler {}, admission {}, stealing {}",
        n_requests,
        keys.len(),
        svc.member_count(),
        serving.workers,
        batch_max_label,
        svc.scheduler_name(),
        svc.admission_name(),
        if serving.work_stealing && svc.member_count() > 1 {
            "on"
        } else {
            "off"
        },
    );

    let seed: u64 = args.get_parsed_or("seed", 42)?;
    let mut rng = tilekit::util::Pcg32::seeded(seed);
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(n_requests);
    let mut rejected = 0usize;
    for i in 0..n_requests {
        let key = *rng.pick(&keys);
        let img = generate::test_scene(key.src.1 as usize, key.src.0 as usize, rng.next_u64());
        // A quarter of the demo traffic is batch-class, so the QoS
        // histograms and shed-batch policy have something to act on.
        let priority = if i % 4 == 3 {
            Priority::Batch
        } else {
            Priority::Interactive
        };
        match svc.submit(Request::new(key.kernel, img, key.scale).priority(priority)) {
            Ok(t) => tickets.push(t),
            Err(SubmitError::Saturated) => rejected += 1,
            Err(e) => return Err(anyhow!("{e}")),
        }
    }
    let mut ok = 0usize;
    for t in &tickets {
        loop {
            match t.wait_timeout(std::time::Duration::from_secs(30)) {
                Ok(Some(_)) => {
                    ok += 1;
                    break;
                }
                Ok(None) => continue,
                Err(_) => break,
            }
        }
    }
    let wall = t0.elapsed();

    // Per-device breakdown BEFORE shutdown consumes the service.
    let mut breakdown = tilekit::util::text::Table::new(vec![
        "device",
        "tile",
        "batch max",
        "admitted",
        "completed",
        "shed",
        "steals",
        "stolen",
        "batches",
        "mean batch",
        "p50 us",
        "p99 us",
        "sim cost ms",
    ]);
    for v in svc.members() {
        let s = v.stats;
        breakdown.row(vec![
            v.label.to_string(),
            v.tile_pref.map(|t| t.label()).unwrap_or_else(|| "-".into()),
            v.batch_max.to_string(),
            s.admitted.get().to_string(),
            s.completed.get().to_string(),
            (s.shed.get() + s.cancelled.get()).to_string(),
            s.steals.get().to_string(),
            s.stolen.get().to_string(),
            s.batches.get().to_string(),
            format!("{:.2}", s.mean_batch()),
            format!("{:.0}", s.latency.percentile_us(50.0)),
            format!("{:.0}", s.latency.percentile_us(99.0)),
            format!("{:.3}", s.sim_cost_ms()),
        ]);
    }
    if let Some(d) = daemon {
        let s = d.stats();
        println!(
            "\nretune daemon: polls={} refreshes={} retunes applied={} errors={}",
            s.polls.get(),
            s.refreshes.get(),
            s.applied.get(),
            s.errors.get()
        );
        d.stop();
    }
    if let Some(a) = autoscaler {
        println!("\nautoscaler: {}", a.handle().view().summary());
        a.stop();
    }
    let stats = svc.shutdown();
    println!(
        "\ncompleted {ok}/{n_requests} ({rejected} rejected) in {:.1} ms",
        wall.as_secs_f64() * 1e3
    );
    println!(
        "throughput: {:.1} req/s | {}",
        n_requests as f64 / wall.as_secs_f64(),
        stats.summary()
    );
    println!("\nper-device breakdown:");
    print!("{}", breakdown.render());
    println!("\nper-priority latency:\n{}", stats.class_summary());
    if let Some(line) = stats.submit_breakdown() {
        println!("\n{line}");
    }
    Ok(())
}

const FLEET_HELP: &str = r#"tilekit fleet — drive the typed control plane against a live demo fleet

USAGE: tilekit fleet <action> [flags]
       tilekit fleet --connect host:port|unix:/p.sock <action> [flags]

ACTIONS (in-process demo)
  topology             serve a short mock workload, then print the
                       epoch-stamped membership snapshot
  drain                mark one member draining mid-load: the scheduler
                       stops picking it, in-flight work still completes
  retune               hot-swap one member's tuned tile mid-load through
                       FleetController::retune (no fleet drain)
  autoscaler <status|enable|disable|set>
                       spin up the demo fleet plus a standby pool
                       (--standby-devices, default 8800gtx), spawn the
                       capacity loop, and drive it through its live
                       handle; `set` takes --low/--high/--cooldown-ms

ACTIONS (remote, with --connect against a `serve --listen` fleet)
  topology             print the remote epoch-stamped topology
  stats                print the remote fleet's serving stats
  drain --device id    stop admissions to a remote member
  retune --device id [--outcome f.json]
                       hot-swap a remote member's tuned tile: sends the
                       TuningOutcome from --outcome, or recomputes the
                       mock-demo outcome with the winner flipped
  add-member --device id [--tile WxH]
                       grow the remote fleet with a registry device
                       (fixed tile, else the portable fallback)
  remove-member --device id [--mode graceful|immediate]
                       shrink the remote fleet
  set-scheduler --scheduler s
                       swap the remote scheduler (round-robin |
                       least-loaded | cost-eta)
  set-admission --policy p [--timeout-ms N]
                       swap the remote admission policy
  set-steal --steal on|off [--steal-threshold N]
                       reconfigure remote work stealing
  autoscaler <status|enable|disable|set>
                       inspect or reconfigure the remote capacity loop
                       (needs `serve --autoscale`); `set` takes
                       --low/--high/--cooldown-ms and echoes the
                       post-update state

FLAGS
  --connect addr       drive a remote fleet instead of the in-process demo
  --devices a,b        (demo) fleet member device ids (default gtx260,fermi)
  --device id          the member the action targets (demo default: the
                       first fleet device)
  --requests N         (demo) workload size (default 24)
  --standby-devices c,d
                       (autoscaler demo) the standby pool (default 8800gtx)
  --low N / --high N   (autoscaler set) watermark band: per-member queue
                       depth below/above which the loop parks/engages
  --cooldown-ms N      (autoscaler set) hold after any scale action,
                       converted to control-loop ticks at the loop's poll

The demo fleet runs in-process over the built-in mock manifest: each
command builds the fleet, applies the control-plane operation while
requests are in flight, and prints the topology before and after. Both
paths speak the transport-agnostic ops::{FleetOps, ControlOps} traits
through one shared driver — the demo with an in-process ops::LocalFleet
behind the traits, --connect with the pipelined net::FleetClient.
"#;

/// Print one epoch-stamped topology snapshot.
fn print_topology(ctl: &FleetController) {
    let topo = ctl.topology();
    println!("topology epoch {}:", topo.epoch);
    let mut t = tilekit::util::text::Table::new(vec![
        "id", "member", "tile", "batch max", "draining", "admitted", "completed", "steals",
        "stolen",
    ]);
    for m in &topo.members {
        t.row(vec![
            m.id.to_string(),
            m.label.to_string(),
            m.tile_pref.map(|x| x.label()).unwrap_or_else(|| "-".into()),
            m.batch_max.to_string(),
            if m.draining { "yes" } else { "no" }.to_string(),
            m.stats.admitted.get().to_string(),
            m.stats.completed.get().to_string(),
            m.stats.steals.get().to_string(),
            m.stats.stolen.get().to_string(),
        ]);
    }
    print!("{}", t.render());
}

fn cmd_fleet(args: &Args, cfg: &Config) -> Result<()> {
    if args.has("help") {
        print!("{FLEET_HELP}");
        return Ok(());
    }
    if let Some(addr) = args.get("connect") {
        return cmd_fleet_remote(args, cfg, addr);
    }
    let action = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| {
            anyhow!("usage: tilekit fleet <topology|drain|retune|autoscaler> [flags]")
        })?;
    if action == "autoscaler" {
        return cmd_fleet_autoscaler_demo(args, cfg);
    }
    if !matches!(action, "topology" | "drain" | "retune") {
        bail!(
            "unknown fleet action '{action}' (expected one of: topology, drain, retune, \
             autoscaler)"
        );
    }
    let n_requests: usize = args.get_parsed_or("requests", 24)?;
    let device_ids: Vec<String> = {
        let list = args.get_list("devices");
        if list.is_empty() {
            vec!["gtx260".into(), "fermi".into()]
        } else {
            list
        }
    };
    let devices: Vec<DeviceDescriptor> = device_ids
        .iter()
        .map(|id| cfg.device(id).cloned())
        .collect::<Result<_>>()?;
    let target = args
        .get("device")
        .unwrap_or(device_ids[0].as_str())
        .to_string();
    if !device_ids.contains(&target) {
        bail!("--device '{target}' is not in the fleet {device_ids:?}");
    }
    if action == "drain" && device_ids.len() < 2 {
        bail!("the drain demo needs at least two fleet members (--devices a,b)");
    }

    // The in-process demo fleet: the built-in mock manifest, each device
    // routed through its own tuned tile.
    let manifest = Manifest::fleet_demo();
    let (kernel, src, scale, tiles) = fleet_tuning_target(&manifest);
    let outcome = TuningSession::new(SimCostModel)
        .devices(devices.clone())
        .kernel(kernel)
        .scale(scale)
        .src((src.1, src.0))
        .tiles(tiles)
        .run()?;
    let serving = tilekit::config::ServingConfig {
        workers: 2,
        batch_max: Some(4),
        batch_deadline_ms: 0.5,
        queue_cap: 1024,
        ..cfg.serving.clone()
    };
    let mut builder = FleetBuilder::new(&serving, &manifest);
    for d in devices {
        builder = builder.device(
            d,
            Arc::new(MockEngine::new()),
            TilePolicy::PerDevice(outcome.clone()),
        );
    }
    let svc = builder
        .admission(tilekit::coordinator::BlockWithTimeout(
            std::time::Duration::from_secs(30),
        ))
        .build()?;
    // Every submit and control-plane mutation below goes through the
    // transport-agnostic ops traits — the same code path `fleet
    // --connect` drives over the wire, with LocalFleet behind the trait
    // instead of FleetClient.
    let fleet = Arc::new(svc);
    let ops = LocalFleet::new(Arc::clone(&fleet), demo_backend_factory());
    println!(
        "demo fleet: {} member(s), mock backends, per-device tuned tiles\n",
        fleet.member_count()
    );
    print_topology(ops.controller());

    let keys = fleet.keys();
    let mut rng = tilekit::util::Pcg32::seeded(7);
    let mut submit_wave = |n: usize| -> Result<Vec<tilekit::coordinator::Ticket>> {
        (0..n)
            .map(|_| {
                let key = *rng.pick(&keys);
                let img = generate::test_scene(
                    key.src.1 as usize,
                    key.src.0 as usize,
                    rng.next_u64(),
                );
                ops.submit_request(Request::new(key.kernel, img, key.scale))
                    .map_err(|e| anyhow!("{e}"))
            })
            .collect()
    };

    let first = submit_wave(n_requests / 2)?;
    match action {
        "topology" => {}
        "drain" => {
            println!("\n=> drain('{target}') with {} requests in flight", first.len());
            ops.drain_member(&target).map_err(|e| anyhow!("{e}"))?;
        }
        "retune" => {
            let before = outcome
                .best_for(&target)
                .map(|t| t.label())
                .unwrap_or_else(|| "-".into());
            let flipped = outcome
                .with_flipped_winner(&target)
                .ok_or_else(|| anyhow!("no launchable point to flip for '{target}'"))?;
            let after = ops
                .retune_member(&target, &flipped)
                .map_err(|e| anyhow!("{e}"))?;
            println!(
                "\n=> retune('{target}'): tile {before} -> {} with {} requests in flight \
                 (no drain; epoch unchanged — retune is not a membership change)",
                after.map(|t| t.label()).unwrap_or_else(|| "-".into()),
                first.len()
            );
        }
        _ => unreachable!("validated above"),
    }
    let second = submit_wave(n_requests - n_requests / 2)?;
    if action == "drain" {
        // Everything submitted after the drain must route around the
        // draining member.
        for t in &second {
            if t.device_id() == Some(target.as_str()) {
                bail!("post-drain request was scheduled onto draining member '{target}'");
            }
        }
    }
    let mut completed = 0usize;
    for t in first.into_iter().chain(second) {
        t.wait()?;
        completed += 1;
    }
    println!("\ncompleted {completed}/{n_requests}; final state:\n");
    print_topology(ops.controller());
    if action == "drain" {
        let drained_new: u64 = ops
            .controller()
            .topology()
            .members
            .iter()
            .filter(|m| &*m.label == target.as_str())
            .map(|m| m.stats.admitted.get())
            .sum();
        println!(
            "\n'{target}' admitted {drained_new} requests total; everything submitted after \
             the drain routed to its peers, and nothing in flight was lost"
        );
    }
    drop(submit_wave);
    drop(ops);
    if let Ok(f) = Arc::try_unwrap(fleet) {
        f.shutdown();
    }
    Ok(())
}

/// Mock backends for members a control verb adds at runtime — the demo
/// analogue of the factory `serve --listen` hands its `NetServer`.
fn demo_backend_factory() -> tilekit::net::BackendFactory {
    Arc::new(|_d: &DeviceDescriptor| Arc::new(MockEngine::new()) as Arc<dyn ResizeBackend>)
}

/// Build an [`AutoscalerUpdate`] from `--low` / `--high` / `--cooldown-ms`.
/// Cooldown converts to control-loop ticks at `poll_ms` — the loop's own
/// sampling interval — so the handle (and the wire) never carry wall-clock.
fn autoscaler_update_from_flags(args: &Args, poll_ms: f64) -> Result<AutoscalerUpdate> {
    let update = AutoscalerUpdate {
        enabled: None,
        low_queue: args.get_parsed::<f64>("low")?,
        high_queue: args.get_parsed::<f64>("high")?,
        high_p99_us: None,
        cooldown_ticks: match args.get_parsed::<f64>("cooldown-ms")? {
            None => None,
            Some(ms) => {
                if ms.is_nan() || ms < 0.0 {
                    bail!("--cooldown-ms must be >= 0 (got {ms})");
                }
                Some((ms / poll_ms.max(1.0)).ceil() as u32)
            }
        },
    };
    if update.is_empty() {
        bail!("set needs at least one of --low, --high, --cooldown-ms");
    }
    Ok(update)
}

/// `tilekit fleet autoscaler <status|enable|disable|set>` without
/// --connect: spin up the in-process demo fleet plus a standby pool,
/// spawn the capacity loop, and drive the sub-action through the same
/// [`AutoscalerHandle`](tilekit::coordinator::AutoscalerHandle) the net
/// server answers `autoscaler`/`set_autoscaler` frames with.
fn cmd_fleet_autoscaler_demo(args: &Args, cfg: &Config) -> Result<()> {
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("status");
    if !matches!(sub, "status" | "enable" | "disable" | "set") {
        bail!(
            "unknown autoscaler action '{sub}' (expected one of: status, enable, disable, set)"
        );
    }
    let device_ids: Vec<String> = {
        let list = args.get_list("devices");
        if list.is_empty() {
            vec!["gtx260".into(), "fermi".into()]
        } else {
            list
        }
    };
    let standby_ids: Vec<String> = {
        let list = args.get_list("standby-devices");
        if list.is_empty() {
            vec!["8800gtx".into()]
        } else {
            list
        }
    };
    for id in &standby_ids {
        if device_ids.contains(id) {
            bail!("standby device '{id}' is already a fleet member");
        }
    }
    let devices: Vec<DeviceDescriptor> = device_ids
        .iter()
        .map(|id| cfg.device(id).cloned())
        .collect::<Result<_>>()?;
    let standby_descs: Vec<DeviceDescriptor> = standby_ids
        .iter()
        .map(|id| cfg.device(id).cloned())
        .collect::<Result<_>>()?;

    let manifest = Manifest::fleet_demo();
    let (kernel, src, scale, tiles) = fleet_tuning_target(&manifest);
    let mut tuned = devices.clone();
    tuned.extend(standby_descs.iter().cloned());
    let outcome = TuningSession::new(SimCostModel)
        .devices(tuned)
        .kernel(kernel)
        .scale(scale)
        .src((src.1, src.0))
        .tiles(tiles)
        .run()?;
    let serving = tilekit::config::ServingConfig {
        workers: 2,
        batch_max: Some(4),
        batch_deadline_ms: 0.5,
        queue_cap: 1024,
        ..cfg.serving.clone()
    };
    let mut builder = FleetBuilder::new(&serving, &manifest);
    for d in devices {
        builder = builder.device(
            d,
            Arc::new(MockEngine::new()),
            TilePolicy::PerDevice(outcome.clone()),
        );
    }
    let svc = builder.build()?;
    let standby: Vec<StandbyMember> = standby_descs
        .iter()
        .map(|d| StandbyMember {
            device: d.clone(),
            backend: Arc::new(MockEngine::new()),
            policy: TilePolicy::PerDevice(outcome.clone()),
        })
        .collect();
    // The demo loop starts per the config table (parked by default), so
    // `status` shows the resting state and `enable` has work to do.
    let scaler = Autoscaler::spawn(svc.controller(), standby, cfg.autoscaler.opts())?;
    let fleet = Arc::new(svc);
    // The sub-action runs through the same transport-agnostic driver
    // `fleet --connect autoscaler` uses, with the live handle wired into
    // the in-process ControlOps implementation.
    let ops = LocalFleet::new(Arc::clone(&fleet), demo_backend_factory())
        .with_autoscaler(scaler.handle());
    println!(
        "demo fleet: {} member(s) + {} standby, mock backends, per-device tuned tiles\n",
        fleet.member_count(),
        standby_ids.len()
    );
    println!(
        "before: {}",
        ops.autoscaler_desc().map_err(|e| anyhow!("{e}"))?.summary()
    );
    if sub != "status" {
        let desc = fleet_autoscaler_action(&ops, args, sub)?;
        println!("after:  {}", desc.summary());
    }
    scaler.stop();
    drop(ops);
    if let Ok(f) = Arc::try_unwrap(fleet) {
        f.shutdown();
    }
    Ok(())
}

/// Print an epoch-stamped [`TopologyDesc`](tilekit::net::TopologyDesc)
/// snapshot — the transport-neutral topology shape both `ControlOps`
/// implementations hand out.
fn print_topology_desc(topo: &tilekit::net::TopologyDesc) {
    println!("topology epoch {}:", topo.epoch);
    let mut t = tilekit::util::text::Table::new(vec![
        "id", "member", "device", "tile", "batch max", "draining", "admitted", "completed",
        "inflight",
    ]);
    for m in &topo.members {
        t.row(vec![
            m.id.to_string(),
            m.label.clone(),
            m.device.clone().unwrap_or_else(|| "-".into()),
            m.tile.map(|x| x.label()).unwrap_or_else(|| "-".into()),
            m.batch_max.to_string(),
            if m.draining { "yes" } else { "no" }.to_string(),
            m.admitted.to_string(),
            m.completed.to_string(),
            m.inflight.to_string(),
        ]);
    }
    print!("{}", t.render());
}

/// The `autoscaler <status|enable|disable|set>` sub-actions, written
/// once against [`ControlOps`]: the in-process demo and `fleet
/// --connect` both dispatch through here. Returns the post-action
/// autoscaler state.
fn fleet_autoscaler_action<C: ControlOps>(
    ctl: &C,
    args: &Args,
    sub: &str,
) -> Result<tilekit::net::AutoscalerDesc> {
    match sub {
        "status" => ctl.autoscaler_desc().map_err(|e| anyhow!("{e}")),
        "enable" | "disable" => {
            let update = AutoscalerUpdate {
                enabled: Some(sub == "enable"),
                ..Default::default()
            };
            ctl.apply_autoscaler(&update).map_err(|e| anyhow!("{e}"))
        }
        "set" => {
            // The loop's own poll interval scales --cooldown-ms into
            // ticks, wherever the loop runs.
            let poll_ms = ctl.autoscaler_desc().map_err(|e| anyhow!("{e}"))?.poll_ms;
            let update = autoscaler_update_from_flags(args, poll_ms as f64)?;
            ctl.apply_autoscaler(&update).map_err(|e| anyhow!("{e}"))
        }
        other => bail!(
            "unknown autoscaler action '{other}' (expected one of: status, \
             enable, disable, set)"
        ),
    }
}

/// One driver for every fleet control verb, written against the
/// transport-agnostic ops traits. `fleet --connect` hands it the wire
/// client; the in-process demo hands it a
/// [`LocalFleet`](tilekit::ops::LocalFleet). Flag parsing, dispatch, and
/// output are identical either way — only the implementation behind the
/// traits differs.
fn fleet_control_action<C: ControlOps + FleetOps>(
    ctl: &C,
    args: &Args,
    cfg: &Config,
    action: &str,
) -> Result<()> {
    use tilekit::coordinator::DrainMode;
    let need_device = || -> Result<&str> {
        args.get("device")
            .ok_or_else(|| anyhow!("'{action}' needs --device <registry id>"))
    };
    match action {
        "topology" => {
            let topo = ctl.topology_desc().map_err(|e| anyhow!("{e}"))?;
            print_topology_desc(&topo);
        }
        "stats" => {
            let stats = ctl.fleet_stats().map_err(|e| anyhow!("{e}"))?;
            println!("{}", stats.summary());
        }
        "drain" => {
            let device = need_device()?;
            let epoch = ctl.drain_member(device).map_err(|e| anyhow!("{e}"))?;
            println!("drain('{device}') acknowledged at epoch {epoch}");
        }
        "retune" => {
            let device = need_device()?;
            let outcome = match args.get("outcome") {
                Some(path) => tilekit::autotuner::TuningOutcome::load(Path::new(path))?,
                None => {
                    // No database given: recompute the mock-demo outcome with
                    // the winner flipped, so the swap is visible against a
                    // fleet started from the same built-in manifest.
                    let manifest = Manifest::fleet_demo();
                    let (kernel, src, scale, tiles) = fleet_tuning_target(&manifest);
                    let base = TuningSession::new(SimCostModel)
                        .devices(vec![cfg.device(device)?.clone()])
                        .kernel(kernel)
                        .scale(scale)
                        .src((src.1, src.0))
                        .tiles(tiles)
                        .run()?;
                    base.with_flipped_winner(device)
                        .ok_or_else(|| anyhow!("no launchable point to flip for '{device}'"))?
                }
            };
            let tile = ctl
                .retune_member(device, &outcome)
                .map_err(|e| anyhow!("{e}"))?;
            println!(
                "retune('{device}'): remote tile now {}",
                tile.map(|t| t.label()).unwrap_or_else(|| "-".into())
            );
        }
        "add-member" => {
            let device = need_device()?;
            let policy = match args.get("tile") {
                Some(t) => TilePolicy::Fixed(t.parse().map_err(|e: String| anyhow!(e))?),
                None => TilePolicy::PortableFallback,
            };
            let (member, epoch) = ctl
                .add_member_by_id(device, &policy)
                .map_err(|e| anyhow!("{e}"))?;
            println!("added '{device}' as member {member}; epoch {epoch}");
        }
        "remove-member" => {
            let device = need_device()?;
            let mode = match args.get_or("mode", "graceful") {
                "graceful" => DrainMode::Graceful,
                "immediate" => DrainMode::Immediate,
                other => bail!("unknown --mode '{other}' (graceful|immediate)"),
            };
            let epoch = ctl
                .remove_member_by_id(device, mode)
                .map_err(|e| anyhow!("{e}"))?;
            println!("removed '{device}'; epoch {epoch}");
        }
        "set-scheduler" => {
            let name = args
                .get("scheduler")
                .ok_or_else(|| anyhow!("set-scheduler needs --scheduler <name>"))?;
            ctl.set_scheduler_named(name).map_err(|e| anyhow!("{e}"))?;
            println!("scheduler set to '{name}'");
        }
        "set-admission" => {
            let name = args
                .get("policy")
                .ok_or_else(|| anyhow!("set-admission needs --policy <name>"))?;
            let timeout_ms: u64 = args.get_parsed_or("timeout-ms", 50)?;
            ctl.set_admission_named(name, std::time::Duration::from_millis(timeout_ms))
                .map_err(|e| anyhow!("{e}"))?;
            println!("admission set to '{name}' (timeout {timeout_ms} ms)");
        }
        "set-steal" => {
            let enabled = match args.get_or("steal", "on") {
                "on" | "true" => true,
                "off" | "false" => false,
                other => bail!("unknown --steal '{other}' (on|off)"),
            };
            let threshold: usize = args.get_parsed_or("steal-threshold", 2)?;
            ctl.set_stealing(enabled, threshold)
                .map_err(|e| anyhow!("{e}"))?;
            println!(
                "work stealing {} (threshold {threshold})",
                if enabled { "enabled" } else { "disabled" }
            );
        }
        "autoscaler" => {
            let sub = args.positional.get(1).map(String::as_str).unwrap_or("status");
            let desc = fleet_autoscaler_action(ctl, args, sub)?;
            println!("{}", desc.summary());
        }
        other => bail!(
            "unknown remote fleet action '{other}' (expected one of: topology, stats, \
             drain, retune, add-member, remove-member, set-scheduler, set-admission, \
             set-steal, autoscaler)"
        ),
    }
    Ok(())
}

/// `tilekit fleet --connect <addr> <action>`: the same control-plane verbs
/// as the in-process demo, but spoken over the wire to a `serve --listen`
/// fleet — plus the membership/reconfiguration verbs that only make sense
/// against a long-lived remote process. Everything after the dial is the
/// shared [`fleet_control_action`] driver with the pipelined, v2-capable
/// `FleetClient` behind the ops traits.
fn cmd_fleet_remote(args: &Args, cfg: &Config, addr: &str) -> Result<()> {
    use tilekit::net::{FleetClient, ListenAddr};

    let action = args.positional.first().map(String::as_str).ok_or_else(|| {
        anyhow!(
            "usage: tilekit fleet --connect <addr> <topology|stats|drain|retune|\
             add-member|remove-member|set-scheduler|set-admission|set-steal|\
             autoscaler> [flags]"
        )
    })?;
    let addr = ListenAddr::parse(addr)?;
    let client = FleetClient::connect_with(&addr, cfg.net.client_config())
        .map_err(|e| anyhow!("connect {addr}: {e}"))?;
    fleet_control_action(&client, args, cfg, action)
}

const SUBMIT_HELP: &str = r#"tilekit submit — send resize requests to a remote fleet over the wire

USAGE: tilekit submit --connect host:port|unix:/p.sock [flags]

FLAGS
  --connect addr       the `serve --listen` fleet to talk to (required)
  --kernel k           nearest | bilinear | bicubic (default bilinear)
  --src WxH            source image size (default 64x64)
  --scale N            integer upscale factor (default 2)
  --requests N         how many requests to submit (default 1)
  --priority p         interactive | batch (default interactive)
  --deadline-ms N      per-request deadline (cost-eta scheduler declines
                       infeasible ones with a typed error)
  --seed N             test-scene seed (default 7)

Each request carries a generated test scene; the command submits them
all, then waits for every ticket and prints the serving device and the
end-to-end wire latency per request.
"#;

fn cmd_submit(args: &Args, cfg: &Config) -> Result<()> {
    if args.has("help") {
        print!("{SUBMIT_HELP}");
        return Ok(());
    }
    use tilekit::net::{FleetClient, ListenAddr};
    let addr_s = args
        .get("connect")
        .ok_or_else(|| anyhow!("submit needs --connect <host:port|unix:/path.sock>"))?;
    let addr = ListenAddr::parse(addr_s)?;
    let kernel = parse_kernel(args)?;
    let (w, h) = parse_src(args.get_or("src", "64x64"))?;
    let scale: u32 = args.get_parsed_or("scale", 2)?;
    let n_requests: usize = args.get_parsed_or("requests", 1)?;
    let seed: u64 = args.get_parsed_or("seed", 7)?;
    let priority = match args.get_or("priority", "interactive") {
        "interactive" => Priority::Interactive,
        "batch" => Priority::Batch,
        other => bail!("unknown --priority '{other}' (interactive|batch)"),
    };
    let deadline_ms: Option<u64> = args.get_parsed("deadline-ms")?;

    let client = FleetClient::connect_with(&addr, cfg.net.client_config())
        .map_err(|e| anyhow!("connect {addr}: {e}"))?;
    println!(
        "submitting {n_requests} {} {w}x{h} s{scale} request(s) to {addr}",
        kernel.label()
    );
    run_submit_batch(
        &client,
        kernel,
        (w, h),
        scale,
        n_requests,
        seed,
        priority,
        deadline_ms,
    )
}

/// Submit `n_requests` generated test scenes through any [`FleetOps`]
/// implementation — `submit --connect` hands this the wire client — then
/// wait for every ticket, printing the serving device and end-to-end
/// latency per request.
#[allow(clippy::too_many_arguments)]
fn run_submit_batch<F: FleetOps>(
    fleet: &F,
    kernel: Interpolator,
    (w, h): (u32, u32),
    scale: u32,
    n_requests: usize,
    seed: u64,
    priority: Priority,
    deadline_ms: Option<u64>,
) -> Result<()> {
    let mut tickets = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let img = generate::test_scene(w as usize, h as usize, seed + i as u64);
        let mut req = Request::new(kernel, img, scale).priority(priority);
        if let Some(ms) = deadline_ms {
            req = req.deadline(std::time::Duration::from_millis(ms));
        }
        let started = std::time::Instant::now();
        let ticket = fleet
            .submit_request(req)
            .map_err(|e| anyhow!("submit: {e}"))?;
        tickets.push((ticket, started));
    }
    for (i, (ticket, started)) in tickets.into_iter().enumerate() {
        let device = TicketOps::device_id(&ticket).map(str::to_string);
        let img = TicketOps::wait(ticket).map_err(|e| anyhow!("wait: {e}"))?;
        println!(
            "  #{i}: {}x{} from {} in {}",
            img.width(),
            img.height(),
            device.as_deref().unwrap_or("<scheduler's choice>"),
            fmt_ms(started.elapsed().as_secs_f64() * 1e3),
        );
    }
    Ok(())
}

const FRONT_HELP: &str = r#"tilekit front — consistent-hash front tier over N fleet servers

USAGE: tilekit front --shards addr1,addr2[,...] [flags]

FLAGS
  --shards a,b         the `serve --listen` shard addresses (required;
                       every shard must be reachable at startup)
  --requests N         demo workload size (default 32)
  --drain-owner        halfway through, drain + remove every member of
                       the shard that owns the demo shape, re-poll, and
                       keep submitting — proves shape-stable rerouting
                       with zero lost tickets
  --seed N             test-scene seed (default 7)

Routing is a consistent hash of the request *shape* (kernel, source
size, scale), so equal shapes always land on the same live shard. The
demo submits the built-in fleet manifest's tuning shape and finishes by
printing per-shard health and the merged fleet-of-fleets stats.
"#;

fn cmd_front(args: &Args, cfg: &Config) -> Result<()> {
    if args.has("help") {
        print!("{FRONT_HELP}");
        return Ok(());
    }
    use tilekit::coordinator::RequestKey;
    use tilekit::net::{FrontTier, FrontTierConfig, ListenAddr};
    let shard_list = args.get_list("shards");
    if shard_list.is_empty() {
        bail!("front needs --shards addr1,addr2[,...]");
    }
    let addrs: Vec<ListenAddr> = shard_list
        .iter()
        .map(|s| ListenAddr::parse(s))
        .collect::<Result<_>>()?;
    let n_requests: usize = args.get_parsed_or("requests", 32)?;
    let seed: u64 = args.get_parsed_or("seed", 7)?;

    let tier_cfg = FrontTierConfig {
        health_poll: Some(saturating_duration_from_ms(cfg.net.health_poll_ms)),
        client: cfg.net.client_config(),
    };
    let tier = FrontTier::connect(&addrs, tier_cfg).map_err(|e| anyhow!("{e}"))?;
    println!("front tier over {} shard(s):", tier.len());
    for v in tier.shard_views() {
        println!(
            "  {} — alive {}, draining {}, epoch {}",
            v.addr, v.alive, v.draining, v.epoch
        );
    }

    // The demo traffic reuses the built-in fleet manifest's tuning shape,
    // so every request hashes to one owner shard — which is exactly what
    // makes --drain-owner a real failover test rather than a lucky miss.
    let manifest = Manifest::fleet_demo();
    let (kernel, src, scale, _) = fleet_tuning_target(&manifest);
    let probe = generate::test_scene(src.1 as usize, src.0 as usize, seed);
    let key = RequestKey::of(kernel, &probe, scale);
    let owner = tier
        .route_for(&key)
        .ok_or_else(|| anyhow!("no live shard for the demo shape"))?;
    println!(
        "\ndemo shape {} {}x{} s{scale} routes to shard {owner} ({})",
        kernel.label(),
        src.1,
        src.0,
        tier.shard_views()[owner].addr
    );

    let drain_at = if args.has("drain-owner") {
        Some(n_requests / 2)
    } else {
        None
    };
    let mut tickets = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        if drain_at == Some(i) {
            let client = tier.client(owner);
            let topo = client.topology().map_err(|e| anyhow!("{e}"))?;
            println!(
                "\n=> drain + remove shard {owner}'s member(s) with {i} ticket(s) in flight"
            );
            for m in &topo.members {
                client.drain(&m.label).map_err(|e| anyhow!("drain: {e}"))?;
            }
            for m in &topo.members {
                client
                    .remove_member(&m.label, tilekit::coordinator::DrainMode::Graceful)
                    .map_err(|e| anyhow!("remove: {e}"))?;
            }
            tier.poll_once();
            let views = tier.shard_views();
            println!(
                "   shard {owner} now draining={} at epoch {}; traffic reroutes",
                views[owner].draining, views[owner].epoch
            );
        }
        let img = generate::test_scene(src.1 as usize, src.0 as usize, seed + i as u64);
        let (shard, ticket) = tier
            .submit(&Request::new(kernel, img, scale))
            .map_err(|e| anyhow!("submit #{i}: {e}"))?;
        tickets.push((shard, ticket));
    }

    let mut per_shard = vec![0usize; tier.len()];
    let mut completed = 0usize;
    for (shard, ticket) in tickets {
        ticket.wait().map_err(|e| anyhow!("wait: {e}"))?;
        per_shard[shard] += 1;
        completed += 1;
    }
    println!("\ncompleted {completed}/{n_requests} (zero lost tickets)");
    for (i, n) in per_shard.iter().enumerate() {
        println!("  shard {i}: {n} request(s) served");
    }
    println!("\nper-shard health:");
    for v in tier.shard_views() {
        println!(
            "  {} — alive {}, draining {}, epoch {}",
            v.addr, v.alive, v.draining, v.epoch
        );
    }
    println!("\nmerged fleet-of-fleets stats:\n{}", tier.merged_stats().summary());
    tier.shutdown();
    Ok(())
}
