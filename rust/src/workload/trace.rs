//! Workload traces: timestamped resize requests with JSON round-trip.

use crate::codec::json::Json;
use crate::coordinator::RequestKey;
use crate::image::Interpolator;
use crate::util::Pcg32;
use anyhow::{anyhow, Result};

/// Arrival process for trace generation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// All requests at t=0 (the closed-loop saturation pattern).
    Immediate,
    /// Poisson arrivals at `rate` requests/second.
    Poisson { rate: f64 },
    /// Evenly spaced arrivals at `rate` requests/second.
    Uniform { rate: f64 },
    /// Bursts of `burst` back-to-back requests, bursts Poisson at
    /// `rate` bursts/second.
    Bursty { rate: f64, burst: u32 },
}

/// One phase of a time-varying load profile: Poisson arrivals at
/// `rate` requests/second for `dur_us`. A phase with `rate = 0` is a
/// silent gap (the clock still advances).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadPhase {
    pub rate: f64,
    pub dur_us: u64,
}

/// One trace event: a request shape arriving at `t_us` after start.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub t_us: u64,
    pub key: RequestKey,
    /// Seed for the deterministic synthetic input image.
    pub seed: u64,
}

/// A replayable workload trace.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Generate a trace of `n` events over `keys` with the given
    /// arrival process. Deterministic in `seed`.
    pub fn generate(keys: &[RequestKey], n: usize, arrival: Arrival, seed: u64) -> Trace {
        assert!(!keys.is_empty(), "need at least one request shape");
        let mut rng = Pcg32::new(seed, 0x7ACE);
        let mut t_us = 0f64;
        let mut events = Vec::with_capacity(n);
        let mut burst_left = 0u32;
        for _ in 0..n {
            match arrival {
                Arrival::Immediate => {}
                Arrival::Poisson { rate } => {
                    // exponential inter-arrival
                    let u = rng.f64().max(1e-12);
                    t_us += -u.ln() / rate * 1e6;
                }
                Arrival::Uniform { rate } => {
                    t_us += 1e6 / rate;
                }
                Arrival::Bursty { rate, burst } => {
                    if burst_left == 0 {
                        let u = rng.f64().max(1e-12);
                        t_us += -u.ln() / rate * 1e6;
                        burst_left = burst;
                    }
                    burst_left -= 1;
                }
            }
            events.push(TraceEvent {
                t_us: t_us as u64,
                key: *rng.pick(keys),
                // Mask to 53 bits: seeds survive the JSON f64 number
                // representation exactly.
                seed: rng.next_u64() & ((1u64 << 53) - 1),
            });
        }
        Trace { events }
    }

    /// Generate a trace whose arrival rate changes over time: one
    /// Poisson process per [`LoadPhase`], on a single continuous clock
    /// (phase boundaries advance the clock even when a phase generates
    /// nothing). Deterministic in `seed`. This is the load shape fixed
    /// capacity cannot be right for — the autoscaler's proving ground.
    pub fn phased(keys: &[RequestKey], phases: &[LoadPhase], seed: u64) -> Trace {
        assert!(!keys.is_empty(), "need at least one request shape");
        let mut rng = Pcg32::new(seed, 0x7ACE);
        let mut events = Vec::new();
        let mut base_us = 0f64;
        for ph in phases {
            assert!(
                ph.rate.is_finite() && ph.rate >= 0.0,
                "phase rate must be finite and >= 0"
            );
            let end = base_us + ph.dur_us as f64;
            if ph.rate > 0.0 {
                let mut t = base_us;
                loop {
                    let u = rng.f64().max(1e-12);
                    t += -u.ln() / ph.rate * 1e6;
                    if t >= end {
                        break;
                    }
                    events.push(TraceEvent {
                        t_us: t as u64,
                        key: *rng.pick(keys),
                        seed: rng.next_u64() & ((1u64 << 53) - 1),
                    });
                }
            }
            base_us = end;
        }
        Trace { events }
    }

    /// A diurnal/burst profile: `cycles` alternations of a quiet phase
    /// (`quiet_rate` rps) and a burst phase (`burst_rate` rps), each
    /// `phase_us` long, ending on a trailing quiet phase so scale-down
    /// is observable inside the trace window. Deterministic in `seed`.
    pub fn diurnal(
        keys: &[RequestKey],
        quiet_rate: f64,
        burst_rate: f64,
        phase_us: u64,
        cycles: usize,
        seed: u64,
    ) -> Trace {
        let mut phases = Vec::with_capacity(cycles * 2 + 1);
        for _ in 0..cycles {
            phases.push(LoadPhase {
                rate: quiet_rate,
                dur_us: phase_us,
            });
            phases.push(LoadPhase {
                rate: burst_rate,
                dur_us: phase_us,
            });
        }
        phases.push(LoadPhase {
            rate: quiet_rate,
            dur_us: phase_us,
        });
        Self::phased(keys, &phases, seed)
    }

    /// Trace duration (arrival of the last event), µs.
    pub fn span_us(&self) -> u64 {
        self.events.last().map(|e| e.t_us).unwrap_or(0)
    }

    /// Offered load in requests/second (0 for immediate traces).
    pub fn offered_rps(&self) -> f64 {
        let span = self.span_us();
        if span == 0 {
            0.0
        } else {
            self.events.len() as f64 / (span as f64 / 1e6)
        }
    }

    // ---- JSON round-trip --------------------------------------------------

    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                Json::obj()
                    .set("t_us", e.t_us)
                    .set("kernel", e.key.kernel.label())
                    .set("src", vec![e.key.src.0 as u64, e.key.src.1 as u64])
                    .set("scale", e.key.scale as u64)
                    .set("seed", e.seed)
            })
            .collect();
        Json::obj().set("version", 1u64).set("events", events)
    }

    pub fn from_json(j: &Json) -> Result<Trace> {
        let events = j
            .get("events")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace missing 'events'"))?;
        let parsed = events
            .iter()
            .map(|e| -> Result<TraceEvent> {
                let kernel_s = e
                    .get("kernel")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("event missing kernel"))?;
                let kernel = Interpolator::parse(kernel_s)
                    .ok_or_else(|| anyhow!("unknown kernel '{kernel_s}'"))?;
                let src = e
                    .get("src")
                    .and_then(Json::as_arr)
                    .filter(|a| a.len() == 2)
                    .ok_or_else(|| anyhow!("event missing src pair"))?;
                Ok(TraceEvent {
                    t_us: e
                        .get("t_us")
                        .and_then(Json::as_u64)
                        .ok_or_else(|| anyhow!("event missing t_us"))?,
                    key: RequestKey {
                        kernel,
                        src: (
                            src[0].as_u64().ok_or_else(|| anyhow!("bad src"))? as u32,
                            src[1].as_u64().ok_or_else(|| anyhow!("bad src"))? as u32,
                        ),
                        scale: e
                            .get("scale")
                            .and_then(Json::as_u64)
                            .ok_or_else(|| anyhow!("event missing scale"))?
                            as u32,
                    },
                    seed: e.get("seed").and_then(Json::as_u64).unwrap_or(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Trace { events: parsed })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow!("{e}"))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys() -> Vec<RequestKey> {
        vec![
            RequestKey {
                kernel: Interpolator::Bilinear,
                src: (64, 64),
                scale: 2,
            },
            RequestKey {
                kernel: Interpolator::Nearest,
                src: (64, 64),
                scale: 2,
            },
        ]
    }

    #[test]
    fn deterministic_generation() {
        let a = Trace::generate(&keys(), 100, Arrival::Poisson { rate: 500.0 }, 1);
        let b = Trace::generate(&keys(), 100, Arrival::Poisson { rate: 500.0 }, 1);
        assert_eq!(a, b);
        let c = Trace::generate(&keys(), 100, Arrival::Poisson { rate: 500.0 }, 2);
        assert_ne!(a, c);
    }

    #[test]
    fn arrivals_monotone_and_rate_plausible() {
        let t = Trace::generate(&keys(), 2000, Arrival::Poisson { rate: 1000.0 }, 7);
        for w in t.events.windows(2) {
            assert!(w[0].t_us <= w[1].t_us);
        }
        let rps = t.offered_rps();
        assert!((700.0..1400.0).contains(&rps), "offered {rps}");
    }

    #[test]
    fn uniform_spacing_exact() {
        let t = Trace::generate(&keys(), 10, Arrival::Uniform { rate: 1000.0 }, 3);
        for (i, e) in t.events.iter().enumerate() {
            assert_eq!(e.t_us, 1000 * (i as u64 + 1));
        }
    }

    #[test]
    fn bursty_groups() {
        let t = Trace::generate(&keys(), 30, Arrival::Bursty { rate: 100.0, burst: 3 }, 5);
        // events come in triplets sharing a timestamp
        for chunk in t.events.chunks(3) {
            assert!(chunk.iter().all(|e| e.t_us == chunk[0].t_us));
        }
    }

    #[test]
    fn phased_rates_track_their_phases() {
        let phases = [
            LoadPhase {
                rate: 100.0,
                dur_us: 1_000_000,
            },
            LoadPhase {
                rate: 0.0,
                dur_us: 500_000,
            },
            LoadPhase {
                rate: 2000.0,
                dur_us: 1_000_000,
            },
        ];
        let t = Trace::phased(&keys(), &phases, 9);
        assert_eq!(t, Trace::phased(&keys(), &phases, 9), "deterministic");
        for w in t.events.windows(2) {
            assert!(w[0].t_us <= w[1].t_us, "one continuous clock");
        }
        let in_window = |lo: u64, hi: u64| {
            t.events
                .iter()
                .filter(|e| e.t_us >= lo && e.t_us < hi)
                .count()
        };
        let quiet = in_window(0, 1_000_000);
        let gap = in_window(1_000_000, 1_500_000);
        let burst = in_window(1_500_000, 2_500_000);
        assert!((50..200).contains(&quiet), "quiet phase ~100 rps: {quiet}");
        assert_eq!(gap, 0, "a zero-rate phase is silent");
        assert!(
            (1400..2800).contains(&burst),
            "burst phase ~2000 rps: {burst}"
        );
        assert!(t.span_us() < 2_500_000, "no event past the last phase");
    }

    #[test]
    fn diurnal_alternates_quiet_and_burst() {
        let t = Trace::diurnal(&keys(), 50.0, 1500.0, 400_000, 2, 4);
        // Phases: quiet burst quiet burst quiet, 400ms each.
        let in_phase = |i: u64| {
            t.events
                .iter()
                .filter(|e| e.t_us >= i * 400_000 && e.t_us < (i + 1) * 400_000)
                .count()
        };
        for burst_phase in [1u64, 3] {
            assert!(
                in_phase(burst_phase) > 4 * in_phase(burst_phase - 1).max(1),
                "burst phase {burst_phase} must dwarf its quiet predecessor"
            );
        }
        assert!(
            t.span_us() < 5 * 400_000,
            "trailing quiet phase bounds the trace"
        );
    }

    #[test]
    fn immediate_all_zero() {
        let t = Trace::generate(&keys(), 5, Arrival::Immediate, 1);
        assert!(t.events.iter().all(|e| e.t_us == 0));
        assert_eq!(t.offered_rps(), 0.0);
    }

    #[test]
    fn json_round_trip() {
        let t = Trace::generate(&keys(), 50, Arrival::Poisson { rate: 200.0 }, 11);
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn file_round_trip() {
        let t = Trace::generate(&keys(), 20, Arrival::Uniform { rate: 50.0 }, 2);
        let path = std::env::temp_dir().join("tilekit_trace_test.json");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(t, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_malformed() {
        assert!(Trace::from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = r#"{"events": [{"t_us": 1, "kernel": "sinc", "src": [2,2], "scale": 2}]}"#;
        assert!(Trace::from_json(&Json::parse(bad).unwrap()).is_err());
    }
}
