//! Workload generation and trace replay for the serving system.
//!
//! The closed-loop driver in `examples/serving_e2e.rs` saturates the
//! queue, which measures throughput but makes latency percentiles
//! queue-dominated. This module provides **open-loop** load: requests
//! arrive on a schedule (Poisson / uniform / bursty), so latency
//! reflects the system under a target load — the methodology serving
//! papers use.
//!
//! Traces are JSON (via [`crate::codec::json`]) and can be saved,
//! loaded, and replayed bit-identically.

pub mod replay;
pub mod trace;

pub use replay::{replay, ReplayOutcome};
pub use trace::{Arrival, LoadPhase, Trace, TraceEvent};
