//! Open-loop trace replay against a running [`Fleet`].
//!
//! The replayer sleeps until each event's timestamp, submits without
//! blocking (backpressure rejections are *recorded*, not retried — an
//! open-loop driver must not let the system push back on the load; run
//! the service with the `RejectWhenFull` admission policy), and a
//! collector thread gathers completions. The outcome separates offered
//! vs achieved load, which is what a serving evaluation needs.

use super::trace::Trace;
use crate::coordinator::{Fleet, Request, SubmitError, Ticket};
use crate::image::generate;
use crate::metrics::Histogram;
use std::time::{Duration, Instant};

/// Result of replaying a trace.
#[derive(Debug)]
pub struct ReplayOutcome {
    pub offered: usize,
    pub completed: usize,
    pub failed: usize,
    pub rejected: usize,
    /// End-to-end latency of completed requests (µs), measured by the
    /// replayer from intended arrival to reply.
    pub latency: Histogram,
    /// Wall time of the whole replay.
    pub wall: Duration,
    /// Max lag between intended and actual submit time (µs) — sanity
    /// check that the driver kept up with the trace.
    pub max_submit_lag_us: u64,
}

impl ReplayOutcome {
    pub fn achieved_rps(&self) -> f64 {
        self.completed as f64 / self.wall.as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "offered={} completed={} failed={} rejected={} achieved={:.0} rps | latency {}",
            self.offered,
            self.completed,
            self.failed,
            self.rejected,
            self.achieved_rps(),
            self.latency.summary()
        )
    }
}

/// Replay `trace` against `svc`. Blocks until every submitted request
/// has resolved.
pub fn replay(svc: &Fleet, trace: &Trace) -> ReplayOutcome {
    // Pre-generate every input OUTSIDE the timed loop: synthesizing a
    // 128x128 test scene costs milliseconds, which would otherwise make
    // the driver lag the trace and corrupt the latency measurement.
    let images: Vec<_> = trace
        .events
        .iter()
        .map(|ev| generate::test_scene(ev.key.src.1 as usize, ev.key.src.0 as usize, ev.seed))
        .collect();

    // Completions are gathered CONCURRENTLY with submission by a
    // collector thread — recording latency in a post-hoc loop would
    // timestamp early requests at the end of the trace.
    let latency = std::sync::Arc::new(Histogram::new());
    let (done_tx, done_rx) = std::sync::mpsc::channel::<(Instant, Ticket)>();
    let collector = {
        let latency = std::sync::Arc::clone(&latency);
        std::thread::spawn(move || {
            let mut completed = 0usize;
            let mut failed = 0usize;
            // Tickets arrive in submit order; try_wait polling keeps
            // the recording close to actual completion even when an
            // earlier ticket is still in flight.
            let mut inflight: Vec<(Instant, Ticket)> = Vec::new();
            let mut open = true;
            while open || !inflight.is_empty() {
                if open {
                    match done_rx.recv_timeout(Duration::from_micros(200)) {
                        Ok(item) => inflight.push(item),
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => open = false,
                    }
                    // Drain whatever else is queued without blocking.
                    while let Ok(item) = done_rx.try_recv() {
                        inflight.push(item);
                    }
                }
                inflight.retain(|(due, ticket)| match ticket.try_wait() {
                    Ok(None) => true, // still pending
                    Ok(Some(_)) => {
                        completed += 1;
                        latency.record(due.elapsed());
                        false
                    }
                    Err(_) => {
                        failed += 1;
                        false
                    }
                });
                if !open && !inflight.is_empty() {
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
            (completed, failed)
        })
    };

    let start = Instant::now();
    let mut rejected = 0usize;
    let mut max_lag = 0u64;
    for (ev, img) in trace.events.iter().zip(images) {
        let due = start + Duration::from_micros(ev.t_us);
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        } else {
            max_lag = max_lag.max((now - due).as_micros() as u64);
        }
        match svc.submit(Request::new(ev.key.kernel, img, ev.key.scale)) {
            Ok(ticket) => {
                let _ = done_tx.send((due, ticket));
            }
            Err(SubmitError::Saturated)
            | Err(SubmitError::Unsupported)
            | Err(SubmitError::DeadlineExceeded)
            | Err(SubmitError::Infeasible) => rejected += 1,
            Err(SubmitError::ShuttingDown) => break,
        }
    }
    drop(done_tx);
    let (completed, failed) = collector.join().expect("collector");

    ReplayOutcome {
        offered: trace.events.len(),
        completed,
        failed,
        rejected,
        latency: std::sync::Arc::try_unwrap(latency).expect("sole owner"),
        wall: start.elapsed(),
        max_submit_lag_us: max_lag,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::coordinator::{FleetBuilder, RejectWhenFull, RequestKey, TilePolicy};
    use crate::runtime::{Manifest, MockEngine};
    use crate::workload::trace::Arrival;
    use std::sync::Arc;

    fn setup(queue_cap: usize, delay_ms: u64) -> (Fleet, Vec<RequestKey>) {
        let manifest = Manifest::parse(
            r#"{
              "version": 1,
              "artifacts": [
                {"name": "bl", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [4, 32], "path": "x"}
              ]
            }"#,
            std::path::PathBuf::from("."),
        )
        .unwrap();
        let backend: Arc<dyn crate::runtime::ResizeBackend> = if delay_ms > 0 {
            Arc::new(MockEngine::with_delay(Duration::from_millis(delay_ms)))
        } else {
            Arc::new(MockEngine::new())
        };
        let cfg = ServingConfig {
            workers: 2,
            batch_max: Some(4),
            batch_deadline_ms: 0.5,
            queue_cap,
            ..ServingConfig::default()
        };
        let svc = FleetBuilder::new(&cfg, &manifest)
            .backend(backend, TilePolicy::PortableFallback)
            .admission(RejectWhenFull)
            .build()
            .unwrap();
        let keys = svc.keys();
        (svc, keys)
    }

    #[test]
    fn replay_completes_everything_at_modest_load() {
        let (svc, keys) = setup(256, 0);
        let trace = Trace::generate(&keys, 60, Arrival::Uniform { rate: 5000.0 }, 1);
        let out = replay(&svc, &trace);
        assert_eq!(out.completed, 60);
        assert_eq!(out.failed + out.rejected, 0);
        assert!(out.latency.count() == 60);
        svc.shutdown();
    }

    #[test]
    fn overload_gets_rejected_not_stuck() {
        // 2ms per batch, queue of 4, offered way over capacity: the
        // open-loop driver must record rejections and still terminate.
        let (svc, keys) = setup(4, 2);
        let trace = Trace::generate(&keys, 80, Arrival::Immediate, 2);
        let out = replay(&svc, &trace);
        assert_eq!(out.offered, 80);
        assert!(out.rejected > 0, "backpressure should reject under overload");
        assert_eq!(out.completed + out.failed + out.rejected, 80);
        svc.shutdown();
    }

    #[test]
    fn outcome_summary_renders() {
        let (svc, keys) = setup(64, 0);
        let trace = Trace::generate(&keys, 5, Arrival::Immediate, 3);
        let out = replay(&svc, &trace);
        assert!(out.summary().contains("completed=5"));
        svc.shutdown();
    }
}
