//! The whole-GPU timing engine.
//!
//! For one launch on one device:
//!
//! 1. Occupancy fixes `R`, the blocks resident per SM
//!    ([`crate::tiling::occupancy`]).
//! 2. Per-block compute-issue cycles, memory transactions, and row
//!    penalties come from [`super::cost`] and [`super::memory`].
//! 3. An SM executes its resident set as a *round*: the round's cycles
//!    are `max(compute-issue, memory-service) + exposed-latency`, where
//!    exposed latency shrinks as resident warps grow (latency hiding —
//!    this is where occupancy buys time, and where the §III.B cliff turns
//!    into milliseconds).
//! 4. Blocks are dispatched greedily to the earliest-free SM (the
//!    hardware's dynamic block scheduler). Per-SM speed factors support
//!    the §IV.C straggler experiment: one slow SM dilutes with SM count.
//!
//! Cycles are shader-clock cycles; `ms` divides by the device clock.

use super::cost::KernelCost;
use super::launch::Launch;
use super::memory::{block_traffic, BlockTraffic};
use crate::device::DeviceDescriptor;
use crate::tiling::occupancy::{occupancy, Occupancy};

/// Resident warps needed to fully hide one DRAM access round-trip: at
/// ~500-cycle latency and ~25 issue-cycles between dependent loads, ~20
/// warps hide everything (cc1.x figures; the guide's rule of thumb is
/// "hundreds of threads per SM").
const CYCLES_HIDDEN_PER_WARP: f64 = 25.0;

/// Per-SM degradation for the §IV.C extreme experiment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Straggler {
    /// Index of the degraded SM.
    pub sm: u32,
    /// Speed multiplier (< 1.0 = slower). The paper's example uses 0.5
    /// ("one tiling dimension t2 leads to the half efficiency").
    pub speed: f64,
}

/// Cycle breakdown of one simulated launch (per-SM-round aggregates
/// summed over the whole grid, before dispatch).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SimBreakdown {
    /// Total compute-issue cycles across all blocks.
    pub compute_cycles: f64,
    /// Total memory-service cycles (transactions at device bandwidth).
    pub memory_cycles: f64,
    /// Total DRAM row-switch penalty cycles.
    pub row_penalty_cycles: f64,
    /// Total exposed (unhidden) latency cycles.
    pub exposed_latency_cycles: f64,
}

/// Result of simulating one launch.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    /// End-to-end kernel time in shader-clock cycles.
    pub cycles: f64,
    /// End-to-end kernel time in milliseconds.
    pub ms: f64,
    /// Residency outcome used.
    pub occupancy: Occupancy,
    /// Blocks in the grid.
    pub total_blocks: u64,
    /// SM rounds executed (sum over SMs).
    pub rounds: u64,
    /// Per-block memory traffic.
    pub traffic: BlockTraffic,
    /// Aggregate cost attribution.
    pub breakdown: SimBreakdown,
}

impl SimReport {
    /// Throughput in output megapixels per second.
    pub fn mpix_per_s(&self, launch: &Launch) -> f64 {
        launch.out_pixels() as f64 / (self.ms / 1000.0) / 1e6
    }
}

/// Memory-service cycles for one transaction on `dev`, per SM, when
/// `active_sms` SMs are concurrently issuing.
///
/// The chip moves `mem_bandwidth` bytes/s; an SM's fair share is
/// 1/active of it (idle SMs don't consume bandwidth — this matters in
/// the grid's tail wave). A transaction occupies the memory system for
/// `segment_bytes / share` seconds, converted to shader cycles.
fn cycles_per_transaction(dev: &DeviceDescriptor, active_sms: u32) -> f64 {
    let seg_bytes = 64.0; // accounting granularity used by the tx counters
    let bw_bytes_per_s = dev.mem_bandwidth_gib() * (1u64 << 30) as f64;
    let share = bw_bytes_per_s / active_sms.max(1) as f64;
    let secs = seg_bytes / share;
    secs * dev.sp_clock_mhz * 1e6
}

/// Simulate `launch` on `dev`. `straggler` optionally degrades one SM.
pub fn simulate(launch: &Launch, dev: &DeviceDescriptor, straggler: Option<Straggler>) -> SimReport {
    let cost = KernelCost::of(launch.kernel);
    let occ = occupancy(launch.tile, &cost.resources, &dev.cc);
    simulate_parts(
        launch,
        dev,
        straggler,
        occ,
        launch.total_blocks(),
        block_traffic(launch, dev),
        cost.instrs_per_thread as f64,
        cost.loads_per_thread as f64,
    )
}

/// The generalized engine core, shared by [`simulate`] (the paper's
/// block-only configuration) and [`super::config::simulate_config`]
/// (thread tiling / shared-memory / unroll / prefetch extensions).
/// `instrs_per_thread` and `latency_load_groups` are the config-adjusted
/// compute and dependent-gather-round counts.
#[allow(clippy::too_many_arguments)]
pub(crate) fn simulate_parts(
    launch: &Launch,
    dev: &DeviceDescriptor,
    straggler: Option<Straggler>,
    occ: Occupancy,
    total_blocks: u64,
    traffic: BlockTraffic,
    instrs_per_thread: f64,
    latency_load_groups: f64,
) -> SimReport {
    if occ.blocks_per_sm == 0 {
        // Unlaunchable tile: report infinite time so sweeps rank it last.
        return SimReport {
            cycles: f64::INFINITY,
            ms: f64::INFINITY,
            occupancy: occ,
            total_blocks,
            rounds: 0,
            traffic: BlockTraffic {
                load_transactions: 0,
                store_transactions: 0,
                bytes: 0,
                row_crossings: 0,
                row_penalty_cycles: 0.0,
            },
            breakdown: SimBreakdown::default(),
        };
    }

    let warps_per_block = launch.tile.warps(dev.cc.warp_size) as f64;
    let r = occ.blocks_per_sm as f64;

    // ---- one SM round: R resident blocks run to completion ------------
    // Parameterized by how many SMs are concurrently active (bandwidth
    // is shared only among active SMs — the grid's tail wave runs with
    // fewer).
    let tx = (traffic.load_transactions + traffic.store_transactions) as f64;
    // Compute side: all resident warps share the SP issue pipeline.
    let cycles_per_warp_instr = 32.0 / dev.cc.sps_per_sm as f64;
    let round_compute = r * warps_per_block * instrs_per_thread * cycles_per_warp_instr;
    // Latency exposure: each thread performs `loads` dependent gather
    // rounds; resident warps hide CYCLES_HIDDEN_PER_WARP each.
    let hidden = (occ.warps_per_sm as f64 * CYCLES_HIDDEN_PER_WARP / dev.mem_latency_cycles)
        .clamp(0.0, 1.0);
    let round_latency = latency_load_groups * dev.mem_latency_cycles * (1.0 - hidden);
    // Row-switch chain (the paper's "pointer movement between rows",
    // §IV.B / Fig. 4): within one block the row switches are a *serial
    // dependency chain* — each crossing stalls that block's access
    // stream. The R resident blocks' chains overlap each other, so one
    // chain's length is exposed per round. Taller tiles have longer
    // chains AND fewer blocks per grid to amortize them, which is
    // exactly why the paper finds 32×4 beating taller tiles once the
    // output pitch (scale) makes crossings expensive.
    let round_rowchain = traffic.row_penalty_cycles;
    let round_cycles = |active_sms: u32| -> f64 {
        let round_memory = r * tx * cycles_per_transaction(dev, active_sms);
        round_compute.max(round_memory) + round_latency + round_rowchain
    };

    let total_rounds = total_blocks.div_ceil(occ.blocks_per_sm as u64);
    let cycles = match straggler {
        None => {
            // ---- uniform wave schedule --------------------------------
            // full waves use every SM; the tail wave's fewer active SMs
            // each get a larger bandwidth share.
            let sms = dev.sm_count as u64;
            let full_waves = total_rounds / sms;
            let tail_rounds = total_rounds % sms;
            let mut c = full_waves as f64 * round_cycles(dev.sm_count);
            if tail_rounds > 0 {
                c += round_cycles(tail_rounds as u32);
            }
            c
        }
        Some(s) => {
            // ---- greedy dispatch with a degraded SM --------------------
            // Rounds are identical, so dispatch reduces to earliest-free
            // assignment over SM speeds; the straggler gets
            // proportionally fewer rounds (the hardware feeds blocks to
            // whichever SM frees up first).
            let mut speeds = vec![1.0f64; dev.sm_count as usize];
            if (s.sm as usize) < speeds.len() {
                speeds[s.sm as usize] = s.speed.max(1e-6);
            }
            dispatch_rounds(total_rounds, round_cycles(dev.sm_count), &speeds)
        }
    };

    let ms = cycles / (dev.sp_clock_mhz * 1e3);
    let b = total_blocks as f64;
    SimReport {
        cycles,
        ms,
        occupancy: occ,
        total_blocks,
        rounds: total_rounds,
        traffic,
        breakdown: SimBreakdown {
            compute_cycles: b * warps_per_block * instrs_per_thread * cycles_per_warp_instr,
            memory_cycles: b * tx * cycles_per_transaction(dev, dev.sm_count),
            row_penalty_cycles: total_rounds as f64 * round_rowchain,
            exposed_latency_cycles: total_rounds as f64 * round_latency,
        },
    }
}

/// Greedy earliest-free dispatch of `n` identical rounds of `round_cycles`
/// over SMs with the given speed factors. Returns the makespan in cycles.
///
/// With uniform speeds this is exactly `ceil(n / sms) * round_cycles`;
/// with a straggler it reproduces the throughput-dilution arithmetic of
/// the paper's §IV.C. O(n log sms) via a binary heap, but the uniform
/// case is computed in O(1) — the Fig. 3 sweep calls this thousands of
/// times.
fn dispatch_rounds(n: u64, round_cycles: f64, speeds: &[f64]) -> f64 {
    let sms = speeds.len() as u64;
    if n == 0 {
        return 0.0;
    }
    let uniform = speeds.iter().all(|&s| (s - speeds[0]).abs() < 1e-12);
    if uniform {
        let per_sm = n.div_ceil(sms);
        return per_sm as f64 * round_cycles / speeds[0];
    }
    // Binary heap of (next-free-time, sm). BinaryHeap is a max-heap, so
    // store negated times via Reverse on an ordered wrapper.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[derive(PartialEq)]
    struct T(f64);
    impl Eq for T {}
    impl PartialOrd for T {
        fn partial_cmp(&self, o: &T) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for T {
        fn cmp(&self, o: &T) -> std::cmp::Ordering {
            self.0.partial_cmp(&o.0).unwrap_or(std::cmp::Ordering::Equal)
        }
    }

    let mut heap: BinaryHeap<Reverse<(T, usize)>> = speeds
        .iter()
        .enumerate()
        .map(|(i, _)| Reverse((T(0.0), i)))
        .collect();
    let mut makespan = 0.0f64;
    for _ in 0..n {
        let Reverse((T(free), i)) = heap.pop().expect("non-empty heap");
        let done = free + round_cycles / speeds[i];
        makespan = makespan.max(done);
        heap.push(Reverse((T(done), i)));
    }
    makespan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{find_device, paper_pair};
    use crate::image::Interpolator;
    use crate::tiling::TileDim;

    fn launch(tile: TileDim, scale: u32) -> Launch {
        Launch::paper(Interpolator::Bilinear, tile, scale)
    }

    #[test]
    fn gtx260_faster_than_8800gts_everywhere() {
        // "It is absolutely clear that, the GTX 260 can provide better
        // performance than the GeForce 8800 GTS."
        let (gtx, gts) = paper_pair();
        for scale in [2, 4, 6, 8, 10] {
            for tile in crate::tiling::paper_sweep_tiles() {
                let l = launch(tile, scale);
                let a = simulate(&l, &gtx, None);
                let b = simulate(&l, &gts, None);
                if a.ms.is_finite() && b.ms.is_finite() {
                    assert!(
                        a.ms < b.ms,
                        "tile {tile} scale {scale}: gtx {} vs gts {}",
                        a.ms,
                        b.ms
                    );
                }
            }
        }
    }

    #[test]
    fn unlaunchable_tile_is_infinite() {
        let gtx = find_device("gtx260").unwrap();
        let r = simulate(&launch(TileDim::new(32, 32), 2), &gtx, None);
        assert!(r.ms.is_infinite());
    }

    #[test]
    fn more_sms_never_slower() {
        let gtx = find_device("gtx260").unwrap();
        let mut small = gtx.clone();
        small.sm_count = 6;
        for tile in [TileDim::new(32, 4), TileDim::new(8, 8)] {
            let l = launch(tile, 4);
            let big = simulate(&l, &gtx, None);
            let sm = simulate(&l, &small, None);
            assert!(big.ms <= sm.ms + 1e-9, "{tile}: {} vs {}", big.ms, sm.ms);
        }
    }

    #[test]
    fn straggler_dilutes_with_sm_count_as_paper_4c() {
        // §IV.C: a half-speed SM costs G1 (2 SMs) ~1/4 of total efficiency
        // but G2 (20 SMs) only ~1/40 — a 10× dilution.
        let g1 = find_device("g1").unwrap();
        let g2 = find_device("g2").unwrap();
        let l = launch(TileDim::new(32, 4), 4);
        let loss = |dev: &crate::device::DeviceDescriptor| {
            let clean = simulate(&l, dev, None).ms;
            let hurt = simulate(
                &l,
                dev,
                Some(Straggler {
                    sm: 0,
                    speed: 0.5,
                }),
            )
            .ms;
            (hurt - clean) / hurt // efficiency lost
        };
        let l1 = loss(&g1);
        let l2 = loss(&g2);
        // theoretical: 1 - (N-0.5)/N ⇒ 0.25 for N=2, 0.025 for N=20
        assert!((l1 - 0.25).abs() < 0.04, "G1 loss {l1}");
        assert!((l2 - 0.025).abs() < 0.01, "G2 loss {l2}");
        let ratio = l1 / l2;
        assert!((8.0..12.5).contains(&ratio), "dilution ratio {ratio}");
    }

    #[test]
    fn occupancy_cliff_shows_up_in_time() {
        // 32x16 on the 8800 GTS runs at 66% occupancy; 32x4 at 100%.
        // The simulator must rank 32x4 no worse.
        let gts = find_device("8800gts").unwrap();
        let t_32x16 = simulate(&launch(TileDim::new(32, 16), 4), &gts, None).ms;
        let t_32x4 = simulate(&launch(TileDim::new(32, 4), 4), &gts, None).ms;
        assert!(t_32x4 <= t_32x16, "{t_32x4} vs {t_32x16}");
    }

    #[test]
    fn wide_beats_tall_at_large_scale() {
        // Fig. 4 consequence at the grid level: 8x4 ≤ 4x8 at scale 8.
        let (gtx, gts) = paper_pair();
        for dev in [&gtx, &gts] {
            let wide = simulate(&launch(TileDim::new(8, 4), 8), dev, None).ms;
            let tall = simulate(&launch(TileDim::new(4, 8), 8), dev, None).ms;
            assert!(wide <= tall, "{}: wide {} tall {}", dev.id, wide, tall);
        }
    }

    #[test]
    fn dispatch_uniform_matches_closed_form() {
        let speeds = [1.0; 24];
        let t = dispatch_rounds(100, 10.0, &speeds);
        assert_eq!(t, (100f64 / 24.0).ceil() * 10.0);
        assert_eq!(dispatch_rounds(0, 10.0, &speeds), 0.0);
    }

    #[test]
    fn dispatch_straggler_matches_throughput_model() {
        // 2 SMs, one at half speed, many rounds: makespan ≈ n/(1.5) * t.
        let speeds = [1.0, 0.5];
        let n = 3000u64;
        let t = dispatch_rounds(n, 1.0, &speeds);
        let ideal = n as f64 / 1.5;
        assert!((t - ideal).abs() / ideal < 0.01, "t={t} ideal={ideal}");
    }

    #[test]
    fn report_throughput_sane() {
        let gtx = find_device("gtx260").unwrap();
        let l = launch(TileDim::new(32, 4), 2);
        let r = simulate(&l, &gtx, None);
        let mp = r.mpix_per_s(&l);
        assert!(mp > 1.0, "suspiciously slow: {mp} Mpix/s");
        assert!(mp < 1e6, "suspiciously fast: {mp} Mpix/s");
    }

    #[test]
    fn ms_positive_finite_for_all_valid_tiles() {
        let (gtx, gts) = paper_pair();
        for dev in [&gtx, &gts] {
            for tile in crate::tiling::paper_sweep_tiles() {
                let r = simulate(&launch(tile, 6), dev, None);
                assert!(r.ms > 0.0);
                assert!(r.ms.is_finite(), "{tile} on {}", dev.id);
            }
        }
    }
}
