//! Generalized kernel configurations: block tiling × thread tiling ×
//! the paper's other optimization categories ("Categories of
//! optimizations can be summarized as tiling, using shared memory,
//! unrolling and prefetching", §I).
//!
//! [`simulate_config`] extends the block-only [`super::engine::simulate`]
//! to the full design space so the ablation benches can test the
//! paper's central thesis — that tiling "is always the decisive factor"
//! — against the other knobs.
//!
//! Modeling of the extra knobs:
//!
//! * **Thread tiling** (`Tiling::per_thread`) — fewer blocks, more work
//!   and registers per thread ([`crate::tiling::thread_tile`]).
//! * **Shared-memory staging** (`smem_staging`) — the block
//!   cooperatively loads its source window once (coalesced row
//!   segments) instead of issuing per-thread gathers; costs smem bytes
//!   (occupancy pressure) + staging instructions + a barrier, and makes
//!   the gather traffic footprint-proportional even on cc1.0 (this was
//!   THE standard fix for strict-coalescing devices).
//! * **Unrolling** (`unrolled`) — removes per-pixel loop overhead,
//!   +4 registers.
//! * **Prefetching** (`prefetch`) — overlaps the next gather with
//!   compute: halves exposed latency, +2 registers.

use super::cost::KernelCost;
use super::engine::{SimReport, Straggler};
use super::launch::Launch;
use super::memory::{
    gather_tx_per_group, row_penalty_factor, store_tx_per_group, BlockTraffic,
};
use crate::device::{CoalescingModel, DeviceDescriptor};
use crate::image::Interpolator;
use crate::tiling::occupancy::{occupancy, KernelResources};
use crate::tiling::{ThreadTile, Tiling};

/// A full kernel design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelConfig {
    pub kernel: Interpolator,
    pub tiling: Tiling,
    /// Stage the block's source window in shared memory.
    pub smem_staging: bool,
    /// Fully unroll the per-thread pixel loop.
    pub unrolled: bool,
    /// Software prefetch of the next gather.
    pub prefetch: bool,
}

impl KernelConfig {
    /// The paper's configuration: block tiling only, plain global loads.
    pub fn paper(kernel: Interpolator, block: crate::tiling::TileDim) -> KernelConfig {
        KernelConfig {
            kernel,
            tiling: Tiling::block_only(block),
            smem_staging: false,
            unrolled: false,
            prefetch: false,
        }
    }

    /// Effective per-thread resources after all knobs.
    pub fn resources(&self, launch: &Launch) -> KernelResources {
        let base = KernelCost::of(self.kernel).resources;
        let mut regs = self.tiling.regs_per_thread(base.regs_per_thread);
        if self.unrolled {
            regs += 4;
        }
        if self.prefetch {
            regs += 2;
        }
        let smem = if self.smem_staging {
            self.window_bytes(launch)
        } else {
            0
        };
        KernelResources {
            regs_per_thread: regs,
            smem_per_block: smem,
        }
    }

    /// Source-window bytes a staging block needs: footprint/scale plus a
    /// +2 halo on each axis (bilinear/bicubic taps).
    pub fn window_bytes(&self, launch: &Launch) -> u32 {
        let fp = self.tiling.footprint();
        let cost = KernelCost::of(self.kernel);
        let wy = fp.y / launch.scale + 2;
        let wx = fp.x / launch.scale + 2;
        wy * wx * cost.elem_bytes
    }

    /// Per-thread instruction count after thread tiling / unroll /
    /// staging overheads.
    pub fn instrs_per_thread(&self) -> u32 {
        let base = KernelCost::of(self.kernel).instrs_per_thread;
        let mut n = self.tiling.instrs_per_thread(base, self.unrolled);
        if self.smem_staging {
            n += 6; // cooperative load + barrier + smem addressing
        }
        if self.prefetch {
            n += 2;
        }
        n
    }

    pub fn label(&self) -> String {
        let mut s = self.tiling.label();
        if self.smem_staging {
            s.push_str("+smem");
        }
        if self.unrolled {
            s.push_str("+unroll");
        }
        if self.prefetch {
            s.push_str("+pf");
        }
        s
    }
}

/// Traffic of one block under a full config (generalizes
/// [`super::memory::block_traffic`]).
pub fn config_traffic(cfg: &KernelConfig, launch: &Launch, dev: &DeviceDescriptor) -> BlockTraffic {
    let cost = KernelCost::of(cfg.kernel);
    let block = cfg.tiling.block;
    let fp = cfg.tiling.footprint();
    let model = dev.cc.coalescing;
    let group = match model {
        CoalescingModel::CachedWarp => dev.cc.warp_size,
        _ => dev.cc.warp_size / 2,
    };
    let groups_per_block = block.threads().div_ceil(group) as u64;
    let g = group.min(block.threads());

    // Stores: every owned pixel, issued per thread-tile column piece —
    // a thread tile of tx>1 keeps stores contiguous per thread, so the
    // group still covers g·tx consecutive pixels per row piece.
    let store_tx = groups_per_block
        * store_tx_per_group(model, g, block.x * cfg.tiling.per_thread.x, cost.elem_bytes)
        * (cost.stores_per_thread * cfg.tiling.per_thread.pixels()) as u64;

    let (load_tx, load_bytes) = if cfg.smem_staging {
        // Cooperative window load: contiguous rows of the source window,
        // fully coalesced segments on every cc (this is why smem staging
        // was the standard cc1.0 remedy).
        let wy = (fp.y / launch.scale + 2) as u64;
        let wx_bytes = (fp.x / launch.scale + 2) as u64 * cost.elem_bytes as u64;
        let tx = wy * wx_bytes.div_ceil(64).max(1);
        (tx, wy * wx_bytes)
    } else {
        let per_group = gather_tx_per_group(model, g, block.x, launch.scale, cost.elem_bytes);
        let tx = groups_per_block
            * per_group
            * (cost.loads_per_thread * cfg.tiling.per_thread.pixels()) as u64;
        let wy = (fp.y / launch.scale + 2) as u64;
        let wx_bytes = (fp.x / launch.scale + 2) as u64 * cost.elem_bytes as u64;
        (tx, wy * wx_bytes)
    };

    let store_bytes = fp.threads() as u64 * cost.elem_bytes as u64;

    // Row crossings over the block's *footprint*.
    let store_crossings = fp.y as u64;
    let load_crossings = fp.y as u64 / launch.scale as u64 + 1;
    let store_pen = store_crossings as f64
        * dev.row_switch_cycles
        * row_penalty_factor(launch.out_pitch_bytes() as f64);
    let load_pen = load_crossings as f64
        * dev.row_switch_cycles
        * row_penalty_factor(launch.src_pitch_bytes() as f64);

    BlockTraffic {
        load_transactions: load_tx,
        store_transactions: store_tx,
        bytes: store_bytes + load_bytes,
        row_crossings: store_crossings + load_crossings,
        row_penalty_cycles: store_pen + load_pen,
    }
}

/// Simulate a full kernel configuration. Mirrors
/// [`super::engine::simulate`]'s cost structure with config-adjusted
/// occupancy, instruction counts, traffic, and latency overlap.
pub fn simulate_config(
    cfg: &KernelConfig,
    launch: &Launch,
    dev: &DeviceDescriptor,
    straggler: Option<Straggler>,
) -> SimReport {
    // Normalize the launch's block shape and kernel to the config's
    // FIRST (the engine core derives warps-per-block and costs from
    // them) — the delegate below must see the config's block, not the
    // caller's.
    let launch = Launch {
        kernel: cfg.kernel,
        tile: cfg.tiling.block,
        ..*launch
    };
    // Delegate the block-only, no-knob case to the canonical engine so
    // the two paths can never drift for the paper's experiments.
    if cfg.tiling.per_thread == ThreadTile::ONE
        && !cfg.smem_staging
        && !cfg.unrolled
        && !cfg.prefetch
    {
        return super::engine::simulate(&launch, dev, straggler);
    }
    super::engine::simulate_parts(
        &launch,
        dev,
        straggler,
        occupancy(cfg.tiling.block, &cfg.resources(&launch), &dev.cc),
        cfg.tiling.blocks_for(launch.out_w(), launch.out_h()),
        config_traffic(cfg, &launch, dev),
        cfg.instrs_per_thread() as f64,
        KernelCost::of(cfg.kernel).loads_per_thread as f64
            * cfg.tiling.per_thread.pixels() as f64
            * if cfg.prefetch { 0.5 } else { 1.0 }
            * if cfg.smem_staging { 0.25 } else { 1.0 },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::paper_pair;
    use crate::tiling::TileDim;

    fn launch(scale: u32) -> Launch {
        Launch::paper(Interpolator::Bilinear, TileDim::new(32, 4), scale)
    }

    #[test]
    fn paper_config_delegates_to_engine() {
        let (gtx, _) = paper_pair();
        let cfg = KernelConfig::paper(Interpolator::Bilinear, TileDim::new(32, 4));
        let l = launch(4);
        let a = simulate_config(&cfg, &l, &gtx, None);
        let b = super::super::engine::simulate(&l, &gtx, None);
        assert_eq!(a.ms, b.ms);
    }

    #[test]
    fn smem_staging_rescues_cc10() {
        // The classic remedy: staging turns the 8800 GTS's serialized
        // gathers into coalesced window loads — a large win.
        let (_, gts) = paper_pair();
        let l = launch(4);
        let plain = KernelConfig::paper(Interpolator::Bilinear, TileDim::new(32, 4));
        let staged = KernelConfig {
            smem_staging: true,
            ..plain
        };
        let a = simulate_config(&plain, &l, &gts, None).ms;
        let b = simulate_config(&staged, &l, &gts, None).ms;
        assert!(b < a * 0.5, "staging should win big on cc1.0: {a} vs {b}");
    }

    #[test]
    fn thread_tiling_trades_blocks_for_registers() {
        let (gtx, _) = paper_pair();
        let l = launch(4);
        let cfg = KernelConfig {
            kernel: Interpolator::Bilinear,
            tiling: Tiling {
                block: TileDim::new(32, 4),
                per_thread: ThreadTile::new(2, 2),
            },
            smem_staging: false,
            unrolled: true,
            prefetch: false,
        };
        let r = simulate_config(&cfg, &l, &gtx, None);
        assert!(r.ms.is_finite());
        // 4x fewer blocks
        let base = simulate_config(
            &KernelConfig::paper(Interpolator::Bilinear, TileDim::new(32, 4)),
            &l,
            &gtx,
            None,
        );
        assert_eq!(r.total_blocks * 4, base.total_blocks);
    }

    #[test]
    fn window_bytes_and_resources() {
        let l = launch(4);
        let cfg = KernelConfig {
            smem_staging: true,
            ..KernelConfig::paper(Interpolator::Bilinear, TileDim::new(32, 4))
        };
        // footprint 32x4 at scale 4: window (4/4+2)x(32/4+2)=3x10 f32 = 120B
        assert_eq!(cfg.window_bytes(&l), 3 * 10 * 4);
        let res = cfg.resources(&l);
        assert_eq!(res.smem_per_block, 120);
        assert_eq!(res.regs_per_thread, 10);
    }

    #[test]
    fn unroll_removes_loop_overhead() {
        let t = Tiling {
            block: TileDim::new(32, 4),
            per_thread: ThreadTile::new(2, 1),
        };
        let rolled = KernelConfig {
            kernel: Interpolator::Bilinear,
            tiling: t,
            smem_staging: false,
            unrolled: false,
            prefetch: false,
        };
        let unrolled = KernelConfig {
            unrolled: true,
            ..rolled
        };
        assert!(unrolled.instrs_per_thread() < rolled.instrs_per_thread());
        assert!(unrolled.resources(&launch(4)).regs_per_thread > rolled.resources(&launch(4)).regs_per_thread);
    }

    #[test]
    fn labels() {
        let cfg = KernelConfig {
            kernel: Interpolator::Bilinear,
            tiling: Tiling {
                block: TileDim::new(32, 4),
                per_thread: ThreadTile::new(2, 2),
            },
            smem_staging: true,
            unrolled: true,
            prefetch: true,
        };
        assert_eq!(cfg.label(), "32x4+2x2pt+smem+unroll+pf");
    }
}
