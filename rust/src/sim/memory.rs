//! The global-memory model: per-block transaction counts under the
//! compute capability's coalescing rules, plus the DRAM row-switch
//! penalty that reproduces the paper's Fig. 4.
//!
//! ## Coalescing
//!
//! Threads in a block are linearized row-major (x fastest) and issued in
//! half-warps of 16 (cc1.x) or warps of 32 (cc2.x).
//!
//! * **Stores** (one per thread, to consecutive output pixels):
//!   - cc1.0/1.1 (strict): ONE 64-byte transaction iff the half-warp's 16
//!     addresses are consecutive and aligned — i.e. `tile.x ≥ 16`.
//!     Otherwise the half-warp spans rows and serializes into one
//!     transaction per thread.
//!   - cc1.2/1.3 (segmented): the minimal set of aligned segments
//!     covering the addresses — one per row-piece the half-warp touches.
//!   - cc2.x: as segmented but warp-wide through the L1 (128 B lines).
//! * **Gathers** (bilinear reads 4 neighbours): addresses are *compressed*
//!   by the scale (thread i and i+1 often read the same source pixel), so
//!   they are never one-per-thread sequential:
//!   - strict cc1.0: the half-warp serializes — 16 transactions per
//!     gather. This is the big 8800 GTS handicap.
//!   - segmented cc1.3: the footprint of a half-warp's gather is
//!     `⌈piece_px/scale⌉+1` consecutive source pixels per row-piece — a
//!     couple of 64-byte segments at most.
//!
//! ## Row-switch penalty (Fig. 4)
//!
//! Each time a block's access stream moves to a different image row, the
//! DRAM row buffer / TLB locality is lost. The penalty per crossing grows
//! with the row pitch (wider final images put consecutive rows in
//! different DRAM pages), which is exactly the paper's explanation for
//! why 32×4 beats taller tiles at scales 6–10. We charge
//! `row_switch_cycles × min(pitch/page, 16)` per crossing.

use super::cost::KernelCost;
use super::launch::Launch;
use crate::device::{CoalescingModel, DeviceDescriptor};

/// DRAM page (row-buffer) size the penalty model assumes.
pub const DRAM_PAGE_BYTES: f64 = 2048.0;
/// Cap on the pitch/page penalty multiplier.
pub const ROW_PENALTY_CAP: f64 = 16.0;

/// Memory traffic of ONE block of a launch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockTraffic {
    /// Global-memory transactions for all gathers of the block.
    pub load_transactions: u64,
    /// Global-memory transactions for all stores of the block.
    pub store_transactions: u64,
    /// Bytes moved (loads + stores), for the bandwidth bound.
    pub bytes: u64,
    /// Image-row crossings performed by the block (loads + stores).
    pub row_crossings: u64,
    /// Cycles charged for those crossings on `dev`.
    pub row_penalty_cycles: f64,
}

/// Row-pieces a coalescing group spans: a group of `group` linear threads
/// inside a `tile.x`-wide block touches `ceil(group / tile.x)` distinct
/// rows when `tile.x < group`, else 1.
pub(crate) fn row_pieces(group: u32, tile_x: u32) -> u32 {
    group.div_ceil(tile_x.max(1)).max(1)
}

/// Transactions for one *store* issued by one coalescing group.
pub(crate) fn store_tx_per_group(model: CoalescingModel, group: u32, tile_x: u32, elem_bytes: u32) -> u64 {
    let pieces = row_pieces(group, tile_x) as u64;
    let piece_px = tile_x.min(group) as u64;
    match model {
        CoalescingModel::StrictHalfWarp => {
            // Coalesces only if the whole group is one consecutive run.
            if pieces == 1 {
                1
            } else {
                group as u64 // serialized: one transaction per thread
            }
        }
        CoalescingModel::SegmentedHalfWarp | CoalescingModel::CachedWarp => {
            // Minimal segments: each row-piece is `piece_px * elem_bytes`
            // consecutive bytes → ceil over 64B segments (128B lines on
            // cc2.x behave the same at this granularity).
            let seg = if model == CoalescingModel::CachedWarp {
                128
            } else {
                64
            };
            let bytes = piece_px * elem_bytes as u64;
            pieces * bytes.div_ceil(seg).max(1)
        }
    }
}

/// Transactions for one *gather* (scale-compressed addresses) issued by
/// one coalescing group.
pub(crate) fn gather_tx_per_group(
    model: CoalescingModel,
    group: u32,
    tile_x: u32,
    scale: u32,
    elem_bytes: u32,
) -> u64 {
    let pieces = row_pieces(group, tile_x) as u64;
    let piece_px = tile_x.min(group) as u64;
    // Footprint of one row-piece in source pixels.
    let fp_px = piece_px / scale as u64 + 1;
    match model {
        CoalescingModel::StrictHalfWarp => {
            // Gathers are not thread-sequential under upscaling (threads
            // share neighbours) — strict coalescing fails, the half-warp
            // serializes. scale == 1 keeps addresses sequential per piece.
            if scale == 1 && pieces == 1 {
                1
            } else {
                group as u64
            }
        }
        CoalescingModel::SegmentedHalfWarp | CoalescingModel::CachedWarp => {
            let seg = if model == CoalescingModel::CachedWarp {
                128
            } else {
                64
            };
            let bytes = fp_px * elem_bytes as u64;
            pieces * bytes.div_ceil(seg).max(1)
        }
    }
}

/// Row-switch penalty multiplier for a given pitch.
pub fn row_penalty_factor(pitch_bytes: f64) -> f64 {
    (pitch_bytes / DRAM_PAGE_BYTES).clamp(1.0, ROW_PENALTY_CAP)
}

/// Compute the memory traffic of one block of `launch` on `dev`.
pub fn block_traffic(launch: &Launch, dev: &DeviceDescriptor) -> BlockTraffic {
    let cost = KernelCost::of(launch.kernel);
    let tile = launch.tile;
    let threads = tile.threads() as u64;
    let model = dev.cc.coalescing;
    // Coalescing group size: half-warp on cc1.x, full warp on cc2.x.
    let group = match model {
        CoalescingModel::CachedWarp => dev.cc.warp_size,
        _ => dev.cc.warp_size / 2,
    };
    let groups_per_block = (tile.threads().div_ceil(group)) as u64;

    let store_tx = groups_per_block
        * store_tx_per_group(model, group.min(tile.threads()), tile.x, cost.elem_bytes)
        * cost.stores_per_thread as u64;
    let load_tx = groups_per_block
        * gather_tx_per_group(
            model,
            group.min(tile.threads()),
            tile.x,
            launch.scale,
            cost.elem_bytes,
        )
        * cost.loads_per_thread as u64;

    // Useful bytes: every thread stores one pixel and gathers its taps
    // (tap reuse across threads is served by the same transactions; for
    // the bandwidth bound we count unique-ish traffic: stores exact,
    // loads approximated by the block's source footprint per tap group).
    let store_bytes = threads * cost.elem_bytes as u64;
    let src_fp_w = tile.x as u64 / launch.scale as u64 + 2;
    let src_fp_h = tile.y as u64 / launch.scale as u64 + 2;
    let load_bytes = src_fp_w * src_fp_h * cost.elem_bytes as u64
        * (cost.loads_per_thread as u64).min(4).max(1) / 4 * 4; // footprint read once per tap quad
    let bytes = store_bytes + load_bytes.max(cost.elem_bytes as u64);

    // Row crossings: the block walks tile.y output rows (stores) and
    // ~tile.y/scale + 1 source rows (gathers).
    let store_crossings = tile.y as u64;
    let load_crossings = tile.y as u64 / launch.scale as u64 + 1;
    let row_crossings = store_crossings + load_crossings;

    let store_pen = store_crossings as f64
        * dev.row_switch_cycles
        * row_penalty_factor(launch.out_pitch_bytes() as f64);
    let load_pen = load_crossings as f64
        * dev.row_switch_cycles
        * row_penalty_factor(launch.src_pitch_bytes() as f64);

    BlockTraffic {
        load_transactions: load_tx,
        store_transactions: store_tx,
        bytes,
        row_crossings,
        row_penalty_cycles: store_pen + load_pen,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{find_device, paper_pair};
    use crate::image::Interpolator;
    use crate::tiling::TileDim;

    fn launch(tile: TileDim, scale: u32) -> Launch {
        Launch::paper(Interpolator::Bilinear, tile, scale)
    }

    #[test]
    fn fig4_asymmetry_8x4_beats_4x8() {
        // Fig. 4: same 32 threads, 8x4 crosses 4 output rows, 4x8 crosses
        // 8 — the wide tile must have fewer crossings and a smaller
        // penalty on both devices.
        let (gtx, gts) = paper_pair();
        for dev in [&gtx, &gts] {
            let wide = block_traffic(&launch(TileDim::new(8, 4), 6), dev);
            let tall = block_traffic(&launch(TileDim::new(4, 8), 6), dev);
            assert!(
                wide.row_crossings < tall.row_crossings,
                "{}: wide {} vs tall {}",
                dev.id,
                wide.row_crossings,
                tall.row_crossings
            );
            assert!(wide.row_penalty_cycles < tall.row_penalty_cycles);
        }
    }

    #[test]
    fn row_penalty_grows_with_scale() {
        let gtx = find_device("gtx260").unwrap();
        let t = TileDim::new(16, 8);
        let p2 = block_traffic(&launch(t, 2), &gtx).row_penalty_cycles;
        let p6 = block_traffic(&launch(t, 6), &gtx).row_penalty_cycles;
        let p10 = block_traffic(&launch(t, 10), &gtx).row_penalty_cycles;
        assert!(p2 < p6, "penalty must grow with pitch: {p2} vs {p6}");
        assert!(p6 < p10, "{p6} vs {p10}");
    }

    #[test]
    fn strict_coalescing_serializes_narrow_stores() {
        // tile.x = 8 < 16: a half-warp spans 2 rows → cc1.0 serializes
        // (16 tx), cc1.3 issues 2 segments.
        let gts = find_device("8800gts").unwrap();
        let gtx = find_device("gtx260").unwrap();
        let l = launch(TileDim::new(8, 8), 2);
        let strict = block_traffic(&l, &gts);
        let seg = block_traffic(&l, &gtx);
        // per block: 64 threads = 4 half-warps; strict: 4*16, segmented: 4*2
        assert_eq!(strict.store_transactions, 4 * 16);
        assert_eq!(seg.store_transactions, 4 * 2);
    }

    #[test]
    fn wide_stores_coalesce_on_both() {
        let (gtx, gts) = paper_pair();
        let l = launch(TileDim::new(32, 4), 2);
        // 128 threads = 8 half-warps, tile.x=32 ≥ 16 → 1 tx each on both.
        assert_eq!(block_traffic(&l, &gts).store_transactions, 8);
        assert_eq!(block_traffic(&l, &gtx).store_transactions, 8);
    }

    #[test]
    fn gathers_serialize_on_cc10_but_not_cc13() {
        let (gtx, gts) = paper_pair();
        let l = launch(TileDim::new(32, 4), 4);
        let strict = block_traffic(&l, &gts);
        let seg = block_traffic(&l, &gtx);
        // 8 half-warps × 4 taps: strict = 8×16×4, segmented ≈ 8×1×4
        assert_eq!(strict.load_transactions, 8 * 16 * 4);
        assert_eq!(seg.load_transactions, 8 * 4);
        assert!(strict.load_transactions > 10 * seg.load_transactions);
    }

    #[test]
    fn penalty_factor_caps() {
        assert_eq!(row_penalty_factor(100.0), 1.0);
        assert!((row_penalty_factor(6400.0) - 3.125).abs() < 1e-9);
        assert_eq!(row_penalty_factor(1e9), ROW_PENALTY_CAP);
    }

    #[test]
    fn bytes_positive_and_store_dominated_at_scale() {
        let gtx = find_device("gtx260").unwrap();
        let t = block_traffic(&launch(TileDim::new(16, 16), 8), &gtx);
        assert!(t.bytes >= 256 * 4); // at least the stores
    }

    #[test]
    fn fermi_uses_warp_granularity() {
        let fermi = find_device("fermi").unwrap();
        let l = launch(TileDim::new(32, 4), 2);
        // 128 threads = 4 warps; tile.x=32 = warp-wide piece of 128B → 1 tx
        let t = block_traffic(&l, &fermi);
        assert_eq!(t.store_transactions, 4);
    }
}
