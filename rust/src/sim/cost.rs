//! Per-kernel cost descriptors: how much arithmetic and how many global
//! memory operations one thread of each interpolation kernel performs.
//!
//! The counts come from reading the kernels' inner loops (eqs. (1)–(5) of
//! the paper for bilinear): coordinate math, tap weights, the gathers and
//! the single store. They feed the simulator's compute-issue and
//! memory-traffic terms; absolute values only need to be *proportionally*
//! right across kernels and small enough that memory dominates, matching
//! the memory-bound reality the paper describes.

use crate::image::Interpolator;
use crate::tiling::occupancy::KernelResources;

/// Static cost profile of one interpolation kernel, per thread.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelCost {
    /// Arithmetic/control instructions per thread (SP issue slots).
    pub instrs_per_thread: u32,
    /// Global-memory gathers per thread.
    pub loads_per_thread: u32,
    /// Global-memory stores per thread (always 1: the terminal pixel).
    pub stores_per_thread: u32,
    /// Bytes per element (f32).
    pub elem_bytes: u32,
    /// Occupancy-relevant resources (registers / shared memory).
    pub resources: KernelResources,
}

impl KernelCost {
    /// Cost profile for a kernel.
    pub fn of(kernel: Interpolator) -> KernelCost {
        match kernel {
            // int coords + rounding + 1 tap
            Interpolator::Nearest => KernelCost {
                instrs_per_thread: 14,
                loads_per_thread: 1,
                stores_per_thread: 1,
                elem_bytes: 4,
                resources: KernelResources::NEAREST,
            },
            // eq. (1)-(5): 2 divides, offsets, 3 lerps ≈ 30 slots, 4 taps
            Interpolator::Bilinear => KernelCost {
                instrs_per_thread: 30,
                loads_per_thread: 4,
                stores_per_thread: 1,
                elem_bytes: 4,
                resources: KernelResources::BILINEAR,
            },
            // 16 taps, 8 cubic weights ≈ 90 slots
            Interpolator::Bicubic => KernelCost {
                instrs_per_thread: 90,
                loads_per_thread: 16,
                stores_per_thread: 1,
                elem_bytes: 4,
                resources: KernelResources::BICUBIC,
            },
        }
    }

    /// SP-issue cycles for one warp executing the whole thread body on a
    /// cc with `sps_per_sm` SPs: a 32-lane warp instruction occupies the
    /// SP pipeline for `32 / sps_per_sm` cycles (4 on cc1.x, 1 on cc2.0).
    pub fn warp_issue_cycles(&self, sps_per_sm: u32) -> f64 {
        let cycles_per_warp_instr = 32.0 / sps_per_sm as f64;
        self.instrs_per_thread as f64 * cycles_per_warp_instr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_nearest_lt_bilinear_lt_bicubic() {
        let n = KernelCost::of(Interpolator::Nearest);
        let bl = KernelCost::of(Interpolator::Bilinear);
        let bc = KernelCost::of(Interpolator::Bicubic);
        assert!(n.instrs_per_thread < bl.instrs_per_thread);
        assert!(bl.instrs_per_thread < bc.instrs_per_thread);
        assert!(n.loads_per_thread < bl.loads_per_thread);
        assert!(bl.loads_per_thread < bc.loads_per_thread);
    }

    #[test]
    fn bilinear_is_four_tap() {
        let bl = KernelCost::of(Interpolator::Bilinear);
        assert_eq!(bl.loads_per_thread, 4); // eq. (5): f11,f21,f12,f22
        assert_eq!(bl.stores_per_thread, 1);
    }

    #[test]
    fn warp_issue_cycles_scale_with_sps() {
        let bl = KernelCost::of(Interpolator::Bilinear);
        // cc1.x: 8 SPs → 4 cycles per warp instruction
        assert_eq!(bl.warp_issue_cycles(8), 30.0 * 4.0);
        // Fermi: 32 SPs → 1 cycle
        assert_eq!(bl.warp_issue_cycles(32), 30.0);
    }
}
