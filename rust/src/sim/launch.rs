//! Kernel-launch descriptor: which kernel, which tile, what geometry.

use crate::image::Interpolator;
use crate::tiling::TileDim;

/// One kernel launch: resize `src_w`×`src_h` by integer `scale` using
/// `kernel`, with thread blocks shaped `tile`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Launch {
    pub kernel: Interpolator,
    pub tile: TileDim,
    pub src_w: u32,
    pub src_h: u32,
    pub scale: u32,
}

impl Launch {
    /// The paper's standard workload: 800×800 source.
    pub fn paper(kernel: Interpolator, tile: TileDim, scale: u32) -> Launch {
        Launch {
            kernel,
            tile,
            src_w: 800,
            src_h: 800,
            scale,
        }
    }

    pub fn out_w(&self) -> u32 {
        self.src_w * self.scale
    }

    pub fn out_h(&self) -> u32 {
        self.src_h * self.scale
    }

    /// Output pixels (total threads launched, one per terminal pixel).
    pub fn out_pixels(&self) -> u64 {
        self.out_w() as u64 * self.out_h() as u64
    }

    /// Grid size in blocks.
    pub fn grid(&self) -> (u32, u32) {
        self.tile.grid_for(self.out_w(), self.out_h())
    }

    /// Total blocks in the grid.
    pub fn total_blocks(&self) -> u64 {
        self.tile.blocks_for(self.out_w(), self.out_h())
    }

    /// Output row pitch in bytes (f32 pixels, tight pitch).
    pub fn out_pitch_bytes(&self) -> u64 {
        self.out_w() as u64 * 4
    }

    /// Source row pitch in bytes.
    pub fn src_pitch_bytes(&self) -> u64 {
        self.src_w as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let l = Launch::paper(Interpolator::Bilinear, TileDim::new(32, 4), 2);
        assert_eq!(l.out_w(), 1600);
        assert_eq!(l.out_h(), 1600);
        assert_eq!(l.out_pixels(), 2_560_000);
        assert_eq!(l.grid(), (50, 400));
        assert_eq!(l.total_blocks(), 20_000);
        assert_eq!(l.out_pitch_bytes(), 6400);
    }

    #[test]
    fn block_count_scales_with_scale_squared() {
        let t = TileDim::new(16, 16);
        let b2 = Launch::paper(Interpolator::Bilinear, t, 2).total_blocks();
        let b10 = Launch::paper(Interpolator::Bilinear, t, 10).total_blocks();
        assert_eq!(b10, b2 * 25);
    }
}
