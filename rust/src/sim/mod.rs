//! The GPU timing simulator — the substrate that stands in for the
//! paper's physical GTX 260 / GeForce 8800 GTS testbed.
//!
//! The paper's claims are about *relative timing shapes* induced by three
//! microarchitectural mechanisms, each modeled by a submodule:
//!
//! 1. **Residency / occupancy** (`tiling::occupancy`) — how many blocks of
//!    a given tile shape fit on an SM under the capability limits
//!    (the §III.B 32×16 cliff).
//! 2. **Memory-access geometry** ([`memory`]) — coalescing rules per
//!    compute capability and the DRAM row-switch penalty that grows with
//!    the output image's row pitch (the Fig. 4 4×8-vs-8×4 effect and the
//!    Fig. 3 (c)–(e) jaggedness at large scales).
//! 3. **Block dispatch across SMs** ([`engine`]) — greedy dynamic
//!    dispatch of blocks to free SMs, which dilutes per-SM inefficiency
//!    on many-SM devices (the §IV.C G1/G2 extreme example).
//!
//! [`cost`] carries per-kernel instruction/footprint counts, and
//! [`launch`] describes a kernel launch (tile + output geometry).
//! Cycle counts are converted to milliseconds with the device's shader
//! clock; EXPERIMENTS.md compares *shapes*, never absolute numbers.

pub mod config;
pub mod cost;
pub mod engine;
pub mod launch;
pub mod memory;

pub use config::{simulate_config, KernelConfig};
pub use cost::KernelCost;
pub use engine::{simulate, SimBreakdown, SimReport, Straggler};
pub use launch::Launch;
pub use memory::{block_traffic, BlockTraffic};
