//! Configuration system: a typed view over the TOML subset in
//! [`crate::codec::toml`]. One file configures devices (custom entries
//! merged over the builtin registry), sweep parameters, and the serving
//! coordinator. See `examples/tilekit.toml` (written by `tilekit
//! init-config`) for the full schema.

use crate::codec::toml::{TomlDoc, TomlValue};
use crate::device::{builtin_devices, DeviceDescriptor};
use crate::image::Interpolator;
use crate::net::protocol::{saturating_duration_from_ms, MAX_DURATION_MS};
use crate::tiling::TileDim;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Sweep parameters (`[sweep]`).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepConfig {
    /// Source image size (the paper: 800×800).
    pub src: (u32, u32),
    /// Scales to sweep (the paper: 2, 4, 6, 8, 10).
    pub scales: Vec<u32>,
    /// Devices to sweep (registry ids).
    pub devices: Vec<String>,
    /// Kernel to sweep.
    pub kernel: Interpolator,
    /// Explicit tile list; empty = the paper's power-of-two sweep.
    pub tiles: Vec<TileDim>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            src: (800, 800),
            scales: vec![2, 4, 6, 8, 10],
            devices: vec!["gtx260".into(), "8800gts".into()],
            kernel: Interpolator::Bilinear,
            tiles: Vec::new(),
        }
    }
}

/// Serving parameters (`[serving]`). Per-device settings apply to every
/// fleet member; `devices` names the fleet (empty = one anonymous
/// single-backend member).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    /// Worker threads executing artifacts (per device member).
    pub workers: usize,
    /// Max requests folded into one batch. `None` (the default) derives
    /// each member's cap from its compute capability — see
    /// [`batch_max_for`](ServingConfig::batch_max_for); `Some(n)` pins
    /// every member to `n` (the `--batch-max` override).
    pub batch_max: Option<usize>,
    /// Batching deadline: a partial batch is flushed after this long.
    pub batch_deadline_ms: f64,
    /// Bounded queue capacity per member (backpressure beyond this).
    pub queue_cap: usize,
    /// Artifacts directory.
    pub artifacts_dir: String,
    /// Serving fleet device ids (registry/config ids). Empty = serve a
    /// single anonymous backend.
    pub devices: Vec<String>,
    /// Scheduler picking the device per request: `round-robin`,
    /// `least-loaded`, or `cost-eta`.
    pub scheduler: String,
    /// Admission policy when a member's queue is full: `reject`,
    /// `block`, or `shed-batch`.
    pub admission: String,
    /// Wait budget (ms) for the blocking admission policies.
    pub admission_timeout_ms: f64,
    /// Work-stealing between fleet members: when a member's queue runs
    /// hot, idle members pull compatible pending requests and serve
    /// them through their own tuned tiles.
    pub work_stealing: bool,
    /// Minimum backlog (queued requests) on a member before peers steal
    /// from it.
    pub steal_threshold: usize,
    /// Poll interval (ms) of the tuning-database watcher behind
    /// `tilekit serve --watch-db` (the
    /// [`RetuneDaemon`](crate::coordinator::RetuneDaemon)).
    pub retune_poll_ms: f64,
    /// Sample every Nth submit into the submit-path time-breakdown
    /// histograms (snapshot/schedule/admit phases; see
    /// `ServingStats::submit_breakdown`). `0` disables sampling; the
    /// unsampled submits stay timer-free on the fast path.
    pub breakdown_sample: u64,
    /// Default listen address for `tilekit serve --listen` when the
    /// flag gives no address: `host:port` or `unix:/path.sock`. `None`
    /// keeps `serve` in its in-process demo mode.
    pub listen: Option<String>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            workers: 2,
            batch_max: None,
            batch_deadline_ms: 2.0,
            queue_cap: 256,
            artifacts_dir: "artifacts".into(),
            devices: Vec::new(),
            scheduler: "round-robin".into(),
            admission: "reject".into(),
            admission_timeout_ms: 5000.0,
            work_stealing: true,
            steal_threshold: 4,
            retune_poll_ms: 200.0,
            breakdown_sample: 16,
            listen: None,
        }
    }
}

impl ServingConfig {
    /// The dynamic-batch cap for one fleet member: the explicit
    /// `batch_max` override when set, else derived from the member's
    /// compute capability — an architecture with more resident threads
    /// per SM amortizes launch overhead over bigger batches, so a
    /// Fermi-class (cc2.x) part batches 16, a cc1.2/1.3 part 8, and a
    /// cc1.0/1.1 part 4. Members with no device identity use the
    /// classic single-backend default
    /// ([`ANON_BATCH_MAX`](crate::coordinator::ANON_BATCH_MAX)).
    ///
    /// Derived caps are clamped to `queue_cap` so the size-triggered
    /// batch flush stays reachable on tiny queues (an *explicit*
    /// `batch_max` over `queue_cap` is rejected by
    /// [`validate`](Self::validate) instead).
    pub fn batch_max_for(&self, device: Option<&DeviceDescriptor>) -> usize {
        if let Some(b) = self.batch_max {
            return b;
        }
        let derived = match device {
            None => crate::coordinator::ANON_BATCH_MAX,
            // Monotone in capability: anything newer than Fermi batches
            // at least as big (hand-built descriptors may carry cc > 2.0
            // even though the registry tops out there).
            Some(d) => match (d.cc.major, d.cc.minor) {
                (major, _) if major >= 2 => 16,
                (1, 2) | (1, 3) => 8,
                _ => 4,
            },
        };
        derived.min(self.queue_cap.max(1))
    }

    /// Field-level validation, called from config load and again at
    /// `Service` startup (builders can be fed hand-made configs).
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("serving.workers must be >= 1 (got 0)");
        }
        if self.batch_max == Some(0) {
            bail!("serving.batch_max must be >= 1 (got 0)");
        }
        if self.queue_cap == 0 {
            bail!("serving.queue_cap must be >= 1 (got 0)");
        }
        if !self.batch_deadline_ms.is_finite()
            || !(0.0..=MAX_DURATION_MS).contains(&self.batch_deadline_ms)
        {
            bail!(
                "serving.batch_deadline_ms must be in 0..={MAX_DURATION_MS} (got {})",
                self.batch_deadline_ms
            );
        }
        if !self.admission_timeout_ms.is_finite()
            || !(0.0..=MAX_DURATION_MS).contains(&self.admission_timeout_ms)
        {
            bail!(
                "serving.admission_timeout_ms must be in 0..={MAX_DURATION_MS} (got {})",
                self.admission_timeout_ms
            );
        }
        if let Some(b) = self.batch_max {
            if self.queue_cap < b {
                bail!(
                    "serving.queue_cap ({}) must be >= serving.batch_max ({})",
                    self.queue_cap,
                    b
                );
            }
        }
        if self.steal_threshold == 0 {
            bail!("serving.steal_threshold must be >= 1 (got 0)");
        }
        if !self.retune_poll_ms.is_finite()
            || self.retune_poll_ms <= 0.0
            || self.retune_poll_ms > MAX_DURATION_MS
        {
            bail!(
                "serving.retune_poll_ms must be > 0 and <= {MAX_DURATION_MS} (got {})",
                self.retune_poll_ms
            );
        }
        if let Some(addr) = &self.listen {
            crate::net::ListenAddr::parse(addr)
                .with_context(|| format!("serving.listen = \"{addr}\""))?;
        }
        Ok(())
    }
}

/// Autoscaler parameters (`[autoscaler]`): the closed-loop capacity
/// controller behind `tilekit serve --autoscale` (see
/// [`Autoscaler`](crate::coordinator::Autoscaler)). Watermarks are
/// per-member queue depth (queued requests ÷ live members).
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalerConfig {
    /// Start the control loop armed. `serve --autoscale` implies it.
    pub enabled: bool,
    /// Standby device ids (registry/config ids) the loop may engage,
    /// in order; must be disjoint from `serving.devices` (scale-down
    /// removes by label).
    pub standby_devices: Vec<String>,
    /// Scale down only while per-member queue depth < this.
    pub low_queue: f64,
    /// Scale up once per-member queue depth > this.
    pub high_queue: f64,
    /// Optional scale-up trigger on interactive p99 (ms); 0 = off (the
    /// served histograms are cumulative, so a past burst would pin the
    /// signal).
    pub high_p99_ms: f64,
    /// Hold this long after any scale action (hysteresis in time).
    pub cooldown_ms: f64,
    /// Sampling interval of the control loop.
    pub poll_ms: f64,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            enabled: false,
            standby_devices: Vec::new(),
            low_queue: 1.0,
            high_queue: 8.0,
            high_p99_ms: 0.0,
            cooldown_ms: 1000.0,
            poll_ms: 100.0,
        }
    }
}

impl AutoscalerConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.poll_ms.is_finite() || self.poll_ms <= 0.0 || self.poll_ms > MAX_DURATION_MS {
            bail!(
                "autoscaler.poll_ms must be > 0 and <= {MAX_DURATION_MS} (got {})",
                self.poll_ms
            );
        }
        if !self.cooldown_ms.is_finite() || !(0.0..=MAX_DURATION_MS).contains(&self.cooldown_ms) {
            bail!(
                "autoscaler.cooldown_ms must be in 0..={MAX_DURATION_MS} (got {})",
                self.cooldown_ms
            );
        }
        if !self.high_p99_ms.is_finite() || !(0.0..=MAX_DURATION_MS).contains(&self.high_p99_ms) {
            bail!(
                "autoscaler.high_p99_ms must be in 0..={MAX_DURATION_MS} (got {})",
                self.high_p99_ms
            );
        }
        if !self.low_queue.is_finite() || !self.high_queue.is_finite() || self.low_queue < 0.0 {
            bail!("autoscaler watermarks must be finite and non-negative");
        }
        if self.low_queue >= self.high_queue {
            bail!(
                "autoscaler.low_queue ({}) must be < autoscaler.high_queue ({})",
                self.low_queue,
                self.high_queue
            );
        }
        for (i, id) in self.standby_devices.iter().enumerate() {
            if self.standby_devices[..i].contains(id) {
                bail!("autoscaler.standby_devices lists '{id}' twice");
            }
        }
        Ok(())
    }

    /// Materialize the control-loop options (member bounds are derived
    /// at spawn from the fleet and the pool).
    pub fn opts(&self) -> crate::coordinator::AutoscalerOpts {
        let poll = self.poll_ms.max(1.0);
        crate::coordinator::AutoscalerOpts {
            poll: saturating_duration_from_ms(poll),
            low_queue: self.low_queue,
            high_queue: self.high_queue,
            high_p99_us: (self.high_p99_ms * 1e3) as u64,
            cooldown_ticks: (self.cooldown_ms / poll).ceil() as u32,
            start_disabled: !self.enabled,
        }
    }
}

/// Wire-protocol parameters (`[net]`), shared by `serve --listen`,
/// `fleet`/`submit --connect`, and `front --shards`.
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    /// Client-side TCP connect timeout (ms).
    pub connect_timeout_ms: f64,
    /// Server-side socket read timeout (ms) — also the poll tick for
    /// shutdown/idle checks.
    pub read_timeout_ms: f64,
    /// Server closes a connection idle (no complete frame) this long (ms).
    pub idle_timeout_ms: f64,
    /// How long a client call may wait for its response (ms); must
    /// exceed the server's 5 s per-call `wait` cap.
    pub response_timeout_ms: f64,
    /// Concurrent connection cap per server.
    pub max_conns: usize,
    /// Per-line (frame) byte cap, in KiB.
    pub max_line_kib: usize,
    /// Front-tier health poll cadence (ms).
    pub health_poll_ms: f64,
    /// Most pipelined calls in flight per connection: the client blocks
    /// past this, and the server's per-connection work queue is bounded
    /// by it (back-pressuring TCP instead of buffering unboundedly).
    pub max_inflight_per_conn: usize,
    /// Base delay (ms) of the client's jittered exponential redial
    /// backoff. 0 retries without sleeping.
    pub reconnect_backoff_ms: f64,
    /// Image payload encoding: `"binary"` negotiates protocol v2
    /// (length-prefixed f32 blocks, falling back to v1 against old
    /// servers); `"json"` forces v1 JSON-array frames.
    pub payload_encoding: String,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            connect_timeout_ms: 2000.0,
            read_timeout_ms: 250.0,
            idle_timeout_ms: 30_000.0,
            response_timeout_ms: 10_000.0,
            max_conns: 64,
            max_line_kib: 8192,
            health_poll_ms: 200.0,
            max_inflight_per_conn: 32,
            reconnect_backoff_ms: 50.0,
            payload_encoding: "binary".to_string(),
        }
    }
}

impl NetConfig {
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("net.connect_timeout_ms", self.connect_timeout_ms),
            ("net.read_timeout_ms", self.read_timeout_ms),
            ("net.idle_timeout_ms", self.idle_timeout_ms),
            ("net.response_timeout_ms", self.response_timeout_ms),
            ("net.health_poll_ms", self.health_poll_ms),
        ] {
            if !v.is_finite() || v <= 0.0 || v > MAX_DURATION_MS {
                bail!("{name} must be > 0 and <= {MAX_DURATION_MS} (got {v})");
            }
        }
        if self.idle_timeout_ms < self.read_timeout_ms {
            bail!(
                "net.idle_timeout_ms ({}) must be >= net.read_timeout_ms ({})",
                self.idle_timeout_ms,
                self.read_timeout_ms
            );
        }
        if self.response_timeout_ms <= 5000.0 {
            bail!(
                "net.response_timeout_ms ({}) must exceed the server's 5000 ms per-call wait cap",
                self.response_timeout_ms
            );
        }
        if self.max_conns == 0 {
            bail!("net.max_conns must be >= 1 (got 0)");
        }
        if self.max_line_kib == 0 {
            bail!("net.max_line_kib must be >= 1 (got 0)");
        }
        if self.max_inflight_per_conn == 0 {
            bail!("net.max_inflight_per_conn must be >= 1 (got 0)");
        }
        if !self.reconnect_backoff_ms.is_finite()
            || !(0.0..=MAX_DURATION_MS).contains(&self.reconnect_backoff_ms)
        {
            bail!(
                "net.reconnect_backoff_ms must be in 0..={MAX_DURATION_MS} (got {})",
                self.reconnect_backoff_ms
            );
        }
        if crate::net::PayloadEncoding::parse(&self.payload_encoding).is_none() {
            bail!(
                "net.payload_encoding must be \"binary\" or \"json\" (got \"{}\")",
                self.payload_encoding
            );
        }
        Ok(())
    }

    /// Materialize the server-side knobs.
    pub fn server_config(&self) -> crate::net::NetServerConfig {
        crate::net::NetServerConfig {
            max_conns: self.max_conns,
            read_timeout: saturating_duration_from_ms(self.read_timeout_ms),
            idle_timeout: saturating_duration_from_ms(self.idle_timeout_ms),
            max_line_bytes: self.max_line_kib * 1024,
            drain_timeout: std::time::Duration::from_secs(10),
            max_inflight_per_conn: self.max_inflight_per_conn,
        }
    }

    /// Materialize the client-side knobs.
    pub fn client_config(&self) -> crate::net::NetClientConfig {
        crate::net::NetClientConfig {
            connect_timeout: saturating_duration_from_ms(self.connect_timeout_ms),
            response_timeout: saturating_duration_from_ms(self.response_timeout_ms),
            max_line_bytes: self.max_line_kib * 1024,
            wait_poll: std::time::Duration::from_secs(2),
            max_inflight: self.max_inflight_per_conn,
            reconnect_backoff: saturating_duration_from_ms(self.reconnect_backoff_ms),
            payload_encoding: crate::net::PayloadEncoding::parse(&self.payload_encoding)
                .unwrap_or(crate::net::PayloadEncoding::Binary),
            ..crate::net::NetClientConfig::default()
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub sweep: SweepConfig,
    pub serving: ServingConfig,
    pub autoscaler: AutoscalerConfig,
    pub net: NetConfig,
    /// Builtin devices plus any `[[device]]` entries (by id; custom
    /// entries with a builtin id override it).
    pub devices: Vec<DeviceDescriptor>,
}

impl Config {
    /// Builtin defaults (no file).
    pub fn builtin() -> Config {
        Config {
            sweep: SweepConfig::default(),
            serving: ServingConfig::default(),
            autoscaler: AutoscalerConfig::default(),
            net: NetConfig::default(),
            devices: builtin_devices(),
        }
    }

    /// Load from a TOML file, merging over the defaults.
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml_str(&text).with_context(|| format!("in {}", path.display()))
    }

    /// Parse from TOML text, merging over the defaults.
    pub fn from_toml_str(text: &str) -> Result<Config> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut cfg = Config::builtin();

        if let Some(t) = doc.table("sweep") {
            if let Some(v) = t.get("src") {
                let pair = int_pair(v).context("sweep.src")?;
                cfg.sweep.src = pair;
            }
            if let Some(v) = t.get("scales") {
                cfg.sweep.scales = int_list(v).context("sweep.scales")?;
            }
            if let Some(v) = t.get("devices") {
                cfg.sweep.devices = str_list(v).context("sweep.devices")?;
            }
            if let Some(v) = t.get("kernel") {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow!("sweep.kernel must be a string"))?;
                cfg.sweep.kernel = Interpolator::parse(s)
                    .ok_or_else(|| anyhow!("unknown kernel '{s}'"))?;
            }
            if let Some(v) = t.get("tiles") {
                cfg.sweep.tiles = str_list(v)?
                    .iter()
                    .map(|s| s.parse::<TileDim>().map_err(|e| anyhow!("{e}")))
                    .collect::<Result<Vec<_>>>()
                    .context("sweep.tiles")?;
            }
        }

        if let Some(t) = doc.table("serving") {
            if let Some(v) = t.get("workers") {
                cfg.serving.workers = as_usize(v).context("serving.workers")?;
            }
            if let Some(v) = t.get("batch_max") {
                cfg.serving.batch_max = Some(as_usize(v).context("serving.batch_max")?);
            }
            if let Some(v) = t.get("batch_deadline_ms") {
                cfg.serving.batch_deadline_ms = v
                    .as_float()
                    .ok_or_else(|| anyhow!("serving.batch_deadline_ms must be a number"))?;
            }
            if let Some(v) = t.get("queue_cap") {
                cfg.serving.queue_cap = as_usize(v).context("serving.queue_cap")?;
            }
            if let Some(v) = t.get("artifacts_dir") {
                cfg.serving.artifacts_dir = v
                    .as_str()
                    .ok_or_else(|| anyhow!("serving.artifacts_dir must be a string"))?
                    .to_string();
            }
            if let Some(v) = t.get("devices") {
                cfg.serving.devices = str_list(v).context("serving.devices")?;
            }
            if let Some(v) = t.get("scheduler") {
                cfg.serving.scheduler = v
                    .as_str()
                    .ok_or_else(|| anyhow!("serving.scheduler must be a string"))?
                    .to_string();
            }
            if let Some(v) = t.get("admission") {
                cfg.serving.admission = v
                    .as_str()
                    .ok_or_else(|| anyhow!("serving.admission must be a string"))?
                    .to_string();
            }
            if let Some(v) = t.get("admission_timeout_ms") {
                cfg.serving.admission_timeout_ms = v
                    .as_float()
                    .ok_or_else(|| anyhow!("serving.admission_timeout_ms must be a number"))?;
            }
            if let Some(v) = t.get("work_stealing") {
                cfg.serving.work_stealing = v
                    .as_bool()
                    .ok_or_else(|| anyhow!("serving.work_stealing must be a boolean"))?;
            }
            if let Some(v) = t.get("steal_threshold") {
                cfg.serving.steal_threshold =
                    as_usize(v).context("serving.steal_threshold")?;
            }
            if let Some(v) = t.get("retune_poll_ms") {
                cfg.serving.retune_poll_ms = v
                    .as_float()
                    .ok_or_else(|| anyhow!("serving.retune_poll_ms must be a number"))?;
            }
            if let Some(v) = t.get("breakdown_sample") {
                cfg.serving.breakdown_sample =
                    as_usize(v).context("serving.breakdown_sample")? as u64;
            }
            if let Some(v) = t.get("listen") {
                cfg.serving.listen = Some(
                    v.as_str()
                        .ok_or_else(|| anyhow!("serving.listen must be a string"))?
                        .to_string(),
                );
            }
        }

        if let Some(t) = doc.table("autoscaler") {
            if let Some(v) = t.get("enabled") {
                cfg.autoscaler.enabled = v
                    .as_bool()
                    .ok_or_else(|| anyhow!("autoscaler.enabled must be a boolean"))?;
            }
            if let Some(v) = t.get("standby_devices") {
                cfg.autoscaler.standby_devices =
                    str_list(v).context("autoscaler.standby_devices")?;
            }
            let float = |key: &str, slot: &mut f64| -> Result<()> {
                if let Some(v) = t.get(key) {
                    *slot = v
                        .as_float()
                        .ok_or_else(|| anyhow!("autoscaler.{key} must be a number"))?;
                }
                Ok(())
            };
            float("low_queue", &mut cfg.autoscaler.low_queue)?;
            float("high_queue", &mut cfg.autoscaler.high_queue)?;
            float("high_p99_ms", &mut cfg.autoscaler.high_p99_ms)?;
            float("cooldown_ms", &mut cfg.autoscaler.cooldown_ms)?;
            float("poll_ms", &mut cfg.autoscaler.poll_ms)?;
        }

        if let Some(t) = doc.table("net") {
            let float = |key: &str, slot: &mut f64| -> Result<()> {
                if let Some(v) = t.get(key) {
                    *slot = v
                        .as_float()
                        .ok_or_else(|| anyhow!("net.{key} must be a number"))?;
                }
                Ok(())
            };
            float("connect_timeout_ms", &mut cfg.net.connect_timeout_ms)?;
            float("read_timeout_ms", &mut cfg.net.read_timeout_ms)?;
            float("idle_timeout_ms", &mut cfg.net.idle_timeout_ms)?;
            float("response_timeout_ms", &mut cfg.net.response_timeout_ms)?;
            float("health_poll_ms", &mut cfg.net.health_poll_ms)?;
            if let Some(v) = t.get("max_conns") {
                cfg.net.max_conns = as_usize(v).context("net.max_conns")?;
            }
            if let Some(v) = t.get("max_line_kib") {
                cfg.net.max_line_kib = as_usize(v).context("net.max_line_kib")?;
            }
            if let Some(v) = t.get("max_inflight_per_conn") {
                cfg.net.max_inflight_per_conn =
                    as_usize(v).context("net.max_inflight_per_conn")?;
            }
            float("reconnect_backoff_ms", &mut cfg.net.reconnect_backoff_ms)?;
            if let Some(v) = t.get("payload_encoding") {
                cfg.net.payload_encoding = v
                    .as_str()
                    .ok_or_else(|| anyhow!("net.payload_encoding must be a string"))?
                    .to_string();
            }
        }

        if let Some(devs) = doc.arrays.get("device") {
            for d in devs {
                let desc = DeviceDescriptor::from_toml(d).map_err(|e| anyhow!("{e}"))?;
                // Override a builtin with the same id, else append.
                if let Some(slot) = cfg.devices.iter_mut().find(|b| b.id == desc.id) {
                    *slot = desc;
                } else {
                    cfg.devices.push(desc);
                }
            }
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<()> {
        if self.sweep.scales.is_empty() {
            bail!("sweep.scales must be non-empty");
        }
        if self.sweep.scales.iter().any(|&s| s == 0 || s > 64) {
            bail!("sweep.scales entries must be in 1..=64");
        }
        if self.sweep.src.0 == 0 || self.sweep.src.1 == 0 {
            bail!("sweep.src must be positive");
        }
        for id in &self.sweep.devices {
            if !self.devices.iter().any(|d| &d.id == id) {
                bail!("sweep.devices references unknown device '{id}'");
            }
        }
        for id in &self.serving.devices {
            if !self.devices.iter().any(|d| &d.id == id) {
                bail!("serving.devices references unknown device '{id}'");
            }
        }
        for id in &self.autoscaler.standby_devices {
            if !self.devices.iter().any(|d| &d.id == id) {
                bail!("autoscaler.standby_devices references unknown device '{id}'");
            }
            // Scale-down removes by label: a standby id colliding with
            // a serving member would take the base fleet down with the
            // burst capacity.
            if self.serving.devices.contains(id) {
                bail!(
                    "autoscaler.standby_devices entry '{id}' is already in serving.devices"
                );
            }
        }
        self.serving.validate()?;
        self.autoscaler.validate()?;
        self.net.validate()?;
        // Fail at load time on a name no scheduler/policy will accept,
        // not at service startup.
        crate::coordinator::scheduler_by_name(&self.serving.scheduler)?;
        crate::coordinator::admission_by_name(
            &self.serving.admission,
            saturating_duration_from_ms(self.serving.admission_timeout_ms),
        )?;
        Ok(())
    }

    /// Resolve a device id against this config's device set.
    pub fn device(&self, id: &str) -> Result<&DeviceDescriptor> {
        let id_l = id.to_ascii_lowercase();
        self.devices
            .iter()
            .find(|d| d.id == id_l)
            .ok_or_else(|| anyhow!("unknown device '{id}'"))
    }
}

fn as_usize(v: &TomlValue) -> Result<usize> {
    v.as_int()
        .filter(|&i| i >= 0)
        .map(|i| i as usize)
        .ok_or_else(|| anyhow!("expected a non-negative integer"))
}

fn int_list(v: &TomlValue) -> Result<Vec<u32>> {
    v.as_array()
        .ok_or_else(|| anyhow!("expected an array"))?
        .iter()
        .map(|x| {
            x.as_int()
                .filter(|&i| i >= 0)
                .map(|i| i as u32)
                .ok_or_else(|| anyhow!("expected integers"))
        })
        .collect()
}

fn int_pair(v: &TomlValue) -> Result<(u32, u32)> {
    let l = int_list(v)?;
    if l.len() != 2 {
        bail!("expected a [w, h] pair");
    }
    Ok((l[0], l[1]))
}

fn str_list(v: &TomlValue) -> Result<Vec<String>> {
    v.as_array()
        .ok_or_else(|| anyhow!("expected an array"))?
        .iter()
        .map(|x| {
            x.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("expected strings"))
        })
        .collect()
}

/// The default config file content written by `tilekit init-config`.
pub const EXAMPLE_CONFIG: &str = r#"# tilekit configuration
# Everything here overrides the builtin defaults; all sections optional.

[sweep]
src = [800, 800]          # the paper's source image
scales = [2, 4, 6, 8, 10] # Fig. 3 insets (a)-(e)
devices = ["gtx260", "8800gts"]
kernel = "bilinear"
# tiles = ["32x4", "16x8"]  # empty = full power-of-two sweep

[serving]
workers = 2                # per device member
# batch_max = 8            # omit to derive per member from its compute
                           # capability (cc2.x: 16, cc1.2/1.3: 8, cc1.0/1.1: 4)
batch_deadline_ms = 2.0
queue_cap = 256
artifacts_dir = "artifacts"
# devices = ["gtx260", "fermi"]  # fleet members; empty = one anonymous backend
scheduler = "round-robin"  # round-robin | least-loaded | cost-eta
                           # (cost-eta also declines deadlines no member can meet)
admission = "reject"       # reject | block | shed-batch
admission_timeout_ms = 5000.0
work_stealing = true       # idle members steal from hot peers' queues
steal_threshold = 4        # min victim backlog before stealing kicks in
retune_poll_ms = 200.0     # tuning-db watcher poll for `serve --watch-db`
breakdown_sample = 16      # time every Nth submit's snapshot/schedule/admit
                           # phases (0 = off)
# listen = "127.0.0.1:7441"     # default addr for `serve --listen`
# listen = "unix:/tmp/tk.sock"  # ...or a Unix socket

[autoscaler]               # closed-loop capacity control (`serve --autoscale`)
enabled = false            # --autoscale arms it even when false here
# standby_devices = ["fermi"]  # pool the loop may engage; disjoint from
                               # serving.devices (scale-down removes by label)
low_queue = 1.0            # scale down while queued/members < low
high_queue = 8.0           # scale up once queued/members > high
high_p99_ms = 0.0          # optional p99 scale-up trigger; 0 = off
cooldown_ms = 1000.0       # hold after any scale action (no flapping)
poll_ms = 100.0            # control-loop sampling interval

[net]                      # wire protocol (serve --listen / --connect / front)
connect_timeout_ms = 2000.0
read_timeout_ms = 250.0        # server poll tick for idle/shutdown checks
idle_timeout_ms = 30000.0      # server drops connections idle this long
response_timeout_ms = 10000.0  # client per-call budget (> 5000 ms wait cap)
max_conns = 64                 # per-server concurrent connection cap
max_line_kib = 8192            # frame size bound (one JSON line or binary block)
health_poll_ms = 200.0         # front tier topology/health poll cadence
max_inflight_per_conn = 32     # pipelined calls per connection (both ends)
reconnect_backoff_ms = 50.0    # base of the client's jittered redial backoff
payload_encoding = "binary"    # "binary" = protocol v2 pixels, "json" = v1

# Custom GPUs (merged over the registry by id):
# [[device]]
# id = "mygpu"
# name = "My GPU"
# cc = "1.3"
# sms = 16
# sp_clock_mhz = 1300.0
# mem_clock_mhz = 2000.0
# mem_bus_bits = 256
# global_mem_mib = 512
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_is_valid() {
        Config::builtin().validate().unwrap();
    }

    #[test]
    fn example_config_parses() {
        let cfg = Config::from_toml_str(EXAMPLE_CONFIG).unwrap();
        assert_eq!(cfg.sweep.scales, vec![2, 4, 6, 8, 10]);
        assert_eq!(cfg.serving.batch_max, None, "derived per member by default");
        assert!(cfg.serving.work_stealing);
        assert_eq!(cfg.serving.steal_threshold, 4);
        assert!(!cfg.autoscaler.enabled, "example ships with the loop off");
        assert_eq!(cfg.autoscaler, AutoscalerConfig::default());
    }

    #[test]
    fn batch_max_derives_from_compute_capability() {
        let cfg = ServingConfig::default();
        let fermi = crate::device::find_device("fermi").unwrap();
        let gtx260 = crate::device::find_device("gtx260").unwrap(); // cc1.3
        let g80 = crate::device::find_device("8800gts").unwrap(); // cc1.0
        assert_eq!(cfg.batch_max_for(Some(&fermi)), 16);
        assert_eq!(cfg.batch_max_for(Some(&gtx260)), 8);
        assert_eq!(cfg.batch_max_for(Some(&g80)), 4);
        assert_eq!(
            cfg.batch_max_for(None),
            crate::coordinator::ANON_BATCH_MAX,
            "anonymous members keep the classic default"
        );
        // The override pins every member.
        let pinned = ServingConfig {
            batch_max: Some(3),
            ..ServingConfig::default()
        };
        assert_eq!(pinned.batch_max_for(Some(&fermi)), 3);
        assert_eq!(pinned.batch_max_for(None), 3);
        // Derived caps clamp to the queue so size-triggered flushes
        // stay reachable (explicit overrides are validated instead).
        let tiny = ServingConfig {
            queue_cap: 8,
            ..ServingConfig::default()
        };
        assert_eq!(tiny.batch_max_for(Some(&fermi)), 8);
        tiny.validate().unwrap();
    }

    #[test]
    fn stealing_fields_parse_and_validate() {
        let cfg = Config::from_toml_str(
            "[serving]\nwork_stealing = false\nsteal_threshold = 9\n",
        )
        .unwrap();
        assert!(!cfg.serving.work_stealing);
        assert_eq!(cfg.serving.steal_threshold, 9);
        assert!(Config::from_toml_str("[serving]\nsteal_threshold = 0\n").is_err());
        assert!(Config::from_toml_str("[serving]\nwork_stealing = 7\n").is_err());
    }

    #[test]
    fn retune_poll_parses_and_validates() {
        let cfg = Config::from_toml_str("[serving]\nretune_poll_ms = 50.0\n").unwrap();
        assert_eq!(cfg.serving.retune_poll_ms, 50.0);
        assert_eq!(
            ServingConfig::default().retune_poll_ms,
            200.0,
            "default poll"
        );
        assert!(Config::from_toml_str("[serving]\nretune_poll_ms = 0.0\n").is_err());
        assert!(Config::from_toml_str("[serving]\nretune_poll_ms = -5.0\n").is_err());
    }

    #[test]
    fn partial_override_keeps_defaults() {
        let cfg = Config::from_toml_str("[serving]\nworkers = 7\n").unwrap();
        assert_eq!(cfg.serving.workers, 7);
        assert_eq!(cfg.serving.batch_max, ServingConfig::default().batch_max);
        assert_eq!(cfg.sweep.scales, SweepConfig::default().scales);
    }

    #[test]
    fn custom_device_merges_and_overrides() {
        let text = r#"
[[device]]
id = "gtx260"
name = "Overridden GTX 260"
cc = "1.3"
sms = 99
sp_clock_mhz = 1.0
mem_clock_mhz = 1.0
mem_bus_bits = 64
global_mem_mib = 64

[[device]]
id = "brand-new"
name = "Brand New"
cc = "2.0"
sms = 4
sp_clock_mhz = 1.0
mem_clock_mhz = 1.0
mem_bus_bits = 64
global_mem_mib = 64
"#;
        let cfg = Config::from_toml_str(text).unwrap();
        assert_eq!(cfg.device("gtx260").unwrap().sm_count, 99);
        assert!(cfg.device("brand-new").is_ok());
        assert_eq!(
            cfg.devices.len(),
            builtin_devices().len() + 1,
            "override must not duplicate"
        );
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(Config::from_toml_str("[sweep]\nscales = []\n").is_err());
        assert!(Config::from_toml_str("[sweep]\nscales = [0]\n").is_err());
        assert!(Config::from_toml_str("[sweep]\ndevices = [\"ghost\"]\n").is_err());
        assert!(Config::from_toml_str("[serving]\nworkers = 0\n").is_err());
        assert!(
            Config::from_toml_str("[serving]\nqueue_cap = 2\nbatch_max = 10\n").is_err()
        );
        assert!(Config::from_toml_str("[sweep]\nkernel = \"sinc\"\n").is_err());
        assert!(Config::from_toml_str("[serving]\ndevices = [\"ghost\"]\n").is_err());
        assert!(Config::from_toml_str("[serving]\nscheduler = \"fifo\"\n").is_err());
        assert!(Config::from_toml_str("[serving]\nadmission = \"yolo\"\n").is_err());
    }

    #[test]
    fn serving_validate_friendly_errors() {
        let base = ServingConfig::default();
        let cases: Vec<(ServingConfig, &str)> = vec![
            (
                ServingConfig {
                    workers: 0,
                    ..base.clone()
                },
                "serving.workers",
            ),
            (
                ServingConfig {
                    batch_max: Some(0),
                    ..base.clone()
                },
                "serving.batch_max",
            ),
            (
                ServingConfig {
                    steal_threshold: 0,
                    ..base.clone()
                },
                "serving.steal_threshold",
            ),
            (
                ServingConfig {
                    queue_cap: 0,
                    ..base.clone()
                },
                "serving.queue_cap",
            ),
            (
                ServingConfig {
                    batch_deadline_ms: -1.0,
                    ..base.clone()
                },
                "serving.batch_deadline_ms",
            ),
            (
                ServingConfig {
                    batch_deadline_ms: f64::NAN,
                    ..base.clone()
                },
                "serving.batch_deadline_ms",
            ),
            (
                ServingConfig {
                    admission_timeout_ms: -5.0,
                    ..base.clone()
                },
                "serving.admission_timeout_ms",
            ),
            (
                ServingConfig {
                    retune_poll_ms: 0.0,
                    ..base.clone()
                },
                "serving.retune_poll_ms",
            ),
        ];
        for (cfg, needle) in cases {
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains(needle), "'{err}' should name {needle}");
        }
        base.validate().unwrap();
    }

    #[test]
    fn serving_fleet_fields_parse() {
        let cfg = Config::from_toml_str(
            "[serving]\ndevices = [\"gtx260\", \"fermi\"]\nscheduler = \"cost-eta\"\n\
             admission = \"shed-batch\"\nadmission_timeout_ms = 250.0\n",
        )
        .unwrap();
        assert_eq!(cfg.serving.devices, vec!["gtx260", "fermi"]);
        assert_eq!(cfg.serving.scheduler, "cost-eta");
        assert_eq!(cfg.serving.admission, "shed-batch");
        assert_eq!(cfg.serving.admission_timeout_ms, 250.0);
    }

    #[test]
    fn listen_key_parses_and_validates() {
        let cfg =
            Config::from_toml_str("[serving]\nlisten = \"127.0.0.1:7441\"\n").unwrap();
        assert_eq!(cfg.serving.listen.as_deref(), Some("127.0.0.1:7441"));
        let cfg = Config::from_toml_str("[serving]\nlisten = \"unix:/tmp/tk.sock\"\n").unwrap();
        assert_eq!(cfg.serving.listen.as_deref(), Some("unix:/tmp/tk.sock"));
        assert_eq!(ServingConfig::default().listen, None, "off by default");
        assert!(Config::from_toml_str("[serving]\nlisten = \"noport\"\n").is_err());
        assert!(Config::from_toml_str("[serving]\nlisten = \"host:yes\"\n").is_err());
        assert!(Config::from_toml_str("[serving]\nlisten = 7441\n").is_err());
    }

    #[test]
    fn autoscaler_table_parses_and_validates() {
        let cfg = Config::from_toml_str(
            "[autoscaler]\nenabled = true\nstandby_devices = [\"fermi\"]\n\
             low_queue = 0.5\nhigh_queue = 6.0\ncooldown_ms = 400.0\npoll_ms = 20.0\n",
        )
        .unwrap();
        assert!(cfg.autoscaler.enabled);
        assert_eq!(cfg.autoscaler.standby_devices, vec!["fermi"]);
        assert_eq!(cfg.autoscaler.low_queue, 0.5);
        assert_eq!(cfg.autoscaler.high_queue, 6.0);
        let opts = cfg.autoscaler.opts();
        assert_eq!(opts.poll, std::time::Duration::from_millis(20));
        assert_eq!(opts.cooldown_ticks, 20, "ceil(400 / 20)");
        assert!(!opts.start_disabled);
        // Defaults: off, empty pool, valid.
        let d = AutoscalerConfig::default();
        assert!(!d.enabled);
        assert!(d.standby_devices.is_empty());
        d.validate().unwrap();
        // Rejections.
        assert!(
            Config::from_toml_str("[autoscaler]\nstandby_devices = [\"ghost\"]\n").is_err(),
            "unknown standby device"
        );
        assert!(
            Config::from_toml_str(
                "[serving]\ndevices = [\"fermi\"]\n\n\
                 [autoscaler]\nstandby_devices = [\"fermi\"]\n"
            )
            .is_err(),
            "standby overlapping the serving fleet"
        );
        assert!(
            Config::from_toml_str(
                "[autoscaler]\nstandby_devices = [\"fermi\", \"fermi\"]\n"
            )
            .is_err(),
            "duplicate standby entry"
        );
        assert!(
            Config::from_toml_str("[autoscaler]\nlow_queue = 9.0\nhigh_queue = 2.0\n")
                .is_err(),
            "inverted watermark band"
        );
        assert!(Config::from_toml_str("[autoscaler]\npoll_ms = 0.0\n").is_err());
        assert!(Config::from_toml_str("[autoscaler]\ncooldown_ms = -1.0\n").is_err());
        assert!(Config::from_toml_str("[autoscaler]\nenabled = 3\n").is_err());
    }

    #[test]
    fn net_table_parses_and_validates() {
        let cfg = Config::from_toml_str(
            "[net]\nmax_conns = 8\nread_timeout_ms = 100.0\nidle_timeout_ms = 5000.0\n\
             response_timeout_ms = 6000.0\nmax_line_kib = 64\nhealth_poll_ms = 50.0\n",
        )
        .unwrap();
        assert_eq!(cfg.net.max_conns, 8);
        assert_eq!(cfg.net.read_timeout_ms, 100.0);
        assert_eq!(cfg.net.idle_timeout_ms, 5000.0);
        assert_eq!(cfg.net.max_line_kib, 64);
        assert_eq!(cfg.net.health_poll_ms, 50.0);
        // defaults survive partial override
        assert_eq!(cfg.net.connect_timeout_ms, 2000.0);
        NetConfig::default().validate().unwrap();
        assert!(Config::from_toml_str("[net]\nmax_conns = 0\n").is_err());
        assert!(Config::from_toml_str("[net]\nmax_line_kib = 0\n").is_err());
        assert!(Config::from_toml_str("[net]\nread_timeout_ms = 0.0\n").is_err());
        // idle must cover at least one read tick
        assert!(Config::from_toml_str(
            "[net]\nread_timeout_ms = 500.0\nidle_timeout_ms = 100.0\n"
        )
        .is_err());
        // client budget must outlast the server's wait cap
        assert!(Config::from_toml_str("[net]\nresponse_timeout_ms = 1000.0\n").is_err());
        // v2 knobs parse and validate
        let cfg = Config::from_toml_str(
            "[net]\nmax_inflight_per_conn = 4\nreconnect_backoff_ms = 10.0\n\
             payload_encoding = \"json\"\n",
        )
        .unwrap();
        assert_eq!(cfg.net.max_inflight_per_conn, 4);
        assert_eq!(cfg.net.reconnect_backoff_ms, 10.0);
        assert_eq!(cfg.net.payload_encoding, "json");
        assert!(Config::from_toml_str("[net]\nmax_inflight_per_conn = 0\n").is_err());
        assert!(Config::from_toml_str("[net]\nreconnect_backoff_ms = -1.0\n").is_err());
        assert!(Config::from_toml_str("[net]\npayload_encoding = \"carrier-pigeon\"\n")
            .is_err());
    }

    #[test]
    fn net_config_materializes_server_and_client_knobs() {
        let net = NetConfig {
            max_conns: 3,
            max_line_kib: 2,
            read_timeout_ms: 100.0,
            max_inflight_per_conn: 7,
            reconnect_backoff_ms: 25.0,
            payload_encoding: "json".to_string(),
            ..NetConfig::default()
        };
        let s = net.server_config();
        assert_eq!(s.max_conns, 3);
        assert_eq!(s.max_line_bytes, 2048);
        assert_eq!(s.read_timeout, std::time::Duration::from_millis(100));
        assert_eq!(s.max_inflight_per_conn, 7);
        let c = net.client_config();
        assert_eq!(c.max_line_bytes, 2048);
        assert_eq!(c.connect_timeout, std::time::Duration::from_secs(2));
        assert_eq!(c.max_inflight, 7);
        assert_eq!(c.reconnect_backoff, std::time::Duration::from_millis(25));
        assert_eq!(c.payload_encoding, crate::net::PayloadEncoding::Json);
    }

    #[test]
    fn tiles_parse() {
        let cfg = Config::from_toml_str("[sweep]\ntiles = [\"32x4\", \"8x8\"]\n").unwrap();
        assert_eq!(cfg.sweep.tiles, vec![TileDim::new(32, 4), TileDim::new(8, 8)]);
        assert!(Config::from_toml_str("[sweep]\ntiles = [\"zz\"]\n").is_err());
    }
}
