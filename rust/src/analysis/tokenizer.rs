//! A hand-rolled Rust lexer for the invariant analyzer.
//!
//! Same vendored-offline idiom as [`crate::codec::json`]: a byte cursor
//! over the source, no external crates, no regexes. The lexer does NOT
//! parse Rust — it produces a flat token stream precise enough for the
//! line-oriented invariant rules in [`super::rules`]:
//!
//! * comments are separated out (they carry suppression annotations),
//! * string/char literals are opaque single tokens (so `"unwrap()"`
//!   inside a string never trips a rule),
//! * lifetimes are distinguished from char literals,
//! * raw strings (`r"…"`, `r#"…"#`, any hash depth, `b`/`br` prefixes)
//!   and nested block comments are handled,
//! * every token records the 1-based source line it starts on.
//!
//! What it deliberately does not do: interpret numeric values, glue
//! multi-char operators (`::` is two `Punct(':')` tokens), or build a
//! syntax tree. Rules pattern-match short token windows instead.

/// Token classes the rules distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifiers and keywords (`fn`, `unwrap`, `topology`, `as`, …).
    Ident,
    /// A lifetime (`'a`, `'static`) — kept distinct so `'a>` in generics
    /// is never confused with a char literal.
    Lifetime,
    /// A numeric literal, suffix included (`1e3`, `0x2F`, `4.0f64`).
    Num,
    /// A string literal (normal, raw, or byte), quotes included.
    Str,
    /// A char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// A single punctuation byte (`.`, `(`, `[`, `!`, …).
    Punct,
}

/// One lexed token with its starting line (1-based).
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One comment with its starting line. `text` is the comment interior
/// (markers stripped, trimmed) — this is where `analyze::allow(...)`
/// annotations live.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// The lexer output: code tokens and comments, both in source order.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_continue(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lex `src` into tokens + comments. Never fails: unterminated literals
/// simply run to end-of-file (the analyzer scans real, compiling source;
/// garbage in degrades to fewer tokens, not a panic).
pub fn lex(src: &str) -> Lexed {
    Lexer {
        b: src.as_bytes(),
        src,
        i: 0,
        line: 1,
        out: Lexed::default(),
    }
    .run()
}

struct Lexer<'a> {
    b: &'a [u8],
    src: &'a str,
    i: usize,
    line: u32,
    out: Lexed,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> Lexed {
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                _ if c.is_ascii_whitespace() => self.i += 1,
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(),
                b'"' => self.string(),
                b'r' | b'b' if self.raw_or_byte_prefix() => {}
                b'\'' => self.char_or_lifetime(),
                _ if is_ident_start(c) => self.ident(),
                _ if c.is_ascii_digit() => self.number(),
                _ => {
                    self.push(TokKind::Punct, self.i, self.i + 1, self.line);
                    self.i += 1;
                }
            }
        }
        self.out
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.b.get(self.i + ahead).copied()
    }

    fn push(&mut self, kind: TokKind, start: usize, end: usize, line: u32) {
        self.out.toks.push(Tok {
            kind,
            text: self.src[start..end].to_string(),
            line,
        });
    }

    fn line_comment(&mut self) {
        let line = self.line;
        let mut start = self.i + 2;
        // Doc comments: strip the extra marker so `/// analyze::allow`
        // and `//! …` interiors read the same as plain comments.
        if matches!(self.b.get(start), Some(b'/') | Some(b'!')) {
            start += 1;
        }
        while self.i < self.b.len() && self.b[self.i] != b'\n' {
            self.i += 1;
        }
        self.out.comments.push(Comment {
            line,
            text: self.src[start.min(self.i)..self.i].trim().to_string(),
        });
    }

    fn block_comment(&mut self) {
        let line = self.line;
        let start = self.i + 2;
        self.i += 2;
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            match self.b[self.i] {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b'/' if self.peek(1) == Some(b'*') => {
                    depth += 1;
                    self.i += 2;
                }
                b'*' if self.peek(1) == Some(b'/') => {
                    depth -= 1;
                    self.i += 2;
                }
                _ => self.i += 1,
            }
        }
        let end = self.i.saturating_sub(2).max(start);
        self.out.comments.push(Comment {
            line,
            text: self.src[start..end].trim().to_string(),
        });
    }

    /// Normal string literal, escapes honored, newlines counted —
    /// including a line-continuation escape (`\` at end of line), whose
    /// skipped newline still advances the line counter.
    fn string(&mut self) {
        let (line, start) = (self.line, self.i);
        self.i += 1;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'\\' => {
                    if self.peek(1) == Some(b'\n') {
                        self.line += 1;
                    }
                    self.i += 2;
                }
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b'"' => {
                    self.i += 1;
                    break;
                }
                _ => self.i += 1,
            }
        }
        self.push(TokKind::Str, start, self.i.min(self.b.len()), line);
    }

    /// Handle `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`, `b'x'`, and raw
    /// identifiers (`r#type`). Returns true when it consumed input;
    /// false means the `r`/`b` is an ordinary identifier start (the
    /// caller's match falls through to `ident` via the guard).
    fn raw_or_byte_prefix(&mut self) -> bool {
        let mut j = self.i;
        let c = self.b[j];
        if c == b'b' {
            if self.peek(1) == Some(b'\'') {
                // byte char literal: delegate with the prefix consumed
                let (line, start) = (self.line, self.i);
                self.i += 1;
                self.char_literal_body(start, line);
                return true;
            }
            j += 1;
            if self.b.get(j) == Some(&b'r') {
                j += 1;
            }
        } else {
            // c == 'r'
            j += 1;
            if self.b.get(j) == Some(&b'#') && self.b.get(j + 1).copied().is_some_and(is_ident_start)
            {
                // raw identifier r#type
                let (line, start) = (self.line, self.i);
                self.i = j + 1;
                while self.i < self.b.len() && is_ident_continue(self.b[self.i]) {
                    self.i += 1;
                }
                self.push(TokKind::Ident, start, self.i, line);
                return true;
            }
        }
        let mut hashes = 0usize;
        while self.b.get(j) == Some(&b'#') {
            hashes += 1;
            j += 1;
        }
        if self.b.get(j) != Some(&b'"') {
            return false; // plain identifier like `radius` or `bytes`
        }
        // Raw/byte string: scan for `"` followed by `hashes` hashes.
        // (hashes == 0 covers b"…" — escapes still apply there, but a
        // `\"` inside b"…" only matters for where the token ends; for
        // `r"…"` there are no escapes at all.)
        let (line, start) = (self.line, self.i);
        let raw = self.src[self.i..j].contains('r');
        self.i = j + 1;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b'\\' if !raw => {
                    if self.peek(1) == Some(b'\n') {
                        self.line += 1;
                    }
                    self.i += 2;
                }
                b'"' => {
                    let mut k = 0usize;
                    while k < hashes && self.b.get(self.i + 1 + k) == Some(&b'#') {
                        k += 1;
                    }
                    if k == hashes {
                        self.i += 1 + hashes;
                        self.push(TokKind::Str, start, self.i.min(self.b.len()), line);
                        return true;
                    }
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
        self.push(TokKind::Str, start, self.b.len(), line);
        true
    }

    /// At a `'`: char literal or lifetime. A `'` that is followed by an
    /// escape, or whose closing quote arrives within one (possibly
    /// multi-byte) character, is a char literal; otherwise a lifetime.
    fn char_or_lifetime(&mut self) {
        let (line, start) = (self.line, self.i);
        match self.peek(1) {
            Some(b'\\') => {
                self.char_literal_body(start, line);
            }
            Some(c) if c != b'\'' => {
                // Find the closing quote within the next 1..=4 bytes
                // (one UTF-8 scalar). `'a'` -> char; `'a>` -> lifetime.
                let close = (2..=5).find(|&k| self.b.get(start + k) == Some(&b'\''));
                match close {
                    Some(k) if !is_ident_continue(c) || k == 2 => {
                        self.i = start + k + 1;
                        self.push(TokKind::Char, start, self.i, line);
                    }
                    _ => {
                        // lifetime: consume ident chars after the quote
                        self.i = start + 1;
                        while self.i < self.b.len() && is_ident_continue(self.b[self.i]) {
                            self.i += 1;
                        }
                        self.push(TokKind::Lifetime, start, self.i, line);
                    }
                }
            }
            _ => {
                // `''` or a trailing `'`: emit as punct and move on.
                self.push(TokKind::Punct, start, start + 1, line);
                self.i += 1;
            }
        }
    }

    /// Body of an escaped char/byte literal, `start` already at the
    /// prefix. Consumes through the closing quote.
    fn char_literal_body(&mut self, start: usize, line: u32) {
        // skip to the opening quote, then past it
        while self.i < self.b.len() && self.b[self.i] != b'\'' {
            self.i += 1;
        }
        self.i += 1;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'\\' => self.i += 2,
                b'\'' => {
                    self.i += 1;
                    break;
                }
                _ => self.i += 1,
            }
        }
        self.push(TokKind::Char, start, self.i.min(self.b.len()), line);
    }

    fn ident(&mut self) {
        let (line, start) = (self.line, self.i);
        while self.i < self.b.len() && is_ident_continue(self.b[self.i]) {
            self.i += 1;
        }
        self.push(TokKind::Ident, start, self.i, line);
    }

    fn number(&mut self) {
        let (line, start) = (self.line, self.i);
        self.i += 1;
        while self.i < self.b.len() {
            let c = self.b[self.i];
            let prev = self.b[self.i - 1];
            if is_ident_continue(c) {
                self.i += 1;
            } else if c == b'.' && self.peek(1).is_some_and(|d| d.is_ascii_digit()) {
                // `1.5` continues the number; `0..n` and tuple-field
                // chains like `self.0.q` do not (so `.lock()` after a
                // tuple index still tokenizes as a method call).
                self.i += 1;
            } else if (c == b'+' || c == b'-') && (prev == b'e' || prev == b'E') {
                // exponent sign: `1.5e-3`
                self.i += 1;
            } else {
                break;
            }
        }
        self.push(TokKind::Num, start, self.i, line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src)
            .toks
            .into_iter()
            .map(|t| (t.kind, t.text))
            .collect()
    }

    #[test]
    fn idents_and_puncts() {
        let toks = kinds("let x = a.unwrap();");
        let texts: Vec<&str> = toks.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(texts, ["let", "x", "=", "a", ".", "unwrap", "(", ")", ";"]);
        assert_eq!(toks[0].0, TokKind::Ident);
        assert_eq!(toks[2].0, TokKind::Punct);
    }

    #[test]
    fn comments_are_separated_with_lines() {
        let l = lex("a // one\n/* two\nlines */ b\n/// doc three\nc");
        let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, ["a", "b", "c"]);
        assert_eq!(l.comments.len(), 3);
        assert_eq!((l.comments[0].line, l.comments[0].text.as_str()), (1, "one"));
        assert_eq!(l.comments[1].line, 2);
        assert_eq!(l.comments[1].text, "two\nlines");
        assert_eq!((l.comments[2].line, l.comments[2].text.as_str()), (4, "doc three"));
        assert_eq!(l.toks[2].line, 5, "token after multi-line comment");
    }

    #[test]
    fn nested_block_comments() {
        let l = lex("a /* outer /* inner */ still */ b");
        let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, ["a", "b"]);
    }

    #[test]
    fn strings_hide_their_contents() {
        let l = lex(r#"f("no.unwrap() // here", 'x', "esc\"aped")"#);
        let idents: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["f"]);
        assert!(l.comments.is_empty());
        assert_eq!(
            l.toks.iter().filter(|t| t.kind == TokKind::Str).count(),
            2
        );
        assert_eq!(
            l.toks.iter().filter(|t| t.kind == TokKind::Char).count(),
            1
        );
    }

    #[test]
    fn raw_strings_with_hashes() {
        let l = lex(r##"let s = r#"quote " and // slash"# ; done"##);
        let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts[0], "let");
        assert_eq!(l.toks[3].kind, TokKind::Str);
        assert!(l.toks[3].text.starts_with("r#\""));
        assert_eq!(texts.last(), Some(&"done"));
        assert!(l.comments.is_empty(), "// inside a raw string is not a comment");
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let l = lex(r#"(b"P5\n", b'\n', br"raw")"#);
        assert_eq!(
            l.toks.iter().filter(|t| t.kind == TokKind::Str).count(),
            2
        );
        assert_eq!(
            l.toks.iter().filter(|t| t.kind == TokKind::Char).count(),
            1
        );
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) { g('a', '\\n', 'static') }");
        let lifetimes: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, ["'a", "'a"]);
        let chars: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Char)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(chars, ["'a'", "'\\n'"]);
        // 'static' (quoted above as a 7-char token) is NOT valid Rust;
        // the lexer reads it as the lifetime 'static followed by a
        // stray quote — degradation, not a panic.
        assert!(l.toks.iter().any(|t| t.text == "'static"));
    }

    #[test]
    fn nested_generics_stay_flat_puncts() {
        let l = lex("let m: HashMap<u64, Vec<Arc<Member>>> = HashMap::new();");
        let gt = l.toks.iter().filter(|t| t.text == ">").count();
        assert_eq!(gt, 3, "each closing angle is its own punct");
        assert!(l.toks.iter().all(|t| t.kind != TokKind::Str));
    }

    #[test]
    fn numbers_with_exponents_and_ranges() {
        let l = lex("a(1e3, 4.0f64, 1.5e-3, 0x2F, 0..n, 18_446_744_073_709_551_616.0)");
        let nums: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(
            nums,
            ["1e3", "4.0f64", "1.5e-3", "0x2F", "0", "18_446_744_073_709_551_616.0"]
        );
    }

    #[test]
    fn raw_identifiers() {
        let l = lex("let r#type = 1;");
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "r#type"));
    }

    #[test]
    fn line_numbers_across_multiline_strings() {
        let l = lex("let a = \"one\ntwo\";\nlet b = 1;");
        let b = l.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 3);
    }

    #[test]
    fn line_numbers_across_string_continuations() {
        // A `\` at end of line inside a string skips the newline but
        // must still count it.
        let l = lex("let a = \"one \\\n two\";\nlet b = 1;");
        let b = l.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 3);
    }
}
