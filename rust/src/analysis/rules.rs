//! The invariant rules. Each rule is one function over a lexed file
//! (plus one corpus-wide pass for atomics pairing), pattern-matching
//! short token windows — no type information, no name resolution. The
//! rules are deliberately conservative heuristics: every one encodes an
//! incident this repo actually shipped (see the rule docs and the
//! ROADMAP "Invariant analysis" table), and every deliberate exception
//! carries an inline `// analyze::allow(rule-id): reason` annotation.
//!
//! Known limitations (by design, documented here once):
//!
//! * Guard tracking follows `let NAME = <expr ending in .lock()/.read()
//!   /.write() [+ .unwrap()/.expect(..)/.unwrap_or_else(..)]>;` bindings
//!   only. Guards bound through match-arm patterns (`match m.lock() {
//!   Ok(g) => g.recv(), .. }`) or tuple patterns are not tracked.
//! * Lock names are the field identifier before the acquisition call
//!   (`self.inner.topology.write()` → `topology`), so the lock-order
//!   rule keys on the fleet's documented field names.
//! * The narrowing rule is type-blind: it flags every integer `as` cast
//!   in decode-path functions and relies on annotations for verified
//!   widenings. That cost is the point — each annotation states WHY the
//!   cast cannot alias.

use std::collections::{BTreeMap, BTreeSet};

use super::tokenizer::{Tok, TokKind};
use super::{FileCx, Finding};

/// Rule ids, exactly as they appear in findings and in
/// `analyze::allow(...)` annotations.
pub const RULE_IDS: &[&str] = &[
    "no-panic-on-wire",
    "no-as-narrowing-in-decode",
    "duration-through-bounds",
    "lock-order",
    "atomics-pairing",
    "no-guard-across-block",
];

fn is_ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

fn is_punct(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

/// Does `path` (normalized to `/` separators) name one of `files`?
fn file_is(path: &str, files: &[&str]) -> bool {
    files.iter().any(|f| path.ends_with(f))
}

/// Token index of the `)` matching the `(` at `open` (which must be a
/// `(`), or `toks.len()` when unbalanced.
fn matching_close(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (i, t) in toks.iter().enumerate().skip(open) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" => depth += 1,
                ")" => {
                    depth -= 1;
                    if depth == 0 {
                        return i;
                    }
                }
                _ => {}
            }
        }
    }
    toks.len()
}

// ------------------------------------------------- no-panic-on-wire --

/// Files whose non-test code faces the wire: every byte they handle may
/// come from a hostile peer, so a panic is a remote denial of service.
const WIRE_FILES: &[&str] = &["net/protocol.rs", "net/server.rs"];

/// Keywords that legitimately precede a `[` without forming an index
/// expression (slice patterns, array expressions in returns, …).
const NON_INDEX_KEYWORDS: &[&str] = &[
    "let", "in", "return", "match", "mut", "ref", "else", "move", "box",
];

/// **no-panic-on-wire** — no `unwrap`/`expect`/`panic!`-family/slice
/// indexing in `net::protocol` decode paths or `net::server` dispatch.
///
/// Incident: the PR 6 review pass found five remote-panic paths in the
/// wire tier (a hostile `deadline_ms` reaching `Duration::from_secs_f64`
/// among them) plus an unflagged sixth — every one a connection-handler
/// panic a single malformed frame could trigger.
pub(crate) fn no_panic_on_wire(cx: &FileCx, out: &mut Vec<Finding>) {
    if !file_is(&cx.path, WIRE_FILES) {
        return;
    }
    let t = &cx.toks;
    let mut seen = BTreeSet::new();
    let mut push = |line: u32, msg: String, seen: &mut BTreeSet<u32>| {
        if seen.insert(line) {
            out.push(Finding::new(&cx.path, line, "no-panic-on-wire", msg));
        }
    };
    for i in 0..t.len() {
        if cx.is_test[i] {
            continue;
        }
        let tok = &t[i];
        if tok.kind == TokKind::Ident
            && (tok.text == "unwrap" || tok.text == "expect")
            && i > 0
            && is_punct(&t[i - 1], ".")
            && t.get(i + 1).is_some_and(|n| is_punct(n, "("))
        {
            push(
                tok.line,
                format!(
                    "`.{}()` on the wire path panics the connection handler on hostile or \
                     truncated input (the PR 6 remote-panic class); return a typed error",
                    tok.text
                ),
                &mut seen,
            );
        }
        if tok.kind == TokKind::Ident
            && matches!(
                tok.text.as_str(),
                "panic" | "unreachable" | "todo" | "unimplemented"
            )
            && t.get(i + 1).is_some_and(|n| is_punct(n, "!"))
        {
            push(
                tok.line,
                format!(
                    "`{}!` on the wire path is a remote denial of service (the PR 6 \
                     remote-panic class); return a typed error",
                    tok.text
                ),
                &mut seen,
            );
        }
        if is_punct(tok, "[") && i > 0 {
            let p = &t[i - 1];
            let indexes = match p.kind {
                TokKind::Ident => !NON_INDEX_KEYWORDS.contains(&p.text.as_str()),
                TokKind::Punct => p.text == "]" || p.text == ")",
                _ => false,
            };
            if indexes {
                push(
                    tok.line,
                    "slice indexing on the wire path panics on short input (the PR 6 \
                     `read_payload` bounds class); use `get(..)`/length checks"
                        .to_string(),
                    &mut seen,
                );
            }
        }
    }
}

// ------------------------------------------ no-as-narrowing-in-decode --

/// Files whose decode paths turn untrusted bytes into typed values.
const DECODE_FILES: &[&str] = &[
    "net/protocol.rs",
    "net/server.rs",
    "codec/json.rs",
    "codec/toml.rs",
];

const INT_TYPES: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];

/// Encode-side functions are exempt: they cast values this process
/// produced, not values a peer chose.
fn is_encode_fn(name: &str) -> bool {
    name.starts_with("encode")
        || name.starts_with("to_")
        || name.starts_with("write")
        || name.starts_with("escape")
        || name.starts_with("fmt")
        || name.ends_with("_to_json")
}

/// For each token, the name of the innermost `fn` whose body contains
/// it (empty string at module scope). A flat, brace-depth-driven pass:
/// after `fn NAME`, the first `{` at paren/bracket depth 0 opens the
/// body.
struct FnSpans {
    /// Interned function names; index 0 is the empty "no fn" name.
    names: Vec<String>,
    /// Per-token index into `names`.
    of: Vec<usize>,
}

impl FnSpans {
    fn name_at(&self, i: usize) -> &str {
        &self.names[self.of[i]]
    }
}

fn fn_spans(toks: &[Tok]) -> FnSpans {
    let mut names_of = vec![0usize; toks.len()];
    let mut names: Vec<String> = vec![String::new()];
    let mut stack: Vec<(usize, usize)> = Vec::new(); // (name idx, depth at open)
    let mut pending: Option<usize> = None;
    let mut sig_depth = 0usize;
    let mut depth = 0usize;
    for (i, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" => {
                    if pending.is_some() {
                        sig_depth += 1;
                    }
                }
                ")" | "]" => {
                    if pending.is_some() {
                        sig_depth = sig_depth.saturating_sub(1);
                    }
                }
                ";" => {
                    if sig_depth == 0 {
                        pending = None; // trait method declaration
                    }
                }
                "{" => {
                    depth += 1;
                    if sig_depth == 0 {
                        if let Some(n) = pending.take() {
                            stack.push((n, depth));
                        }
                    }
                }
                "}" => {
                    if stack.last().is_some_and(|&(_, d)| d == depth) {
                        stack.pop();
                    }
                    depth = depth.saturating_sub(1);
                }
                _ => {}
            }
        } else if is_ident(t, "fn") {
            if let Some(n) = toks.get(i + 1) {
                if n.kind == TokKind::Ident {
                    names.push(n.text.clone());
                    pending = Some(names.len() - 1);
                    sig_depth = 0;
                }
            }
        }
        names_of[i] = stack.last().map_or(0, |&(n, _)| n);
    }
    FnSpans { names, of: names_of }
}

/// **no-as-narrowing-in-decode** — no integer `as` casts in decode-path
/// functions of the wire/codec files; use `try_from` (or annotate a
/// verified widening with the reason it cannot alias).
///
/// Incident: PR 6's hardening pass found the wire `scale` field decoded
/// with `as u32`, so a hostile `scale: 2^32 + 2` aliased to `2` and
/// produced a "valid" response for an absurd request instead of a typed
/// rejection.
pub(crate) fn no_as_narrowing(cx: &FileCx, out: &mut Vec<Finding>) {
    if !file_is(&cx.path, DECODE_FILES) {
        return;
    }
    let t = &cx.toks;
    let spans = fn_spans(t);
    for i in 0..t.len() {
        if cx.is_test[i] {
            continue;
        }
        if is_ident(&t[i], "as")
            && t.get(i + 1)
                .is_some_and(|n| n.kind == TokKind::Ident && INT_TYPES.contains(&n.text.as_str()))
        {
            let f = spans.name_at(i);
            if f.is_empty() || is_encode_fn(f) {
                continue;
            }
            out.push(Finding::new(
                &cx.path,
                t[i].line,
                "no-as-narrowing-in-decode",
                format!(
                    "`as {}` in decode path `{f}` silently truncates out-of-range wire values \
                     (the PR 6 `scale` 2^32+2 -> 2 aliasing bug); use `try_from`, or annotate \
                     why this cast cannot narrow",
                    t[i + 1].text
                ),
            ));
        }
    }
}

// --------------------------------------------- duration-through-bounds --

/// **duration-through-bounds** — never call the panicking float Duration
/// constructors directly; route wire/config milliseconds through
/// `net::protocol::duration_from_ms` (typed rejection) or
/// `saturating_duration_from_ms` (clamp-to-bounds).
///
/// Incident: `f64::clamp` passes NaN through, so a hostile `deadline_ms:
/// NaN` survived a `clamp(0.0, 5000.0)` "bound" and reached
/// `Duration::from_secs_f64`, which panics on NaN — the sixth remote
/// panic of the PR 6 class, found only after the first five were fixed.
/// (`Duration::from_millis` takes a `u64` and cannot panic, so it is
/// not flagged — the rule covers the constructors with panic paths.)
pub(crate) fn duration_through_bounds(cx: &FileCx, out: &mut Vec<Finding>) {
    for (i, t) in cx.toks.iter().enumerate() {
        if cx.is_test[i] {
            continue;
        }
        if t.kind == TokKind::Ident
            && (t.text == "from_secs_f64" || t.text == "from_secs_f32")
        {
            out.push(Finding::new(
                &cx.path,
                t.line,
                "duration-through-bounds",
                format!(
                    "`Duration::{}` panics on NaN/negative/overflowing input and clamp passes \
                     NaN through (the PR 6 `deadline_ms` incident); route milliseconds through \
                     `net::protocol::duration_from_ms` or `saturating_duration_from_ms`",
                    t.text
                ),
            ));
        }
    }
}

// ------------------------------------------------------- guard tracking --

/// A tracked lock guard: a `let`-binding whose initializer ends in a
/// no-arg `.lock()`/`.read()`/`.write()` call (optionally unwrapped).
/// Live from the end of its `let` statement to an explicit
/// `drop(binding)` or the close of its enclosing block.
pub(crate) struct Guard {
    /// The `let` binding name (`guard`, `st`, `topo`, …).
    pub binding: String,
    /// The lock field acquired (`topology`, `retiring`, `plan`, …).
    pub lock: String,
    /// Source line of the acquisition.
    pub line: u32,
    /// First token index at which the guard is live (the `;` of the
    /// `let` statement).
    pub start: usize,
    /// Token index at which it dies (a `drop` or a closing `}`).
    pub end: usize,
}

impl Guard {
    fn live_at(&self, i: usize) -> bool {
        self.start < i && i < self.end
    }
}

/// Method names that may tail a lock-acquisition chain without changing
/// what the binding holds.
const UNWRAPPERS: &[&str] = &["unwrap", "expect", "unwrap_or_else"];

/// Walk a statement's tail backwards from its `;` and return the lock
/// field name if the chain ends in `.lock()`/`.read()`/`.write()`
/// (no-arg), seen through any [`UNWRAPPERS`] suffix.
fn lock_chain_tail(toks: &[Tok], semi: usize) -> Option<String> {
    let mut k = semi.checked_sub(1)?;
    loop {
        if !is_punct(&toks[k], ")") {
            return None;
        }
        // find the matching `(` backwards
        let mut depth = 0isize;
        let mut open = k;
        loop {
            match toks[open].text.as_str() {
                ")" if toks[open].kind == TokKind::Punct => depth += 1,
                "(" if toks[open].kind == TokKind::Punct => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            open = open.checked_sub(1)?;
        }
        if open < 2 || !is_punct(&toks[open - 2], ".") || toks[open - 1].kind != TokKind::Ident {
            return None;
        }
        let method = toks[open - 1].text.as_str();
        if UNWRAPPERS.contains(&method) {
            k = open.checked_sub(3)?;
            continue;
        }
        if matches!(method, "lock" | "read" | "write") && k == open + 1 {
            // no-arg call: `.lock()` — `.read(&mut buf)` never matches
            let recv = &toks[open.checked_sub(3)?];
            return Some(if recv.kind == TokKind::Ident {
                recv.text.clone()
            } else {
                "<expr>".to_string()
            });
        }
        return None;
    }
}

/// Track every guard binding in the file. See the module doc for the
/// (deliberate) limitations.
pub(crate) fn track_guards(cx: &FileCx) -> Vec<Guard> {
    let t = &cx.toks;
    let mut guards: Vec<Guard> = Vec::new();
    let mut live: Vec<usize> = Vec::new(); // indices into guards
    let mut depth_of: Vec<usize> = Vec::new(); // parallel to live
    let mut depth = 0usize;
    let mut i = 0usize;
    while i < t.len() {
        let tok = &t[i];
        if tok.kind == TokKind::Punct {
            match tok.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    let mut j = 0;
                    while j < live.len() {
                        if depth_of[j] == depth {
                            guards[live[j]].end = i;
                            live.remove(j);
                            depth_of.remove(j);
                        } else {
                            j += 1;
                        }
                    }
                    depth = depth.saturating_sub(1);
                }
                _ => {}
            }
            i += 1;
            continue;
        }
        // drop(binding) ends a guard early
        if is_ident(tok, "drop")
            && t.get(i + 1).is_some_and(|n| is_punct(n, "("))
            && t.get(i + 3).is_some_and(|n| is_punct(n, ")"))
        {
            if let Some(name) = t.get(i + 2).filter(|n| n.kind == TokKind::Ident) {
                let mut j = 0;
                while j < live.len() {
                    if guards[live[j]].binding == name.text {
                        guards[live[j]].end = i;
                        live.remove(j);
                        depth_of.remove(j);
                    } else {
                        j += 1;
                    }
                }
            }
            i += 1;
            continue;
        }
        if is_ident(tok, "let") {
            // `if let` / `while let` bind through patterns and their
            // "initializer" ends at `{`, not `;` — out of scope.
            if i > 0 && (is_ident(&t[i - 1], "if") || is_ident(&t[i - 1], "while")) {
                i += 1;
                continue;
            }
            // binding name: `let [mut] NAME …`
            let mut j = i + 1;
            if t.get(j).is_some_and(|n| is_ident(n, "mut")) {
                j += 1;
            }
            let name = match t.get(j) {
                Some(n) if n.kind == TokKind::Ident => n.text.clone(),
                _ => {
                    i += 1;
                    continue; // tuple / struct pattern: not tracked
                }
            };
            // find `=` (skipping a `: Type` annotation), then the
            // statement-ending `;` at balanced nesting
            let mut k = j + 1;
            let mut nest = 0isize;
            let mut eq = None;
            while let Some(n) = t.get(k) {
                match n.text.as_str() {
                    "(" | "[" | "{" if n.kind == TokKind::Punct => nest += 1,
                    ")" | "]" | "}" if n.kind == TokKind::Punct => nest -= 1,
                    "=" if n.kind == TokKind::Punct && nest == 0 => {
                        // `==`/`=>`/`<=` never appear here at nest 0
                        eq = Some(k);
                        break;
                    }
                    ";" if n.kind == TokKind::Punct && nest == 0 => break,
                    _ => {}
                }
                k += 1;
            }
            let Some(eq) = eq else {
                i += 1;
                continue;
            };
            // A closure initializer (`let f = || m.lock()...;`) defers
            // the acquisition to each call site — the binding is a
            // closure, not a guard.
            if t.get(eq + 1)
                .is_some_and(|n| is_punct(n, "|") || is_ident(n, "move"))
            {
                i = eq + 1;
                continue;
            }
            let mut semi = eq + 1;
            let mut nest = 0isize;
            let mut found = false;
            while let Some(n) = t.get(semi) {
                match n.text.as_str() {
                    "(" | "[" | "{" if n.kind == TokKind::Punct => nest += 1,
                    ")" | "]" | "}" if n.kind == TokKind::Punct => nest -= 1,
                    ";" if n.kind == TokKind::Punct && nest == 0 => {
                        found = true;
                        break;
                    }
                    _ => {}
                }
                if nest < 0 {
                    break; // escaped the enclosing block — no `;` belongs to this `let`
                }
                semi += 1;
            }
            if found {
                if let Some(lock) = lock_chain_tail(t, semi) {
                    guards.push(Guard {
                        binding: name,
                        lock,
                        line: tok.line,
                        start: semi,
                        end: t.len(),
                    });
                    live.push(guards.len() - 1);
                    depth_of.push(depth);
                }
                i = semi + 1;
                continue;
            }
        }
        i += 1;
    }
    guards
}

// --------------------------------------------------------- lock-order --

/// The fleet's documented acquisition orders, first-then-second.
/// `rebuild_plan` publishes under plan-write then topology-read; every
/// stats/removal path takes topology then retiring.
const LOCK_ORDERS: &[(&str, &str)] = &[("plan", "topology"), ("topology", "retiring")];

/// The lock fields the ordering contract tracks (re-acquiring any of
/// these while already holding it self-deadlocks: std's RwLock/Mutex
/// are not reentrant).
const ORDERED_LOCKS: &[&str] = &["plan", "topology", "retiring"];

/// **lock-order** — within one function body, never acquire `plan`
/// while holding `topology`, or `topology` while holding `retiring`
/// (the documented orders run the other way), never re-acquire a
/// tracked lock you already hold, and never call `rebuild_plan()` with
/// a `plan`/`topology` guard live (it takes plan-write then
/// topology-read itself).
///
/// Incident: PR 8's audit found `rebuild_plan` called with the topology
/// write guard still live — a guaranteed self-deadlock on the
/// non-reentrant RwLock — and fixed it with an explicit `drop(guard)`;
/// this rule pins that contract so the next refactor cannot undo it.
pub(crate) fn lock_order(cx: &FileCx, guards: &[Guard], out: &mut Vec<Finding>) {
    let t = &cx.toks;
    for i in 0..t.len() {
        if cx.is_test[i] {
            continue;
        }
        // an acquisition site: IDENT . (lock|read|write) ( )
        if t[i].kind == TokKind::Ident
            && matches!(t[i].text.as_str(), "lock" | "read" | "write")
            && i >= 2
            && is_punct(&t[i - 1], ".")
            && t[i - 2].kind == TokKind::Ident
            && t.get(i + 1).is_some_and(|n| is_punct(n, "("))
            && t.get(i + 2).is_some_and(|n| is_punct(n, ")"))
        {
            let acquired = t[i - 2].text.as_str();
            if !ORDERED_LOCKS.contains(&acquired) {
                continue;
            }
            for g in guards.iter().filter(|g| g.live_at(i)) {
                if g.lock == acquired {
                    out.push(Finding::new(
                        &cx.path,
                        t[i].line,
                        "lock-order",
                        format!(
                            "re-acquiring `{acquired}` while the guard from line {} is still \
                             held self-deadlocks (std locks are not reentrant; the PR 8 \
                             `rebuild_plan` contract) — drop the guard first",
                            g.line
                        ),
                    ));
                }
                for (first, second) in LOCK_ORDERS {
                    if g.lock == *second && acquired == *first {
                        out.push(Finding::new(
                            &cx.path,
                            t[i].line,
                            "lock-order",
                            format!(
                                "acquiring `{first}` while holding `{second}` (line {}) inverts \
                                 the documented `{first}` -> `{second}` order and can deadlock \
                                 against the writers that follow it",
                                g.line
                            ),
                        ));
                    }
                }
            }
        }
        // calling rebuild_plan() re-acquires plan-write then
        // topology-read internally
        if is_ident(&t[i], "rebuild_plan")
            && t.get(i + 1).is_some_and(|n| is_punct(n, "("))
            && i > 0
            && !is_ident(&t[i - 1], "fn")
        {
            for g in guards.iter().filter(|g| g.live_at(i)) {
                if g.lock == "topology" || g.lock == "plan" {
                    out.push(Finding::new(
                        &cx.path,
                        t[i].line,
                        "lock-order",
                        format!(
                            "`rebuild_plan()` takes the plan write lock then the topology read \
                             lock; calling it while the `{}` guard from line {} is live \
                             self-deadlocks (the PR 8 contract) — drop the guard first",
                            g.lock, g.line
                        ),
                    ));
                }
            }
        }
    }
}

// ---------------------------------------------------- atomics-pairing --

const ATOMIC_WRITE_OPS: &[&str] = &[
    "store",
    "swap",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_or",
    "fetch_xor",
    "fetch_max",
    "fetch_min",
    "fetch_update",
    "compare_exchange",
    "compare_exchange_weak",
];

const RELEASING: &[&str] = &["Release", "AcqRel", "SeqCst"];

/// **atomics-pairing** — corpus-wide: an atomic field that is stored
/// with Release (or stronger) ordering anywhere must never be loaded
/// `Relaxed` elsewhere. Keys on the field name; test code (and
/// tests-dir files, whose same-named locals are different objects) is
/// out of scope.
///
/// Incident: the `plan_version` protocol — the submit path pairs an
/// Acquire load with `rebuild_plan`'s Release store to see the plan the
/// version stamps; a Relaxed load there would let a submitter race a
/// republish and tag a response with a version from a plan it never
/// read. The one deliberate Relaxed load (under the plan write lock) is
/// annotated.
pub(crate) fn atomics_pairing(cxs: &[FileCx], out: &mut Vec<Finding>) {
    struct Sites {
        release_store: Option<(String, u32)>,
        relaxed_loads: Vec<(String, u32)>,
    }
    let mut fields: BTreeMap<String, Sites> = BTreeMap::new();
    for cx in cxs {
        if cx.in_tests_dir {
            continue;
        }
        let t = &cx.toks;
        for i in 0..t.len() {
            if cx.is_test[i] {
                continue;
            }
            let tok = &t[i];
            if tok.kind != TokKind::Ident
                || i < 2
                || !is_punct(&t[i - 1], ".")
                || t[i - 2].kind != TokKind::Ident
                || !t.get(i + 1).is_some_and(|n| is_punct(n, "("))
            {
                continue;
            }
            let is_load = tok.text == "load";
            let is_write = ATOMIC_WRITE_OPS.contains(&tok.text.as_str());
            if !is_load && !is_write {
                continue;
            }
            let close = matching_close(t, i + 1);
            let orderings: Vec<&str> = t[i + 1..close.min(t.len())]
                .iter()
                .filter(|n| {
                    n.kind == TokKind::Ident
                        && matches!(
                            n.text.as_str(),
                            "Relaxed" | "Release" | "Acquire" | "AcqRel" | "SeqCst"
                        )
                })
                .map(|n| n.text.as_str())
                .collect();
            if orderings.is_empty() {
                continue; // not an atomic op (e.g. `rx.load(...)` of something else)
            }
            let field = t[i - 2].text.clone();
            let entry = fields.entry(field).or_insert(Sites {
                release_store: None,
                relaxed_loads: Vec::new(),
            });
            if is_write && orderings.iter().any(|o| RELEASING.contains(o)) {
                if entry.release_store.is_none() {
                    entry.release_store = Some((cx.path.clone(), tok.line));
                }
            } else if is_load && orderings == ["Relaxed"] {
                entry.relaxed_loads.push((cx.path.clone(), tok.line));
            }
        }
    }
    for (field, sites) in fields {
        let Some((spath, sline)) = sites.release_store else {
            continue;
        };
        for (lpath, lline) in sites.relaxed_loads {
            out.push(Finding::new(
                &lpath,
                lline,
                "atomics-pairing",
                format!(
                    "atomic `{field}` is stored with Release ordering at {spath}:{sline} but \
                     loaded Relaxed here — the load is unordered with the writer's publish \
                     protocol (the `plan_version` contract); use Acquire or annotate why \
                     Relaxed is sound"
                ),
            ));
        }
    }
}

// ------------------------------------------------ no-guard-across-block --

/// Calls that park the current thread.
const BLOCKING_CALLS: &[&str] = &[
    "wait",
    "wait_timeout",
    "join",
    "recv",
    "recv_timeout",
    "send_timeout",
    "sleep",
];

/// **no-guard-across-block** — a tracked lock guard must not be live
/// across a blocking call, unless the guard is handed TO the call
/// (the condvar protocol: `cv.wait(guard)` releases it atomically).
///
/// Incident: `Member::join_threads` held the member's `threads` mutex
/// across `JoinHandle::join`, so any thread touching the handle table
/// during a slow worker shutdown blocked for the worker's whole drain
/// — fixed in this PR by taking the handles out under the lock and
/// joining outside it.
pub(crate) fn guard_across_block(cx: &FileCx, guards: &[Guard], out: &mut Vec<Finding>) {
    let t = &cx.toks;
    for i in 0..t.len() {
        if cx.is_test[i] {
            continue;
        }
        if t[i].kind != TokKind::Ident
            || !BLOCKING_CALLS.contains(&t[i].text.as_str())
            || !t.get(i + 1).is_some_and(|n| is_punct(n, "("))
            || i == 0
            || !(is_punct(&t[i - 1], ".") || is_punct(&t[i - 1], ":"))
        {
            continue;
        }
        let close = matching_close(t, i + 1);
        for g in guards.iter().filter(|g| g.live_at(i)) {
            let handed_over = t[i + 1..close.min(t.len())]
                .iter()
                .any(|a| a.kind == TokKind::Ident && a.text == g.binding);
            if handed_over {
                continue; // condvar protocol: wait(guard) releases it
            }
            out.push(Finding::new(
                &cx.path,
                t[i].line,
                "no-guard-across-block",
                format!(
                    "`{}` blocks while the `{}` guard `{}` (line {}) is held, stalling every \
                     other acquirer for the full wait (the `join_threads` incident); drop the \
                     guard first",
                    t[i].text, g.lock, g.binding, g.line
                ),
            ));
        }
    }
}
