//! # `analysis` — the self-hosted invariant analyzer behind `tilekit analyze`
//!
//! Six of the last eight PRs in this repo were authored without a Rust
//! toolchain and verified by audit alone, and every audit found a slip
//! the previous one missed. This module turns the recurring classes of
//! slip — wire-path panics, narrowing decodes, unbounded Durations,
//! lock-order inversions, mismatched atomic orderings, guards held
//! across blocking calls — into machine-checked rules that run in CI,
//! in the same vendored-offline idiom as `codec::json`: a hand-rolled
//! tokenizer, no crate dependencies, no `syn`.
//!
//! ## Pipeline
//!
//! [`analyze_paths`] walks `.rs` files (skipping `target/`, `vendor/`,
//! `.git/`, and the deliberately-bad `analysis_fixtures/`), lexes each
//! with [`tokenizer::lex`] into a token stream plus a comment stream,
//! marks `#[cfg(test)] mod` spans, runs every rule in [`rules`], then
//! applies inline suppressions and returns a [`Report`]. The pure core
//! is [`analyze_corpus`], which takes `(path, source)` pairs directly —
//! tests feed it fixture text under pretend paths so path-scoped rules
//! fire without touching the real tree.
//!
//! ## Suppressions
//!
//! A finding on line N is suppressed by a comment
//!
//! ```text
//! // analyze::allow(rule-id): why this one is sound
//! ```
//!
//! on line N itself or on the closest preceding comment-only run (an
//! allow "covers" every line up to and including the next line that
//! carries code). The reason is mandatory: a bare
//! `// analyze::allow(rule-id)` is reported as `bare-allow`, and an
//! allow that matches no finding is reported as `unused-allow` under
//! `--strict` — so stale annotations rot loudly, not silently. Neither
//! meta-finding can itself be suppressed.
//!
//! ## Adding a rule
//!
//! 1. Write `fn my_rule(cx: &FileCx, out: &mut Vec<Finding>)` in
//!    `rules.rs` (take `&[Guard]` from [`rules::track_guards`] if you
//!    need lock-guard liveness, or see `rules::atomics_pairing` for a
//!    corpus-wide pass). Skip tokens with `cx.is_test[i]` set.
//! 2. Add its id to [`rules::RULE_IDS`] (this is what makes
//!    `analyze::allow(my-rule)` valid) and call it from
//!    [`analyze_corpus`].
//! 3. Document the motivating incident in the rule's doc comment and
//!    the ROADMAP "Invariant analysis" table. A rule that doesn't
//!    encode a real incident is a lint, and lints belong in clippy.
//! 4. Add a known-bad and a known-clean fixture under
//!    `rust/tests/analysis_fixtures/` and assert the bad one produces
//!    exactly your finding (see `rust/tests/analysis.rs`).
//! 5. Re-run `tilekit analyze --strict` over the tree and fix or
//!    annotate every new true positive before committing — the rule
//!    ships together with its cleanup, never ahead of it.

pub mod rules;
pub mod tokenizer;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use tokenizer::{Comment, Tok, TokKind};

/// One reported violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path as analyzed (normalized to `/` separators).
    pub path: String,
    /// 1-based source line.
    pub line: u32,
    /// Rule id (one of [`rules::RULE_IDS`], or `bare-allow` /
    /// `unused-allow` for suppression-hygiene findings).
    pub rule: &'static str,
    /// Human rationale: what is wrong and which incident it re-creates.
    pub message: String,
}

impl Finding {
    pub(crate) fn new(path: &str, line: u32, rule: &'static str, message: String) -> Self {
        Finding { path: path.to_string(), line, rule, message }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// The result of an analysis run.
#[derive(Debug, Default)]
pub struct Report {
    /// Unsuppressed findings, sorted by (path, line, rule).
    pub findings: Vec<Finding>,
    /// Number of files analyzed.
    pub files: usize,
    /// Number of findings silenced by `analyze::allow` annotations.
    pub suppressed: usize,
}

impl Report {
    /// True when the run found nothing actionable.
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Per-file context handed to every rule.
pub struct FileCx {
    /// Normalized (`/`-separated) path, used for path-scoped rules.
    pub path: String,
    /// The token stream (comments excluded — see `comments`).
    pub toks: Vec<Tok>,
    /// The comment stream, for suppression parsing.
    pub comments: Vec<Comment>,
    /// `is_test[i]` — token `i` lies inside a `#[cfg(test)] mod` body.
    pub is_test: Vec<bool>,
    /// File lives under a `tests/` directory (integration tests).
    pub in_tests_dir: bool,
}

/// Mark tokens inside `#[cfg(test)] mod NAME { ... }` bodies. Rules
/// skip these: test code panics on purpose.
fn test_spans(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        // #[cfg(test)]
        let is_cfg_test = toks[i].kind == TokKind::Punct
            && toks[i].text == "#"
            && toks.get(i + 1).is_some_and(|t| t.kind == TokKind::Punct && t.text == "[")
            && toks.get(i + 2).is_some_and(|t| t.kind == TokKind::Ident && t.text == "cfg")
            && toks.get(i + 3).is_some_and(|t| t.kind == TokKind::Punct && t.text == "(")
            && toks.get(i + 4).is_some_and(|t| t.kind == TokKind::Ident && t.text == "test")
            && toks.get(i + 5).is_some_and(|t| t.kind == TokKind::Punct && t.text == ")")
            && toks.get(i + 6).is_some_and(|t| t.kind == TokKind::Punct && t.text == "]");
        if !is_cfg_test {
            i += 1;
            continue;
        }
        // Skip any further attributes, visibility, then require `mod`.
        let mut j = i + 7;
        loop {
            match toks.get(j) {
                Some(t) if t.kind == TokKind::Punct && t.text == "#" => {
                    // skip the whole #[...] group
                    let mut depth = 0usize;
                    j += 1;
                    while let Some(t) = toks.get(j) {
                        if t.kind == TokKind::Punct && t.text == "[" {
                            depth += 1;
                        } else if t.kind == TokKind::Punct && t.text == "]" {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        j += 1;
                    }
                    j += 1;
                }
                Some(t) if t.kind == TokKind::Ident && t.text == "pub" => {
                    j += 1;
                    if toks.get(j).is_some_and(|t| t.kind == TokKind::Punct && t.text == "(") {
                        while let Some(t) = toks.get(j) {
                            if t.kind == TokKind::Punct && t.text == ")" {
                                break;
                            }
                            j += 1;
                        }
                        j += 1;
                    }
                }
                _ => break,
            }
        }
        let is_mod = toks.get(j).is_some_and(|t| t.kind == TokKind::Ident && t.text == "mod");
        if !is_mod {
            i += 1;
            continue;
        }
        // find the opening `{` then mark to its matching `}`
        while let Some(t) = toks.get(j) {
            if t.kind == TokKind::Punct && t.text == "{" {
                break;
            }
            if t.kind == TokKind::Punct && t.text == ";" {
                break; // `mod name;` — out-of-line, nothing to mark
            }
            j += 1;
        }
        if !toks.get(j).is_some_and(|t| t.text == "{") {
            i = j;
            continue;
        }
        let mut depth = 0usize;
        let start = i;
        while let Some(t) = toks.get(j) {
            if t.kind == TokKind::Punct && t.text == "{" {
                depth += 1;
            } else if t.kind == TokKind::Punct && t.text == "}" {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            j += 1;
        }
        let end = j.min(toks.len().saturating_sub(1));
        for m in mask.iter_mut().take(end + 1).skip(start) {
            *m = true;
        }
        i = j + 1;
    }
    mask
}

/// A parsed `analyze::allow(...)` annotation.
struct Allow {
    line: u32,
    /// Last line this allow covers: the first line at or after `line`
    /// that carries a code token (so an allow on its own line covers
    /// the statement that follows it, and a trailing allow covers its
    /// own line).
    covers_to: u32,
    rule: String,
    has_reason: bool,
    used: bool,
}

const ALLOW_MARKER: &str = "analyze::allow";

/// Parse allows out of a file's comments and compute their coverage.
///
/// The annotation must START the comment (`// analyze::allow(..): ..`)
/// — mid-comment mentions are prose (this very module's docs talk
/// about the syntax) and are not parsed.
fn parse_allows(cx: &FileCx, out: &mut Vec<Finding>) -> Vec<Allow> {
    let mut allows = Vec::new();
    for c in &cx.comments {
        let Some(rest) = c.text.trim_start().strip_prefix(ALLOW_MARKER) else {
            continue;
        };
        let rest = rest.trim_start();
        let Some(stripped) = rest.strip_prefix('(') else {
            out.push(Finding::new(
                &cx.path,
                c.line,
                "bare-allow",
                "malformed `analyze::allow` — expected `analyze::allow(rule-id): reason`"
                    .to_string(),
            ));
            continue;
        };
        let Some(close) = stripped.find(')') else {
            out.push(Finding::new(
                &cx.path,
                c.line,
                "bare-allow",
                "malformed `analyze::allow` — missing `)` after rule id".to_string(),
            ));
            continue;
        };
        let rule = stripped[..close].trim().to_string();
        if !rules::RULE_IDS.contains(&rule.as_str()) {
            out.push(Finding::new(
                &cx.path,
                c.line,
                "bare-allow",
                format!("unknown rule id `{rule}` in `analyze::allow`"),
            ));
            continue;
        }
        let after = stripped[close + 1..].trim_start();
        let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
        if reason.is_empty() {
            out.push(Finding::new(
                &cx.path,
                c.line,
                "bare-allow",
                format!(
                    "`analyze::allow({rule})` without a reason — state why this exception is \
                     sound: `analyze::allow({rule}): reason`"
                ),
            ));
            continue;
        }
        // Coverage: up to and including the first code-bearing line >= c.line.
        let covers_to = cx
            .toks
            .iter()
            .map(|t| t.line)
            .find(|&l| l >= c.line)
            .unwrap_or(c.line);
        allows.push(Allow { line: c.line, covers_to, rule, has_reason: true, used: false });
    }
    allows
}

/// Run every rule over one lexed file (the per-file rules; the
/// corpus-wide atomics pass runs in [`analyze_corpus`]).
fn run_file_rules(cx: &FileCx, out: &mut Vec<Finding>) {
    rules::no_panic_on_wire(cx, out);
    rules::no_as_narrowing(cx, out);
    rules::duration_through_bounds(cx, out);
    let guards = rules::track_guards(cx);
    rules::lock_order(cx, &guards, out);
    rules::guard_across_block(cx, &guards, out);
}

/// Analyze in-memory `(path, source)` pairs. The pure core of the
/// subsystem: `analyze` the CLI subcommand is a directory walk plus
/// this function, and tests call it directly with fixture text under
/// pretend paths so path-scoped rules fire.
pub fn analyze_corpus(files: &[(String, String)], strict: bool) -> Report {
    let mut cxs = Vec::with_capacity(files.len());
    for (path, src) in files {
        let path = path.replace('\\', "/");
        let lexed = tokenizer::lex(src);
        let is_test = test_spans(&lexed.toks);
        let in_tests_dir = path.contains("tests/");
        cxs.push(FileCx { path, toks: lexed.toks, comments: lexed.comments, is_test, in_tests_dir });
    }
    let mut raw: Vec<Finding> = Vec::new();
    for cx in &cxs {
        run_file_rules(cx, &mut raw);
    }
    rules::atomics_pairing(&cxs, &mut raw);

    // Apply suppressions per file. Meta-findings (bare-allow,
    // unused-allow) are appended unsuppressable.
    let mut findings: Vec<Finding> = Vec::new();
    let mut suppressed = 0usize;
    for cx in &cxs {
        let mut meta: Vec<Finding> = Vec::new();
        let mut allows = parse_allows(cx, &mut meta);
        for f in raw.iter().filter(|f| f.path == cx.path) {
            let hit = allows.iter_mut().find(|a| {
                a.rule == f.rule && a.has_reason && a.line <= f.line && f.line <= a.covers_to
            });
            match hit {
                Some(a) => {
                    a.used = true;
                    suppressed += 1;
                }
                None => findings.push(f.clone()),
            }
        }
        if strict {
            for a in allows.iter().filter(|a| !a.used) {
                meta.push(Finding::new(
                    &cx.path,
                    a.line,
                    "unused-allow",
                    format!(
                        "`analyze::allow({})` suppresses nothing — the finding it covered is \
                         gone; delete the annotation",
                        a.rule
                    ),
                ));
            }
        }
        findings.append(&mut meta);
    }
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    Report { findings, files: cxs.len(), suppressed }
}

/// Directory names never descended into.
const SKIP_DIRS: &[&str] = &["target", "vendor", ".git", "analysis_fixtures"];

fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(root)
        .with_context(|| format!("analyze: cannot read {}", root.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if SKIP_DIRS.contains(&name) {
                continue;
            }
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Walk `paths` (files or directories), lex every `.rs` file, and
/// analyze the corpus. Deterministic: files are visited in sorted
/// order and findings are sorted.
pub fn analyze_paths(paths: &[PathBuf], strict: bool) -> Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        if p.is_dir() {
            collect_rs(p, &mut files)?;
        } else if p.is_file() {
            files.push(p.clone());
        } else {
            bail!("analyze: no such path: {}", p.display());
        }
    }
    files.sort();
    files.dedup();
    let mut corpus = Vec::with_capacity(files.len());
    for f in &files {
        let src = std::fs::read_to_string(f)
            .with_context(|| format!("analyze: cannot read {}", f.display()))?;
        corpus.push((f.to_string_lossy().into_owned(), src));
    }
    Ok(analyze_corpus(&corpus, strict))
}
