//! A fixed-size thread pool with graceful shutdown, used by the
//! coordinator's worker stage and by the bench harness's parallel sweeps.

use super::channel::{bounded, Receiver, Sender};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool. Jobs are dispatched FIFO to idle workers; `drop`
/// (or [`ThreadPool::join`]) waits for queued jobs to finish.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool of `n` workers (≥1) with a job queue of `2n`.
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx): (Sender<Job>, Receiver<Job>) = bounded(2 * n);
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("tilekit-worker-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
        }
    }

    /// Submit a job; blocks if the queue is full (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool joined")
            .send(Box::new(job))
            .ok();
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Close the queue and wait for all workers to drain and exit.
    pub fn join(mut self) {
        self.join_inner();
    }

    fn join_inner(&mut self) {
        self.tx.take(); // closes the channel
        for w in self.workers.drain(..) {
            w.join().expect("worker panicked");
        }
    }

    /// Map `f` over `items` in parallel, preserving order. A convenience
    /// built on scoped threads (no 'static bound needed).
    pub fn scoped_map<T: Sync, R: Send>(
        n_threads: usize,
        items: &[T],
        f: impl Fn(&T) -> R + Sync,
    ) -> Vec<R> {
        let n_threads = n_threads.max(1).min(items.len().max(1));
        let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
        let next = std::sync::atomic::AtomicUsize::new(0);
        let out_ptr = std::sync::Mutex::new(&mut out);
        crossbeam_utils::thread::scope(|s| {
            for _ in 0..n_threads {
                s.spawn(|_| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let r = f(&items[i]);
                    out_ptr.lock().unwrap()[i] = Some(r);
                });
            }
        })
        .expect("scoped threads");
        out.into_iter().map(|o| o.expect("all filled")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if self.tx.is_some() {
            self.join_inner();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn all_jobs_run() {
        let pool = ThreadPool::new(4);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&count);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn drop_joins() {
        let count = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&count);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop here
        assert_eq!(count.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn scoped_map_preserves_order() {
        let items: Vec<u64> = (0..200).collect();
        let out = ThreadPool::scoped_map(8, &items, |&x| x * x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i * i) as u64);
        }
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        pool.join();
        let out = ThreadPool::scoped_map(0, &[1, 2, 3], |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
