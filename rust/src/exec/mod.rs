//! Execution substrate: a fixed-size thread pool and a bounded MPMC
//! channel (the offline environment has no tokio; the coordinator is a
//! thread-per-worker system, which at this scale is the simpler and
//! faster design anyway — see DESIGN.md §2).

pub mod channel;
pub mod pool;

pub use channel::{bounded, Receiver, RecvError, SendError, SendTimeoutError, Sender, TrySendError};
pub use pool::ThreadPool;
