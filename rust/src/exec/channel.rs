//! A bounded multi-producer/multi-consumer channel built on
//! `Mutex<VecDeque>` + condvars.
//!
//! Why not `std::sync::mpsc`? The coordinator needs (a) *multi-consumer*
//! receive (a worker pool pulling from one queue) and (b) *backpressure*
//! — a bounded queue whose `send` blocks (or `try_send` fails) when the
//! serving system is saturated. Both are first-class here and covered by
//! the coordinator's property tests.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

struct Shared<T> {
    q: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    /// Mirror of `q.buf.len()`, maintained under the queue lock but
    /// readable without it — `len()` is on the coordinator's lock-free
    /// submit path (every member's queue depth is read per request).
    depth: AtomicUsize,
}

impl<T> Shared<T> {
    /// Publish the new queue depth; call while holding the queue lock
    /// (all writers do, so the mirror never goes backwards in time).
    fn sync_depth(&self, st: &State<T>) {
        self.depth.store(st.buf.len(), Ordering::Release);
    }
}

struct State<T> {
    buf: VecDeque<T>,
    senders: usize,
    receivers: usize,
    /// Set by [`Sender::close`]: the channel refuses new sends even
    /// while live `Sender` clones exist, and receivers drain what is
    /// buffered and then see end-of-stream.
    closed: bool,
}

/// Sending half. Cloning adds a producer.
pub struct Sender<T>(Arc<Shared<T>>);

/// Receiving half. Cloning adds a consumer.
pub struct Receiver<T>(Arc<Shared<T>>);

/// Error returned by `send` when all receivers are gone.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Error returned by `try_send`.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// Queue at capacity (backpressure signal).
    Full(T),
    /// All receivers dropped.
    Disconnected(T),
}

/// Error returned by `send_timeout`.
#[derive(Debug, PartialEq, Eq)]
pub enum SendTimeoutError<T> {
    /// Queue stayed at capacity for the whole timeout.
    Timeout(T),
    /// All receivers dropped.
    Disconnected(T),
}

/// Error returned by `recv` when the queue is empty and all senders are
/// gone.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

/// Create a bounded channel of capacity `cap` (≥ 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap >= 1, "channel capacity must be >= 1");
    let shared = Arc::new(Shared {
        q: Mutex::new(State {
            buf: VecDeque::with_capacity(cap),
            senders: 1,
            receivers: 1,
            closed: false,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        cap,
        depth: AtomicUsize::new(0),
    });
    (Sender(Arc::clone(&shared)), Receiver(shared))
}

impl<T> Sender<T> {
    /// Close the channel for good: every subsequent send — from *any*
    /// `Sender` clone, including ones stashed in snapshots elsewhere —
    /// fails as disconnected, while receivers still drain whatever was
    /// buffered before seeing end-of-stream. Idempotent. This is the
    /// teardown primitive for owners that hand out sender clones they
    /// cannot collect back (the fleet's immutable submit plans).
    pub fn close(&self) {
        let mut st = self.0.q.lock().unwrap();
        if !st.closed {
            st.closed = true;
            drop(st);
            self.0.not_empty.notify_all();
            self.0.not_full.notify_all();
        }
    }

    /// Whether [`close`](Self::close) has been called (racy; metrics
    /// and assertions only).
    pub fn is_closed(&self) -> bool {
        self.0.q.lock().unwrap().closed
    }

    /// Blocking send; waits while full. Errors if all receivers dropped
    /// or the channel was closed.
    pub fn send(&self, v: T) -> Result<(), SendError<T>> {
        let mut st = self.0.q.lock().unwrap();
        loop {
            if st.receivers == 0 || st.closed {
                return Err(SendError(v));
            }
            if st.buf.len() < self.0.cap {
                st.buf.push_back(v);
                self.0.sync_depth(&st);
                drop(st);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            st = self.0.not_full.wait(st).unwrap();
        }
    }

    /// Blocking send with a deadline: waits for queue space at most `d`,
    /// parked on the `not_full` condvar (no sleep/poll loop). `Timeout`
    /// is the backpressure signal; the value is handed back in the error
    /// so callers can retry or shed it.
    pub fn send_timeout(&self, v: T, d: Duration) -> Result<(), SendTimeoutError<T>> {
        let deadline = std::time::Instant::now() + d;
        let mut st = self.0.q.lock().unwrap();
        loop {
            if st.receivers == 0 || st.closed {
                return Err(SendTimeoutError::Disconnected(v));
            }
            if st.buf.len() < self.0.cap {
                st.buf.push_back(v);
                self.0.sync_depth(&st);
                drop(st);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(SendTimeoutError::Timeout(v));
            }
            let (guard, _res) = self.0.not_full.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Non-blocking send: `Full` signals backpressure.
    pub fn try_send(&self, v: T) -> Result<(), TrySendError<T>> {
        let mut st = self.0.q.lock().unwrap();
        if st.receivers == 0 || st.closed {
            return Err(TrySendError::Disconnected(v));
        }
        if st.buf.len() >= self.0.cap {
            return Err(TrySendError::Full(v));
        }
        st.buf.push_back(v);
        self.0.sync_depth(&st);
        drop(st);
        self.0.not_empty.notify_one();
        Ok(())
    }

    /// Current queue depth (racy; for metrics only). Lock-free: reads
    /// the depth mirror, never the queue mutex.
    pub fn len(&self) -> usize {
        self.0.depth.load(Ordering::Acquire)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive. Errors once empty AND all senders dropped.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.0.q.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                self.0.sync_depth(&st);
                drop(st);
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if st.senders == 0 || st.closed {
                return Err(RecvError);
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with a timeout; `Ok(None)` on timeout.
    pub fn recv_timeout(&self, d: Duration) -> Result<Option<T>, RecvError> {
        let deadline = std::time::Instant::now() + d;
        let mut st = self.0.q.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                self.0.sync_depth(&st);
                drop(st);
                self.0.not_full.notify_one();
                return Ok(Some(v));
            }
            if st.senders == 0 || st.closed {
                return Err(RecvError);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, _res) = self
                .0
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
    }

    /// Remove and return selected items from anywhere in the queue
    /// (work-stealing). `select` sees a snapshot of the queued items
    /// (index 0 = oldest) and returns the indices to take; out-of-range
    /// and duplicate indices are ignored. The relative order of the
    /// remaining items is preserved, and removals free capacity (the
    /// `not_full` waiters are woken). Returned items are oldest-first.
    pub fn steal_by(&self, select: impl FnOnce(&VecDeque<T>) -> Vec<usize>) -> Vec<T> {
        let mut st = self.0.q.lock().unwrap();
        let mut idx = select(&st.buf);
        idx.retain(|&i| i < st.buf.len());
        idx.sort_unstable();
        idx.dedup();
        let mut stolen = Vec::with_capacity(idx.len());
        for &i in idx.iter().rev() {
            if let Some(v) = st.buf.remove(i) {
                stolen.push(v);
            }
        }
        stolen.reverse();
        let freed = !stolen.is_empty();
        self.0.sync_depth(&st);
        drop(st);
        if freed {
            self.0.not_full.notify_all();
        }
        stolen
    }

    /// Drain everything currently queued without blocking.
    pub fn drain_now(&self) -> Vec<T> {
        let mut st = self.0.q.lock().unwrap();
        let out: Vec<T> = st.buf.drain(..).collect();
        self.0.sync_depth(&st);
        drop(st);
        self.0.not_full.notify_all();
        out
    }

    /// Current queue depth (racy; for metrics only). Lock-free, like
    /// [`Sender::len`].
    pub fn len(&self) -> usize {
        self.0.depth.load(Ordering::Acquire)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().senders += 1;
        Sender(Arc::clone(&self.0))
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().receivers += 1;
        Receiver(Arc::clone(&self.0))
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.q.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            drop(st);
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.0.q.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            drop(st);
            self.0.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order_single_thread() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn try_send_full_signals_backpressure() {
        let (tx, rx) = bounded(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        match tx.try_send(3) {
            Err(TrySendError::Full(3)) => {}
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(rx.recv().unwrap(), 1);
        tx.try_send(3).unwrap();
    }

    #[test]
    fn recv_errors_after_senders_gone() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), 7);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn send_errors_after_receivers_gone() {
        let (tx, rx) = bounded::<u32>(2);
        drop(rx);
        assert_eq!(tx.send(1), Err(SendError(1)));
        assert!(matches!(
            tx.try_send(2),
            Err(TrySendError::Disconnected(2))
        ));
    }

    #[test]
    fn blocking_send_wakes_on_recv() {
        let (tx, rx) = bounded(1);
        tx.send(0).unwrap();
        let h = thread::spawn(move || tx.send(1));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 0);
        h.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 1);
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(16);
        let n_producers = 4;
        let per = 500;
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let tx = tx.clone();
            producers.push(thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<usize> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..n_producers * per).collect::<Vec<_>>());
    }

    #[test]
    fn send_timeout_times_out_when_full() {
        let (tx, _rx) = bounded(1);
        tx.send(1).unwrap();
        let t0 = std::time::Instant::now();
        match tx.send_timeout(2, Duration::from_millis(20)) {
            Err(SendTimeoutError::Timeout(2)) => {}
            other => panic!("expected Timeout, got {other:?}"),
        }
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn send_timeout_wakes_on_recv() {
        let (tx, rx) = bounded(1);
        tx.send(0).unwrap();
        let h = thread::spawn(move || tx.send_timeout(1, Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 0);
        h.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 1);
    }

    #[test]
    fn send_timeout_disconnected() {
        let (tx, rx) = bounded::<u32>(1);
        drop(rx);
        assert!(matches!(
            tx.send_timeout(7, Duration::from_millis(5)),
            Err(SendTimeoutError::Disconnected(7))
        ));
    }

    #[test]
    fn recv_timeout_times_out() {
        let (_tx, rx) = bounded::<u32>(1);
        let got = rx.recv_timeout(Duration::from_millis(10)).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn steal_by_removes_selected_and_frees_capacity() {
        let (tx, rx) = bounded(4);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        // Steal the two even items; bogus/duplicate indices are ignored.
        let stolen = rx.steal_by(|q| {
            let mut idx: Vec<usize> =
                (0..q.len()).filter(|&i| q[i] % 2 == 0).collect();
            idx.push(99); // out of range
            idx.push(idx[0]); // duplicate
            idx
        });
        assert_eq!(stolen, vec![0, 2]);
        // Remaining order preserved, and the freed slots accept sends.
        tx.try_send(4).unwrap();
        tx.try_send(5).unwrap();
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 3);
        assert_eq!(rx.recv().unwrap(), 4);
        assert_eq!(rx.recv().unwrap(), 5);
    }

    #[test]
    fn steal_by_wakes_blocked_sender() {
        let (tx, rx) = bounded(1);
        tx.send(0).unwrap();
        let h = thread::spawn(move || tx.send(1));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.steal_by(|_| vec![0]), vec![0]);
        h.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 1);
    }

    #[test]
    fn len_mirror_tracks_every_mutation() {
        let (tx, rx) = bounded(8);
        assert_eq!(tx.len(), 0);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(tx.len(), 5);
        assert_eq!(rx.len(), 5);
        rx.recv().unwrap();
        assert_eq!(rx.len(), 4);
        rx.steal_by(|_| vec![0, 1]);
        assert_eq!(tx.len(), 2);
        rx.drain_now();
        assert_eq!(rx.len(), 0);
        assert!(rx.is_empty() && tx.is_empty());
    }

    #[test]
    fn close_fails_sends_from_every_clone() {
        let (tx, rx) = bounded::<u32>(4);
        let tx2 = tx.clone();
        tx.send(1).unwrap();
        tx.close();
        tx.close(); // idempotent
        assert!(tx.is_closed());
        assert_eq!(tx.send(2), Err(SendError(2)));
        assert!(matches!(tx2.try_send(3), Err(TrySendError::Disconnected(3))));
        assert!(matches!(
            tx2.send_timeout(4, Duration::from_millis(5)),
            Err(SendTimeoutError::Disconnected(4))
        ));
        // Buffered items drain before end-of-stream.
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv(), Err(RecvError));
        assert_eq!(rx.recv_timeout(Duration::from_millis(5)), Err(RecvError));
    }

    #[test]
    fn close_wakes_blocked_receiver() {
        let (tx, rx) = bounded::<u32>(1);
        let h = thread::spawn(move || rx.recv());
        thread::sleep(Duration::from_millis(20));
        tx.close();
        assert_eq!(h.join().unwrap(), Err(RecvError));
    }

    #[test]
    fn close_wakes_blocked_sender() {
        let (tx, _rx) = bounded::<u32>(1);
        tx.send(0).unwrap();
        let tx2 = tx.clone();
        let h = thread::spawn(move || tx2.send(1));
        thread::sleep(Duration::from_millis(20));
        tx.close();
        assert_eq!(h.join().unwrap(), Err(SendError(1)));
    }

    #[test]
    fn drain_now() {
        let (tx, rx) = bounded(8);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.drain_now(), vec![0, 1, 2, 3]);
        assert!(rx.is_empty());
    }
}
