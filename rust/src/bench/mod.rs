//! Benchmark support: a measurement harness (the offline environment has
//! no criterion) and the renderers that regenerate the paper's tables and
//! figures as text/CSV.

pub mod figures;
pub mod harness;

pub use harness::{Bench, Measurement};
