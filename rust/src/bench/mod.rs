//! Benchmark support: a measurement harness (the offline environment has
//! no criterion), the renderers that regenerate the paper's tables and
//! figures as text/CSV, and the CI bench-regression gate behind
//! `tilekit bench`.

pub mod figures;
pub mod gate;
pub mod harness;
pub mod serving;
pub mod wire;

pub use gate::{compare, smoke_suite, BenchReport, GateResult};
pub use harness::{Bench, Measurement};
pub use serving::{serving_suite, ServingProfile};
pub use wire::{wire_suite, WireProfile};
