//! Regenerators for every table and figure in the paper's evaluation.
//! Each function returns a [`Table`] whose rows carry the same series the
//! paper plots; the bench binaries and the CLI print them (and CSV for
//! plotting).

use crate::autotuner::{SimCostModel, TuningOutcome, TuningSession};
use crate::device::{paper_pair, table1, DeviceDescriptor};
use crate::image::Interpolator;
use crate::sim::{block_traffic, simulate, Launch, Straggler};
use crate::tiling::occupancy::{occupancy, KernelResources};
use crate::tiling::TileDim;
use crate::util::text::{fmt_ms, Table};

/// The paper's Fig. 3 scales, insets (a)–(e).
pub const FIG3_SCALES: [u32; 5] = [2, 4, 6, 8, 10];

/// Table I — regenerated from the device registry.
pub fn table1_figure() -> Table {
    table1()
}

/// An exhaustive paper-pair tuning outcome for one (kernel, scale, src)
/// — the data behind each Fig. 3 inset.
fn paper_pair_outcome(kernel: Interpolator, scale: u32, src: (u32, u32)) -> TuningOutcome {
    let (gtx, gts) = paper_pair();
    TuningSession::new(SimCostModel)
        .devices([gtx, gts])
        .kernel(kernel)
        .scale(scale)
        .src(src)
        .run()
        .expect("paper tiles are launchable on the paper pair")
}

/// One inset of Fig. 3: time per tile on both paper devices at `scale`.
pub fn fig3_inset(kernel: Interpolator, scale: u32, src: (u32, u32)) -> Table {
    inset_table(&paper_pair_outcome(kernel, scale, src))
}

/// Render the inset table for an already-computed paper-pair outcome.
fn inset_table(outcome: &TuningOutcome) -> Table {
    let (sg, ss) = (&outcome.per_device[0], &outcome.per_device[1]);
    let mut t = Table::new(vec![
        "tile".to_string(),
        "threads".to_string(),
        format!("{} ms", sg.device_id),
        format!("{} ms", ss.device_id),
        "ratio".to_string(),
    ]);
    for (pg, ps) in sg.points.iter().zip(&ss.points) {
        let (a, b) = (pg.ms, ps.ms);
        t.row(vec![
            pg.tile.label(),
            pg.tile.threads().to_string(),
            fmt_ms(a),
            fmt_ms(b),
            if a.is_finite() && a > 0.0 {
                format!("{:.2}", b / a)
            } else {
                "-".into()
            },
        ]);
    }
    t
}

/// All five Fig. 3 insets plus the per-inset best tiles and smoothness —
/// the full headline figure with the paper's three findings called out.
pub fn fig3_summary(kernel: Interpolator, src: (u32, u32)) -> (Vec<(u32, Table)>, Table) {
    let mut insets = Vec::new();
    let mut summary = Table::new(vec![
        "scale",
        "best@gtx260",
        "best@8800gts",
        "range@gtx260 (ms)",
        "range@8800gts (ms)",
    ]);
    for scale in FIG3_SCALES {
        let outcome = paper_pair_outcome(kernel, scale, src);
        insets.push((scale, inset_table(&outcome)));
        let (sg, ss) = (&outcome.per_device[0], &outcome.per_device[1]);
        summary.row(vec![
            scale.to_string(),
            sg.best.label(),
            ss.best.label(),
            format!("{:.3}", sg.range_ms()),
            format!("{:.3}", ss.range_ms()),
        ]);
    }
    (insets, summary)
}

/// Fig. 4 — the 4×8 vs 8×4 access-pattern comparison, as per-block
/// traffic counts on both devices, across the paper's scales.
pub fn fig4_access(scale: u32) -> Table {
    let (gtx, gts) = paper_pair();
    let mut t = Table::new(vec![
        "device",
        "tile",
        "row crossings/block",
        "load tx/block",
        "store tx/block",
        "row penalty (cyc)",
        "sim ms (800x800)",
    ]);
    for dev in [&gtx, &gts] {
        for tile in [TileDim::new(4, 8), TileDim::new(8, 4)] {
            let l = Launch::paper(Interpolator::Bilinear, tile, scale);
            let tr = block_traffic(&l, dev);
            let r = simulate(&l, dev, None);
            t.row(vec![
                dev.id.clone(),
                tile.label(),
                tr.row_crossings.to_string(),
                tr.load_transactions.to_string(),
                tr.store_transactions.to_string(),
                format!("{:.0}", tr.row_penalty_cycles),
                fmt_ms(r.ms),
            ]);
        }
    }
    t
}

/// §III.B — the 32×16 occupancy cliff table.
pub fn occupancy_cliff(tile: TileDim) -> Table {
    let (gtx, gts) = paper_pair();
    let mut t = Table::new(vec![
        "device",
        "tile",
        "blocks/SM",
        "threads/SM",
        "occupancy",
        "limiter",
    ]);
    for dev in [&gtx, &gts] {
        let o = occupancy(tile, &KernelResources::BILINEAR, &dev.cc);
        t.row(vec![
            dev.id.clone(),
            tile.label(),
            o.blocks_per_sm.to_string(),
            o.threads_per_sm.to_string(),
            format!("{:.0}%", o.ratio * 100.0),
            o.limiter.label().to_string(),
        ]);
    }
    t
}

/// §IV.C — the G1/G2 straggler-dilution experiment: a half-speed SM on a
/// 2-SM vs a 20-SM device.
pub fn extreme_example() -> Table {
    let mut t = Table::new(vec![
        "device",
        "SMs",
        "clean ms",
        "straggler ms",
        "efficiency lost",
        "paper predicts",
    ]);
    for (id, predict) in [("g1", "1/4"), ("g2", "1/40")] {
        let dev = crate::device::find_device(id).expect("builtin");
        let l = Launch::paper(Interpolator::Bilinear, TileDim::new(32, 4), 4);
        let clean = simulate(&l, &dev, None).ms;
        let hurt = simulate(&l, &dev, Some(Straggler { sm: 0, speed: 0.5 })).ms;
        let lost = (hurt - clean) / hurt;
        t.row(vec![
            dev.id.clone(),
            dev.sm_count.to_string(),
            fmt_ms(clean),
            fmt_ms(hurt),
            format!("{:.3}", lost),
            predict.to_string(),
        ]);
    }
    t
}

/// §V — portable-tile selection over a device set at a given scale,
/// through the TuningSession API.
pub fn portable_selection(
    devices: &[DeviceDescriptor],
    kernel: Interpolator,
    scale: u32,
    src: (u32, u32),
) -> (Table, Option<TileDim>) {
    let mut t = Table::new(vec!["device", "best tile", "portable-tile regret"]);
    if devices.is_empty() {
        return (t, None);
    }
    let outcome = match TuningSession::new(SimCostModel)
        .devices(devices.to_vec())
        .kernel(kernel)
        .scale(scale)
        .src(src)
        .run()
    {
        Ok(o) => o,
        Err(_) => return (t, None), // no launchable tile on some device
    };
    if let Some(c) = &outcome.portable {
        for (dev, best, regret) in &c.per_device {
            t.row(vec![dev.clone(), best.label(), format!("{:.3}x", regret)]);
        }
    }
    let tile = outcome.portable_tile();
    (t, tile)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::paper_sweep_tiles;

    #[test]
    fn fig3_inset_has_all_tiles() {
        let t = fig3_inset(Interpolator::Bilinear, 2, (800, 800));
        assert_eq!(t.n_rows(), paper_sweep_tiles().len());
    }

    #[test]
    fn fig3_summary_finds_32x4_at_large_scale() {
        let (_insets, summary) = fig3_summary(Interpolator::Bilinear, (800, 800));
        let text = summary.render();
        // scales 6,8,10 rows contain 32x4 twice (both devices)
        for line in text.lines().filter(|l| {
            l.starts_with("6 ") || l.starts_with("8 ") || l.starts_with("10")
        }) {
            assert_eq!(
                line.matches("32x4").count(),
                2,
                "expected 32x4 best on both devices: {line}"
            );
        }
    }

    #[test]
    fn fig4_table_shape() {
        let t = fig4_access(6);
        assert_eq!(t.n_rows(), 4); // 2 devices × 2 tiles
    }

    #[test]
    fn occupancy_cliff_table() {
        let t = occupancy_cliff(TileDim::new(32, 16));
        let text = t.render();
        assert!(text.contains("100%"));
        assert!(text.contains("67%") || text.contains("66%"));
    }

    #[test]
    fn extreme_table_has_both() {
        let t = extreme_example();
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn portable_runs_over_paper_pair() {
        let (gtx, gts) = paper_pair();
        let (t, choice) =
            portable_selection(&[gtx, gts], Interpolator::Bilinear, 8, (800, 800));
        assert_eq!(t.n_rows(), 2);
        assert_eq!(choice, Some(TileDim::new(32, 4)));
    }
}
