//! The loopback wire benchmark behind `tilekit bench --wire`: proof for
//! the protocol-v2 redesign (pipelined frames + binary image payloads).
//!
//! One mock fleet is served over an ephemeral loopback TCP socket by
//! [`NetServer`], then driven twice through [`FleetClient`] with the
//! same request mix:
//!
//! 1. **v1** — [`PayloadEncoding::Json`] forces the pre-negotiation
//!    protocol: pixels travel as a JSON `f32` array in the frame line.
//! 2. **v2** — [`PayloadEncoding::Binary`] negotiates protocol v2 on
//!    connect: pixels travel as a length-prefixed little-endian binary
//!    block after the header line, both ways.
//!
//! Each run keeps a window of submits in flight (the client pipelines
//! over one connection), and reports wall-clock µs per completed
//! request plus — the deterministic half of the comparison — bytes on
//! the wire per request, measured from the client's own
//! [`wire_metrics`](FleetClient::wire_metrics) counters. The records
//! land in `BENCH_PR.json` behind the same regression gate as the rest
//! of the suite, so a change that silently reverts submits to JSON
//! pixels (or breaks pipelining into lock-step) fails CI.

use super::gate::BenchRecord;
use crate::config::ServingConfig;
use crate::coordinator::{Fleet, FleetBuilder, Request, TilePolicy};
use crate::device::{find_device, DeviceDescriptor};
use crate::image::generate;
use crate::net::{
    BackendFactory, FleetClient, ListenAddr, NetClientConfig, NetServer, NetServerConfig,
    PayloadEncoding,
};
use crate::runtime::{Manifest, MockEngine, ResizeBackend};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Knobs of one wire-bench run. The CLI uses [`quick`](Self::quick)
/// (CI smoke) or [`full`](Self::full); tests shrink further. The
/// request shape is not a knob: it comes from whatever the benched
/// fleet's manifest serves, so the bench never drifts from a shippable
/// configuration.
#[derive(Debug, Clone)]
pub struct WireProfile {
    /// Submit+wait round trips per protocol version.
    pub requests: usize,
    /// Submits kept in flight on the connection at once.
    pub inflight: usize,
}

impl WireProfile {
    /// CI smoke profile: enough traffic to amortize connect/hello, small
    /// enough to stay in the tier-1 budget.
    pub fn quick() -> WireProfile {
        WireProfile {
            requests: 64,
            inflight: 16,
        }
    }

    /// The default profile.
    pub fn full() -> WireProfile {
        WireProfile {
            requests: 256,
            inflight: 16,
        }
    }
}

/// The benched fleet: two mock-backed members over the demo manifest —
/// the same shape `serve --listen --mock` builds.
fn wire_fleet() -> Result<Arc<Fleet>> {
    let manifest = Manifest::fleet_demo();
    let cfg = ServingConfig {
        workers: 2,
        batch_max: Some(8),
        batch_deadline_ms: 0.2,
        queue_cap: 256,
        ..ServingConfig::default()
    };
    let mut b = FleetBuilder::new(&cfg, &manifest);
    for id in ["gtx260", "fermi"] {
        let dev = find_device(id)
            .unwrap_or_else(|| panic!("built-in device '{id}' missing from the registry"));
        let backend: Arc<dyn ResizeBackend> = Arc::new(MockEngine::new());
        b = b.device(dev, backend, TilePolicy::PortableFallback);
    }
    Ok(Arc::new(b.build()?))
}

fn mock_factory() -> BackendFactory {
    Arc::new(|_d: &DeviceDescriptor| Arc::new(MockEngine::new()) as Arc<dyn ResizeBackend>)
}

/// Client knobs for one protocol version. Identical apart from the
/// payload encoding, so the two runs differ only in what the wire
/// carries.
fn client_cfg(encoding: PayloadEncoding) -> NetClientConfig {
    NetClientConfig {
        wait_poll: Duration::from_millis(250),
        payload_encoding: encoding,
        ..NetClientConfig::default()
    }
}

/// Drive `profile.requests` submit+wait round trips of `template`
/// through `client`, keeping up to `profile.inflight` outstanding.
/// Returns `(us_per_request, bytes_per_request)`; the byte count covers
/// both directions and comes from the client's own transport counters,
/// so it is deterministic for a fixed image.
fn drive(client: &FleetClient, profile: &WireProfile, template: &Request) -> Result<(f64, f64)> {
    let before = client.wire_metrics();
    let t0 = Instant::now();
    let mut done = 0usize;
    while done < profile.requests {
        let burst = profile.inflight.min(profile.requests - done);
        let mut window = Vec::with_capacity(burst);
        for _ in 0..burst {
            window.push(
                client
                    .submit(template)
                    .map_err(|e| anyhow!("wire bench submit failed: {e}"))?,
            );
        }
        for t in window {
            t.wait().map_err(|e| anyhow!("wire bench wait failed: {e}"))?;
            done += 1;
        }
    }
    let elapsed = t0.elapsed();
    let after = client.wire_metrics();
    let bytes = (after.bytes_sent - before.bytes_sent)
        + (after.bytes_received - before.bytes_received);
    let n = profile.requests as f64;
    Ok((elapsed.as_secs_f64() * 1e6 / n, bytes as f64 / n))
}

/// Run one wire-bench profile and return its gate records, normalized
/// against `calib_us` like every other suite. Byte records are
/// deterministic; the µs records carry the loopback wall-clock.
pub fn run_profile(calib_us: f64, profile: &WireProfile) -> Result<Vec<BenchRecord>> {
    let calib = calib_us.max(f64::MIN_POSITIVE);
    let fleet = wire_fleet()?;
    let keys = fleet.keys();
    let Some(key) = keys.first() else {
        bail!("wire bench fleet serves no request shapes");
    };
    let img = generate::test_scene(key.src.1 as usize, key.src.0 as usize, 11);
    let template = Request::new(key.kernel, img, key.scale);

    let server = NetServer::bind(
        &ListenAddr::Tcp("127.0.0.1:0".into()),
        Arc::clone(&fleet),
        mock_factory(),
        NetServerConfig {
            read_timeout: Duration::from_millis(25),
            ..NetServerConfig::default()
        },
    )?;

    let v1 = FleetClient::connect_with(server.local_addr(), client_cfg(PayloadEncoding::Json))
        .map_err(|e| anyhow!("wire bench v1 connect failed: {e}"))?;
    let (v1_us, v1_bytes) = drive(&v1, profile, &template)?;
    drop(v1);

    let v2 = FleetClient::connect_with(server.local_addr(), client_cfg(PayloadEncoding::Binary))
        .map_err(|e| anyhow!("wire bench v2 connect failed: {e}"))?;
    if !v2.wire_metrics().v2_session {
        bail!("the in-tree server refused the v2 hello — negotiation is broken");
    }
    let (v2_us, v2_bytes) = drive(&v2, profile, &template)?;
    drop(v2);

    server.shutdown();
    if let Ok(f) = Arc::try_unwrap(fleet) {
        f.shutdown();
    }

    println!(
        "wire loopback: {} requests, {} in flight | v1/v2 bytes/req {:.2}x",
        profile.requests,
        profile.inflight,
        v1_bytes / v2_bytes.max(1.0)
    );
    let mut records = Vec::new();
    let mut push = |name: &str, value: f64, unit: &str| {
        println!("{name:<44} {value:>12.3} {unit}");
        records.push(BenchRecord {
            name: name.to_string(),
            mean_us: value,
            normalized: value / calib,
        });
    };
    push("wire: v1 submit+wait us/req", v1_us, "us");
    push("wire: v2 submit+wait us/req", v2_us, "us");
    push("wire: v1 bytes/req", v1_bytes, "B");
    push("wire: v2 bytes/req", v2_bytes, "B");
    Ok(records)
}

/// The `tilekit bench --wire` entry point: run the quick (CI) or full
/// profile.
pub fn wire_suite(calib_us: f64, quick: bool) -> Result<Vec<BenchRecord>> {
    let profile = if quick {
        WireProfile::quick()
    } else {
        WireProfile::full()
    };
    run_profile(calib_us, &profile)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_profile_produces_all_records() {
        let tiny = WireProfile {
            requests: 12,
            inflight: 4,
        };
        let recs = run_profile(10.0, &tiny).unwrap();
        let names: Vec<&str> = recs.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "wire: v1 submit+wait us/req",
                "wire: v2 submit+wait us/req",
                "wire: v1 bytes/req",
                "wire: v2 bytes/req",
            ]
        );
        for r in &recs {
            assert!(
                r.mean_us.is_finite() && r.mean_us > 0.0,
                "{}: {}",
                r.name,
                r.mean_us
            );
            assert!(r.normalized.is_finite() && r.normalized > 0.0);
        }
    }

    #[test]
    fn v2_moves_at_least_4x_fewer_bytes_per_request() {
        // The PR's acceptance criterion, measured rather than derived:
        // binary pixels cost 4 B each both ways, JSON pixels cost a
        // shortest-round-trip f64 decimal (~18 chars) plus a comma.
        let tiny = WireProfile {
            requests: 8,
            inflight: 4,
        };
        let recs = run_profile(10.0, &tiny).unwrap();
        let by_name = |n: &str| {
            recs.iter()
                .find(|r| r.name == n)
                .unwrap_or_else(|| panic!("missing record '{n}'"))
                .mean_us
        };
        let v1 = by_name("wire: v1 bytes/req");
        let v2 = by_name("wire: v2 bytes/req");
        assert!(
            v1 >= 4.0 * v2,
            "v2 must move >=4x fewer bytes per request: v1={v1:.0} B, v2={v2:.0} B ({:.2}x)",
            v1 / v2
        );
    }

    #[test]
    fn profiles_are_sane() {
        for p in [WireProfile::quick(), WireProfile::full()] {
            assert!(p.requests >= 32);
            assert!(p.inflight >= 1 && p.inflight <= p.requests);
        }
    }
}
