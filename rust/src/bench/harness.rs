//! A small wall-clock benchmark harness: warmup, calibrated iteration
//! counts, and robust statistics. Used by every `cargo bench` target
//! (they are `harness = false` binaries).

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// One benchmark's collected measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall time summary (µs).
    pub us: Summary,
    /// Iterations per sample batch.
    pub iters_per_sample: u64,
}

impl Measurement {
    /// Mean per-iteration time in microseconds.
    pub fn mean_us(&self) -> f64 {
        self.us.mean
    }

    /// One line: `name  mean ± std  [min .. max]  (n samples × iters)`.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12.3} us ± {:>8.3}  [{:>10.3} .. {:>10.3}]  ({} × {})",
            self.name, self.us.mean, self.us.std, self.us.min, self.us.max, self.us.n,
            self.iters_per_sample
        )
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bench {
    /// Wall-clock budget for warmup.
    pub warmup: Duration,
    /// Samples to collect.
    pub samples: usize,
    /// Target wall time per sample (iterations are calibrated to this).
    pub sample_target: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            samples: 20,
            sample_target: Duration::from_millis(50),
        }
    }
}

impl Bench {
    /// A faster profile for CI / smoke runs (set `TILEKIT_BENCH_FAST=1`).
    pub fn from_env() -> Bench {
        if std::env::var("TILEKIT_BENCH_FAST").is_ok() {
            Bench {
                warmup: Duration::from_millis(20),
                samples: 5,
                sample_target: Duration::from_millis(5),
            }
        } else {
            Bench::default()
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Measurement {
        // Warmup + calibration: figure out how many iters fill
        // sample_target.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters == 0 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let iters = ((self.sample_target.as_secs_f64() / per_iter).ceil() as u64).clamp(1, 1_000_000);

        let mut samples_us = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64();
            samples_us.push(dt * 1e6 / iters as f64);
        }
        Measurement {
            name: name.to_string(),
            us: Summary::of(&samples_us).expect("non-empty"),
            iters_per_sample: iters,
        }
    }

    /// Run and print in one step; returns the measurement for recording.
    pub fn report<R>(&self, name: &str, f: impl FnMut() -> R) -> Measurement {
        let m = self.run(name, f);
        println!("{}", m.line());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            samples: 3,
            sample_target: Duration::from_millis(2),
        };
        let m = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.us.mean > 0.0);
        assert_eq!(m.us.n, 3);
        assert!(m.iters_per_sample >= 1);
    }

    #[test]
    fn line_formats() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            samples: 2,
            sample_target: Duration::from_millis(1),
        };
        let m = b.run("fmt", || 1 + 1);
        assert!(m.line().contains("fmt"));
    }
}
