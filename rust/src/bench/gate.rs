//! The bench-regression gate behind `tilekit bench` and CI's `bench`
//! job: a fixed smoke suite of hot-path micro-benchmarks, a JSON report
//! (`BENCH_PR.json` / the committed `BENCH_BASELINE.json`), and the
//! >N% regression comparison that fails the build.
//!
//! Wall-clock µs do not transfer between machines, so the gate compares
//! **normalized** scores: each bench's mean divided by the mean of a
//! fixed pure-CPU calibration workload measured in the same run. The
//! ratio cancels most of the machine-speed difference; raw µs are still
//! recorded for human trend-reading.
//!
//! A baseline marked `"provisional": true` (committed from a machine
//! that could not measure, to start the perf trajectory) is compared
//! and reported but never fails the gate; refresh it on a real machine
//! with `tilekit bench --update-baseline` and commit the result.

use super::harness::Bench;
use crate::codec::json::Json;
use crate::coordinator::batcher::BatcherState;
use crate::coordinator::request::{Priority, RequestKey, ResizeRequest, Ticket};
use crate::coordinator::stealing::select_steals;
use crate::device::paper_pair;
use crate::exec::bounded;
use crate::image::{generate, Interpolator};
use crate::sim::{simulate, Launch};
use crate::tiling::occupancy::{occupancy, KernelResources};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::path::Path;
use std::time::{Duration, Instant};

/// Name of the machine-speed calibration workload every report carries.
pub const CALIBRATION: &str = "calibration: integer spin";

/// One benched hot path in a report.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    pub name: String,
    /// Mean wall time per iteration (µs) on the measuring machine.
    pub mean_us: f64,
    /// `mean_us` divided by the calibration workload's mean — the
    /// machine-portable score the gate compares.
    pub normalized: f64,
}

/// A full bench report (the JSON artifact).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    pub version: u64,
    /// True when the numbers were not measured where they claim to
    /// apply; a provisional baseline reports but never fails the gate.
    pub provisional: bool,
    pub records: Vec<BenchRecord>,
}

impl BenchReport {
    /// Look up a record by bench name.
    pub fn record(&self, name: &str) -> Option<&BenchRecord> {
        self.records.iter().find(|r| r.name == name)
    }

    pub fn to_json(&self) -> Json {
        let records: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj()
                    .set("name", r.name.as_str())
                    .set("mean_us", r.mean_us)
                    .set("normalized", r.normalized)
            })
            .collect();
        Json::obj()
            .set("version", 1u64)
            .set("provisional", self.provisional)
            .set("records", Json::Arr(records))
    }

    pub fn from_json(j: &Json) -> Result<BenchReport> {
        match j.get("version").and_then(Json::as_u64) {
            Some(1) => {}
            Some(v) => bail!("unsupported bench report version {v}"),
            None => bail!("bench report missing 'version'"),
        }
        let provisional = j.get("provisional").and_then(Json::as_bool).unwrap_or(false);
        let records = j
            .get("records")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("bench report missing 'records'"))?
            .iter()
            .map(|r| -> Result<BenchRecord> {
                Ok(BenchRecord {
                    name: r
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("record missing 'name'"))?
                        .to_string(),
                    mean_us: r
                        .get("mean_us")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("record missing 'mean_us'"))?,
                    normalized: r
                        .get("normalized")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("record missing 'normalized'"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(BenchReport {
            version: 1,
            provisional,
            records,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing bench report {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<BenchReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench report {}", path.display()))?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow!("{e}"))?)
            .with_context(|| format!("in bench report {}", path.display()))
    }
}

/// The measurement profile of the gate: fast enough for CI smoke, long
/// enough to average out scheduler noise.
pub fn gate_profile() -> Bench {
    Bench {
        warmup: Duration::from_millis(50),
        samples: 10,
        sample_target: Duration::from_millis(10),
    }
}

/// Run the fixed smoke suite and build a report. Prints one line per
/// bench as it runs.
pub fn smoke_suite(b: &Bench) -> BenchReport {
    let (gtx, gts) = paper_pair();
    let mut measurements = Vec::new();

    let calib = b.report(CALIBRATION, || {
        let mut x: u64 = 0x9E37_79B9_7F4A_7C15;
        for i in 0..4096u64 {
            x = x.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(i);
        }
        x
    });
    let calib_us = calib.mean_us().max(f64::MIN_POSITIVE);
    measurements.push(calib);

    let launch = Launch::paper(Interpolator::Bilinear, "32x4".parse().unwrap(), 8);
    measurements.push(b.report("simulate: one launch (gtx260, s8)", || {
        simulate(&launch, &gtx, None)
    }));
    measurements.push(b.report("simulate: one launch (8800gts, s8)", || {
        simulate(&launch, &gts, None)
    }));

    let t32x16 = "32x16".parse().unwrap();
    measurements.push(b.report("occupancy(32x16)", || {
        occupancy(t32x16, &KernelResources::BILINEAR, &gtx.cc)
    }));

    measurements.push(b.report("channel send+recv (cap 64)", || {
        let (tx, rx) = bounded(64);
        for i in 0..32u32 {
            tx.send(i).unwrap();
        }
        let mut s = 0u32;
        for _ in 0..32 {
            s += rx.recv().unwrap();
        }
        s
    }));

    let img = generate::gradient(16, 16);
    let key = RequestKey::of(Interpolator::Bilinear, &img, 2);
    measurements.push(b.report("batcher push+flush (batch 8)", || {
        let mut state = BatcherState::new(8, Duration::from_millis(1));
        for i in 0..8u64 {
            let (_t, tx) = Ticket::new(i);
            if state.push(ResizeRequest::bare(i, key, img.clone(), tx)).is_some() {
                return 1usize;
            }
        }
        0usize
    }));

    // The work-stealing selection over a deep mixed queue — the new
    // fleet hot path this PR adds to the trajectory.
    let key4 = RequestKey::of(Interpolator::Bilinear, &img, 4);
    let queue: VecDeque<ResizeRequest> = (0..64u64)
        .map(|i| {
            let (_t, tx) = Ticket::new(i);
            let mut r =
                ResizeRequest::bare(i, if i % 3 == 0 { key4 } else { key }, img.clone(), tx);
            if i % 2 == 0 {
                r.priority = Priority::Batch;
            }
            r
        })
        .collect();
    let now = Instant::now();
    measurements.push(b.report("steal select (64-deep queue)", || {
        select_steals(&queue, |k| k.scale == 2, now, 8)
    }));

    BenchReport {
        version: 1,
        provisional: false,
        records: measurements
            .into_iter()
            .map(|m| BenchRecord {
                name: m.name.clone(),
                normalized: m.mean_us() / calib_us,
                mean_us: m.mean_us(),
            })
            .collect(),
    }
}

/// Outcome of comparing a PR report against the baseline.
#[derive(Debug)]
pub struct GateResult {
    /// One human-readable line per compared bench.
    pub lines: Vec<String>,
    /// Benches over the threshold (or missing from the current run).
    pub failures: Vec<String>,
    /// The baseline was provisional: report, but never fail.
    pub provisional_baseline: bool,
}

impl GateResult {
    /// Does the gate pass?
    pub fn passed(&self) -> bool {
        self.provisional_baseline || self.failures.is_empty()
    }
}

/// Compare `current` against `baseline` on normalized scores; a bench
/// whose score grew by more than `max_regress_pct` percent (or that
/// disappeared) is a failure. The calibration workload itself is not
/// gated (it defines the scale).
pub fn compare(baseline: &BenchReport, current: &BenchReport, max_regress_pct: f64) -> GateResult {
    let mut lines = Vec::new();
    let mut failures = Vec::new();
    for base in &baseline.records {
        if base.name == CALIBRATION {
            continue;
        }
        let Some(cur) = current.record(&base.name) else {
            failures.push(format!("'{}' missing from the current run", base.name));
            continue;
        };
        if base.normalized <= 0.0 || !base.normalized.is_finite() || !cur.normalized.is_finite() {
            lines.push(format!("{:<44} unreadable scores; skipped", base.name));
            continue;
        }
        let delta_pct = (cur.normalized / base.normalized - 1.0) * 100.0;
        let verdict = if delta_pct > max_regress_pct {
            failures.push(format!(
                "'{}' regressed {delta_pct:+.1}% (limit {max_regress_pct:.0}%)",
                base.name
            ));
            "REGRESSED"
        } else {
            "ok"
        };
        lines.push(format!(
            "{:<44} base {:>8.3} now {:>8.3} ({delta_pct:+6.1}%) {verdict}",
            base.name, base.normalized, cur.normalized
        ));
    }
    for cur in &current.records {
        if cur.name != CALIBRATION && baseline.record(&cur.name).is_none() {
            lines.push(format!("{:<44} new bench (no baseline)", cur.name));
        }
    }
    GateResult {
        lines,
        failures,
        provisional_baseline: baseline.provisional,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(provisional: bool, scores: &[(&str, f64)]) -> BenchReport {
        let mut records = vec![BenchRecord {
            name: CALIBRATION.to_string(),
            mean_us: 10.0,
            normalized: 1.0,
        }];
        records.extend(scores.iter().map(|(name, norm)| BenchRecord {
            name: name.to_string(),
            mean_us: norm * 10.0,
            normalized: *norm,
        }));
        BenchReport {
            version: 1,
            provisional,
            records,
        }
    }

    #[test]
    fn json_round_trip() {
        let r = report(true, &[("simulate", 3.5), ("channel", 0.8)]);
        let back = BenchReport::from_json(&r.to_json()).unwrap();
        assert_eq!(r, back);
        assert!(BenchReport::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(
            BenchReport::from_json(&Json::parse(r#"{"version": 9, "records": []}"#).unwrap())
                .is_err()
        );
    }

    #[test]
    fn gate_passes_within_threshold_and_fails_beyond() {
        let base = report(false, &[("simulate", 1.0), ("channel", 2.0)]);
        let ok = report(false, &[("simulate", 1.10), ("channel", 1.5)]);
        let g = compare(&base, &ok, 15.0);
        assert!(g.passed(), "{:?}", g.failures);
        assert_eq!(g.lines.len(), 2);

        let bad = report(false, &[("simulate", 1.20), ("channel", 2.0)]);
        let g = compare(&base, &bad, 15.0);
        assert!(!g.passed());
        assert_eq!(g.failures.len(), 1);
        assert!(g.failures[0].contains("simulate"), "{:?}", g.failures);
    }

    #[test]
    fn gate_fails_on_disappeared_bench_and_notes_new_ones() {
        let base = report(false, &[("simulate", 1.0)]);
        let cur = report(false, &[("brand-new", 1.0)]);
        let g = compare(&base, &cur, 15.0);
        assert!(!g.passed());
        assert!(g.failures[0].contains("missing"));
        assert!(g.lines.iter().any(|l| l.contains("new bench")));
    }

    #[test]
    fn provisional_baseline_reports_but_never_fails() {
        let base = report(true, &[("simulate", 1.0)]);
        let awful = report(false, &[("simulate", 50.0)]);
        let g = compare(&base, &awful, 15.0);
        assert!(g.passed(), "provisional baselines must not fail the gate");
        assert_eq!(g.failures.len(), 1, "the regression is still reported");
    }

    #[test]
    fn smoke_suite_produces_normalized_records() {
        let fast = Bench {
            warmup: Duration::from_millis(1),
            samples: 2,
            sample_target: Duration::from_millis(1),
        };
        let r = smoke_suite(&fast);
        assert!(!r.provisional);
        assert!(r.records.len() >= 6);
        assert!(r.record(CALIBRATION).is_some());
        assert!((r.record(CALIBRATION).unwrap().normalized - 1.0).abs() < 1e-9);
        assert!(r.records.iter().all(|x| x.mean_us > 0.0 && x.normalized > 0.0));
        assert!(r.record("steal select (64-deep queue)").is_some());
    }
}
