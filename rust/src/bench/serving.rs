//! The open-loop serving benchmark behind `tilekit bench --serving`:
//! proof for the lock-free submit hot path.
//!
//! Two phases against a live mock fleet over the built-in demo
//! manifest:
//!
//! 1. **Closed-loop submit latency** — a tight submit loop (the queue is
//!    drained between bursts so backpressure never pollutes the timing)
//!    measuring the per-call cost of [`Fleet::submit`] itself: mean,
//!    p50, p99.
//! 2. **Open-loop serving** — a phased Poisson trace ([`Trace::phased`])
//!    replayed by the open-loop driver ([`crate::workload::replay`]),
//!    reporting end-to-end p99 latency and achieved throughput.
//!
//! Both phases land in `BENCH_PR.json` as normalized records behind the
//! same >N% regression gate as the micro suite, so a future change that
//! re-introduces a lock or an allocation on the submit path fails CI.

use super::gate::BenchRecord;
use crate::config::ServingConfig;
use crate::coordinator::{
    Fleet, FleetBuilder, LeastLoaded, RejectWhenFull, Request, SubmitError, TilePolicy,
};
use crate::device::find_device;
use crate::image::generate;
use crate::metrics::Histogram;
use crate::runtime::{Manifest, MockEngine, ResizeBackend};
use crate::workload::{replay, LoadPhase, ReplayOutcome, Trace};
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

/// Built-in device ids the benched fleet draws members from, in join
/// order.
const BENCH_DEVICES: [&str; 4] = ["gtx260", "fermi", "8800gts", "gtx280"];

/// Knobs of one serving-bench run. The CLI uses [`quick`](Self::quick)
/// (CI smoke) or [`full`](Self::full); tests shrink further.
#[derive(Debug, Clone)]
pub struct ServingProfile {
    /// Fleet size (clamped to the built-in device registry).
    pub members: usize,
    /// Closed-loop submits to time in phase 1.
    pub submits: usize,
    /// Open-loop load shape for phase 2.
    pub phases: Vec<LoadPhase>,
    /// Trace seed (the run is deterministic in it, wall-clock aside).
    pub seed: u64,
}

impl ServingProfile {
    /// CI smoke profile: 2 members, a short burst trace.
    pub fn quick() -> ServingProfile {
        ServingProfile {
            members: 2,
            submits: 2_000,
            phases: vec![
                LoadPhase {
                    rate: 1_000.0,
                    dur_us: 250_000,
                },
                LoadPhase {
                    rate: 2_500.0,
                    dur_us: 250_000,
                },
                LoadPhase {
                    rate: 600.0,
                    dur_us: 100_000,
                },
            ],
            seed: 17,
        }
    }

    /// The default profile: 4 members, a longer quiet→burst→quiet trace.
    pub fn full() -> ServingProfile {
        ServingProfile {
            members: 4,
            submits: 8_000,
            phases: vec![
                LoadPhase {
                    rate: 1_200.0,
                    dur_us: 600_000,
                },
                LoadPhase {
                    rate: 3_000.0,
                    dur_us: 600_000,
                },
                LoadPhase {
                    rate: 600.0,
                    dur_us: 300_000,
                },
            ],
            seed: 17,
        }
    }
}

/// Build the benched fleet: `members` mock-backed members over the demo
/// manifest, queue-depth-aware scheduling (so every submit reads every
/// member's depth mirror — the snapshot hot path), and non-blocking
/// admission (the open-loop driver must not be pushed back on).
fn bench_fleet(members: usize) -> Result<Fleet> {
    let n = members.clamp(1, BENCH_DEVICES.len());
    let manifest = Manifest::fleet_demo();
    let cfg = ServingConfig {
        workers: 2,
        batch_max: Some(8),
        batch_deadline_ms: 0.2,
        queue_cap: 256,
        ..ServingConfig::default()
    };
    let mut b = FleetBuilder::new(&cfg, &manifest)
        .scheduler(LeastLoaded)
        .admission(RejectWhenFull);
    for id in &BENCH_DEVICES[..n] {
        let dev = find_device(id)
            .unwrap_or_else(|| panic!("built-in device '{id}' missing from the registry"));
        let backend: Arc<dyn ResizeBackend> = Arc::new(MockEngine::new());
        b = b.device(dev, backend, TilePolicy::PortableFallback);
    }
    b.build()
}

/// Phase 1: time `submits` individual [`Fleet::submit`] calls. Input
/// images are cloned OUTSIDE the timed window; the pending-ticket pile
/// is drained every 128 admissions (and on any `Saturated`) so the
/// queue never fills and the histogram measures the submit path, not
/// backpressure.
fn submit_phase(fleet: &Fleet, submits: usize) -> Result<Histogram> {
    let keys = fleet.keys();
    if keys.is_empty() {
        bail!("bench fleet serves no request shapes");
    }
    let inputs: Vec<_> = keys
        .iter()
        .map(|k| {
            (
                *k,
                generate::test_scene(k.src.1 as usize, k.src.0 as usize, 7),
            )
        })
        .collect();
    let hist = Histogram::new();
    let mut pending = Vec::with_capacity(256);
    let mut done = 0usize;
    while done < submits {
        let (key, img) = &inputs[done % inputs.len()];
        let req = Request::new(key.kernel, img.clone(), key.scale);
        let t0 = Instant::now();
        match fleet.submit(req) {
            Ok(t) => {
                hist.record(t0.elapsed());
                pending.push(t);
                done += 1;
            }
            Err(SubmitError::Saturated) => {
                // Non-blocking admission hit a full queue: let the
                // pipeline catch up, untimed, and retry.
                for t in pending.drain(..) {
                    let _ = t.wait();
                }
            }
            Err(e) => bail!("bench submit failed: {e}"),
        }
        if pending.len() >= 128 {
            for t in pending.drain(..) {
                let _ = t.wait();
            }
        }
    }
    for t in pending {
        let _ = t.wait();
    }
    Ok(hist)
}

/// Phase 2: replay a phased Poisson trace open-loop and return the
/// driver's outcome.
fn open_loop_phase(fleet: &Fleet, profile: &ServingProfile) -> Result<ReplayOutcome> {
    let keys = fleet.keys();
    if keys.is_empty() {
        bail!("bench fleet serves no request shapes");
    }
    let trace = Trace::phased(&keys, &profile.phases, profile.seed);
    if trace.events.is_empty() {
        bail!("serving profile generated an empty trace");
    }
    Ok(replay(fleet, &trace))
}

/// Run one serving-bench profile and return its gate records,
/// normalized against `calib_us` (the calibration workload's mean from
/// the same run). Prints one line per record plus the sampled
/// submit-path breakdown.
pub fn run_profile(calib_us: f64, profile: &ServingProfile) -> Result<Vec<BenchRecord>> {
    let calib = calib_us.max(f64::MIN_POSITIVE);
    let fleet = bench_fleet(profile.members)?;
    let hist = submit_phase(&fleet, profile.submits)?;
    let out = open_loop_phase(&fleet, profile)?;
    if out.completed == 0 {
        bail!("open-loop phase completed nothing: {}", out.summary());
    }
    println!("open-loop: {}", out.summary());
    let stats = fleet.shutdown();
    if let Some(line) = stats.submit_breakdown() {
        println!("{line}");
    }
    let mut records = Vec::new();
    let mut push = |name: &str, mean_us: f64| {
        println!("{name:<44} {mean_us:>12.3} us");
        records.push(BenchRecord {
            name: name.to_string(),
            mean_us,
            normalized: mean_us / calib,
        });
    };
    push("serving: submit us/op", hist.mean_us());
    push("serving: submit p50", hist.percentile_us(50.0));
    push("serving: submit p99", hist.percentile_us(99.0));
    push("serving: open-loop e2e p99", out.latency.percentile_us(99.0));
    // Lower-is-better throughput: µs of wall per completed request, so
    // the regression gate's "grew by >N%" check applies unchanged.
    push("serving: open-loop us/req", 1e6 / out.achieved_rps().max(1.0));
    Ok(records)
}

/// The `tilekit bench --serving` entry point: run the quick (CI) or
/// full profile.
pub fn serving_suite(calib_us: f64, quick: bool) -> Result<Vec<BenchRecord>> {
    let profile = if quick {
        ServingProfile::quick()
    } else {
        ServingProfile::full()
    };
    run_profile(calib_us, &profile)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_profile_produces_all_records() {
        let tiny = ServingProfile {
            members: 2,
            submits: 64,
            phases: vec![LoadPhase {
                rate: 2_000.0,
                dur_us: 50_000,
            }],
            seed: 3,
        };
        let recs = run_profile(10.0, &tiny).unwrap();
        let names: Vec<&str> = recs.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "serving: submit us/op",
                "serving: submit p50",
                "serving: submit p99",
                "serving: open-loop e2e p99",
                "serving: open-loop us/req",
            ]
        );
        for r in &recs {
            assert!(
                r.mean_us.is_finite() && r.mean_us > 0.0,
                "{}: {}",
                r.name,
                r.mean_us
            );
            assert!(r.normalized.is_finite() && r.normalized > 0.0);
        }
    }

    #[test]
    fn profiles_are_sane() {
        for p in [ServingProfile::quick(), ServingProfile::full()] {
            assert!(p.members >= 2 && p.members <= BENCH_DEVICES.len());
            assert!(p.submits >= 1_000);
            assert!(!p.phases.is_empty());
        }
    }
}
