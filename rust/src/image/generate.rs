//! Deterministic synthetic test images.
//!
//! The paper resizes an 800×800 photograph; interpolation cost is
//! data-independent, so any deterministic source with structure (edges,
//! gradients, texture) exercises the same code paths while keeping the
//! repo free of binary assets. All generators are seeded/deterministic so
//! python and rust can build bit-identical inputs.

use super::buffer::Image;
use crate::util::Pcg32;

/// Smooth two-axis gradient: f(x,y) = x/(w-1) stacked with y/(h-1).
pub fn gradient(w: usize, h: usize) -> Image<f32> {
    let mut img = Image::new(w, h);
    let wd = (w.max(2) - 1) as f32;
    let hd = (h.max(2) - 1) as f32;
    for y in 0..h {
        for x in 0..w {
            img.set(x, y, 0.5 * (x as f32 / wd) + 0.5 * (y as f32 / hd));
        }
    }
    img
}

/// Checkerboard with the given cell size — the worst case for
/// interpolation quality (hard edges everywhere).
pub fn checkerboard(w: usize, h: usize, cell: usize) -> Image<f32> {
    assert!(cell > 0);
    let mut img = Image::new(w, h);
    for y in 0..h {
        for x in 0..w {
            let v = ((x / cell) + (y / cell)) % 2;
            img.set(x, y, v as f32);
        }
    }
    img
}

/// Band-limited value noise: bilinearly-interpolated random lattice,
/// several octaves. A stand-in for photographic texture.
pub fn value_noise(w: usize, h: usize, seed: u64) -> Image<f32> {
    let mut img = Image::new(w, h);
    let octaves: &[(usize, f32)] = &[(8, 0.5), (16, 0.3), (32, 0.2)];
    for (oi, &(cells, amp)) in octaves.iter().enumerate() {
        let gw = cells + 2;
        let gh = cells + 2;
        let mut rng = Pcg32::new(seed, oi as u64 + 1);
        let lattice: Vec<f32> = (0..gw * gh).map(|_| rng.f32()).collect();
        for y in 0..h {
            let fy = y as f32 / h as f32 * cells as f32;
            let y0 = fy as usize;
            let ty = fy - y0 as f32;
            for x in 0..w {
                let fx = x as f32 / w as f32 * cells as f32;
                let x0 = fx as usize;
                let tx = fx - x0 as f32;
                let l = |xx: usize, yy: usize| lattice[yy * gw + xx];
                let top = l(x0, y0) * (1.0 - tx) + l(x0 + 1, y0) * tx;
                let bot = l(x0, y0 + 1) * (1.0 - tx) + l(x0 + 1, y0 + 1) * tx;
                let v = top * (1.0 - ty) + bot * ty;
                img.set(x, y, img.get(x, y) + amp * v);
            }
        }
    }
    img
}

/// The standard test scene used across examples and benches: gradient +
/// noise + a checker patch, mimicking a photo's mix of smooth regions,
/// texture, and hard edges. Deterministic for a given seed.
pub fn test_scene(w: usize, h: usize, seed: u64) -> Image<f32> {
    let g = gradient(w, h);
    let n = value_noise(w, h, seed);
    let c = checkerboard(w, h, (w / 40).max(1));
    let mut img = Image::new(w, h);
    for y in 0..h {
        for x in 0..w {
            let blend = 0.55 * g.get(x, y) + 0.35 * n.get(x, y) + 0.10 * c.get(x, y);
            img.set(x, y, blend.clamp(0.0, 1.0));
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_corners() {
        let g = gradient(10, 10);
        assert!((g.get(0, 0) - 0.0).abs() < 1e-6);
        assert!((g.get(9, 9) - 1.0).abs() < 1e-6);
        assert!((g.get(9, 0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn checkerboard_alternates() {
        let c = checkerboard(8, 8, 2);
        assert_eq!(c.get(0, 0), 0.0);
        assert_eq!(c.get(2, 0), 1.0);
        assert_eq!(c.get(0, 2), 1.0);
        assert_eq!(c.get(2, 2), 0.0);
    }

    #[test]
    fn noise_deterministic_and_bounded() {
        let a = value_noise(32, 32, 7);
        let b = value_noise(32, 32, 7);
        assert_eq!(a, b);
        let c = value_noise(32, 32, 8);
        assert_ne!(a, c, "different seeds should differ");
        for y in 0..32 {
            for x in 0..32 {
                let v = a.get(x, y);
                assert!((0.0..=1.0).contains(&v), "noise out of range: {v}");
            }
        }
    }

    #[test]
    fn scene_in_unit_range() {
        let s = test_scene(64, 48, 42);
        assert_eq!(s.width(), 64);
        assert_eq!(s.height(), 48);
        for y in 0..48 {
            for x in 0..64 {
                let v = s.get(x, y);
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn scene_deterministic() {
        assert_eq!(test_scene(16, 16, 1), test_scene(16, 16, 1));
    }
}
