//! Row-major image buffer with explicit pitch.
//!
//! The pitch (row stride) is kept distinct from the width because the
//! paper's Fig. 4 effect — the cost of "pointer movement between rows" —
//! is a function of the *pitch in memory*, and the simulator's DRAM model
//! consumes it directly.

use std::fmt;

/// A single-channel image of `T` (the kernels operate per channel; RGB
/// images are three planes).
#[derive(Debug, Clone, PartialEq)]
pub struct Image<T> {
    width: usize,
    height: usize,
    /// Row stride in elements; ≥ width.
    pitch: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> Image<T> {
    /// A zeroed `w`×`h` image with tight pitch.
    pub fn new(w: usize, h: usize) -> Image<T> {
        Self::with_pitch(w, h, w)
    }

    /// A zeroed image with an explicit pitch (pitch ≥ w).
    pub fn with_pitch(w: usize, h: usize, pitch: usize) -> Image<T> {
        assert!(w > 0 && h > 0, "image dims must be positive");
        assert!(pitch >= w, "pitch must cover the width");
        Image {
            width: w,
            height: h,
            pitch,
            data: vec![T::default(); pitch * h],
        }
    }

    /// Build from row-major data with tight pitch. `data.len()` must be
    /// exactly `w*h`.
    pub fn from_vec(w: usize, h: usize, data: Vec<T>) -> Image<T> {
        assert!(w > 0 && h > 0, "image dims must be positive");
        assert_eq!(data.len(), w * h, "data length must be w*h");
        Image {
            width: w,
            height: h,
            pitch: w,
            data,
        }
    }

    pub fn width(&self) -> usize {
        self.width
    }
    pub fn height(&self) -> usize {
        self.height
    }
    pub fn pitch(&self) -> usize {
        self.pitch
    }

    /// Raw element storage (pitch-strided).
    pub fn data(&self) -> &[T] {
        &self.data
    }

    /// Dense row-major copy without pitch padding.
    pub fn to_dense(&self) -> Vec<T> {
        if self.pitch == self.width {
            return self.data.clone();
        }
        let mut out = Vec::with_capacity(self.width * self.height);
        for y in 0..self.height {
            let start = y * self.pitch;
            out.extend_from_slice(&self.data[start..start + self.width]);
        }
        out
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> T {
        debug_assert!(x < self.width && y < self.height);
        self.data[y * self.pitch + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: T) {
        debug_assert!(x < self.width && y < self.height);
        self.data[y * self.pitch + x] = v;
    }

    /// Clamped fetch: out-of-range coordinates are clamped to the border
    /// (the boundary convention shared with the Pallas kernels and ref.py).
    #[inline]
    pub fn get_clamped(&self, x: isize, y: isize) -> T {
        let xc = x.clamp(0, self.width as isize - 1) as usize;
        let yc = y.clamp(0, self.height as isize - 1) as usize;
        self.get(xc, yc)
    }

    /// One row as a slice.
    pub fn row(&self, y: usize) -> &[T] {
        let start = y * self.pitch;
        &self.data[start..start + self.width]
    }

    /// Map every pixel through `f`, producing a new image (tight pitch).
    pub fn map<U: Copy + Default>(&self, mut f: impl FnMut(T) -> U) -> Image<U> {
        let mut out = Image::new(self.width, self.height);
        for y in 0..self.height {
            for x in 0..self.width {
                out.set(x, y, f(self.get(x, y)));
            }
        }
        out
    }
}

impl Image<f32> {
    /// Maximum absolute difference against another image of the same size.
    pub fn max_abs_diff(&self, other: &Image<f32>) -> f32 {
        assert_eq!(self.width, other.width);
        assert_eq!(self.height, other.height);
        let mut m = 0f32;
        for y in 0..self.height {
            for x in 0..self.width {
                m = m.max((self.get(x, y) - other.get(x, y)).abs());
            }
        }
        m
    }
}

impl<T> fmt::Display for Image<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Image({}x{}, pitch {})", self.width, self.height, self.pitch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_round_trip() {
        let mut img: Image<f32> = Image::new(4, 3);
        img.set(3, 2, 7.5);
        assert_eq!(img.get(3, 2), 7.5);
        assert_eq!(img.get(0, 0), 0.0);
    }

    #[test]
    fn pitch_indexing() {
        let mut img: Image<u8> = Image::with_pitch(3, 2, 8);
        img.set(2, 1, 9);
        assert_eq!(img.data()[8 + 2], 9);
        assert_eq!(img.to_dense(), vec![0, 0, 0, 0, 0, 9]);
    }

    #[test]
    fn clamped_fetch() {
        let img = Image::from_vec(2, 2, vec![1f32, 2.0, 3.0, 4.0]);
        assert_eq!(img.get_clamped(-5, -5), 1.0);
        assert_eq!(img.get_clamped(10, 0), 2.0);
        assert_eq!(img.get_clamped(0, 10), 3.0);
        assert_eq!(img.get_clamped(10, 10), 4.0);
    }

    #[test]
    #[should_panic(expected = "data length")]
    fn from_vec_checks_len() {
        Image::from_vec(2, 2, vec![0f32; 3]);
    }

    #[test]
    fn map_converts_type() {
        let img = Image::from_vec(2, 1, vec![0.25f32, 0.5]);
        let bytes = img.map(|v| (v * 255.0) as u8);
        assert_eq!(bytes.get(0, 0), 63);
        assert_eq!(bytes.get(1, 0), 127);
    }

    #[test]
    fn max_abs_diff() {
        let a = Image::from_vec(2, 1, vec![1f32, 2.0]);
        let b = Image::from_vec(2, 1, vec![1.5f32, 1.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    #[test]
    fn row_slice() {
        let img = Image::from_vec(3, 2, vec![1u8, 2, 3, 4, 5, 6]);
        assert_eq!(img.row(1), &[4, 5, 6]);
    }
}
