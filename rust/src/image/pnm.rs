//! PNM (PGM/PPM) image codec — the portable grayscale/pixmap formats.
//!
//! Supports reading P2 (ascii gray), P5 (binary gray), P3 (ascii RGB) and
//! P6 (binary RGB, collapsed to luminance), and writing P5/P2. This gives
//! the examples and the serving demo a real image interchange format
//! without binary assets or external codec crates.

use super::buffer::Image;
use anyhow::{anyhow, bail, Context, Result};
use std::fs;
use std::path::Path;

/// Read a PNM file into a normalized `[0,1]` grayscale image. RGB inputs
/// are converted with the Rec. 601 luma weights.
pub fn read_pnm(path: &Path) -> Result<Image<f32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_pnm(&bytes).with_context(|| format!("parsing {}", path.display()))
}

/// Write a `[0,1]` grayscale image as binary PGM (P5, maxval 255).
pub fn write_pgm(path: &Path, img: &Image<f32>) -> Result<()> {
    let mut out = format!("P5\n{} {}\n255\n", img.width(), img.height()).into_bytes();
    for y in 0..img.height() {
        for x in 0..img.width() {
            out.push((img.get(x, y).clamp(0.0, 1.0) * 255.0).round() as u8);
        }
    }
    fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

/// Write ascii PGM (P2) — handy for golden files in tests.
pub fn write_pgm_ascii(path: &Path, img: &Image<f32>) -> Result<()> {
    fs::write(path, render_p2(img)).with_context(|| format!("writing {}", path.display()))
}

fn render_p2(img: &Image<f32>) -> String {
    let mut s = format!("P2\n{} {}\n255\n", img.width(), img.height());
    for y in 0..img.height() {
        let row: Vec<String> = (0..img.width())
            .map(|x| ((img.get(x, y).clamp(0.0, 1.0) * 255.0).round() as u8).to_string())
            .collect();
        s.push_str(&row.join(" "));
        s.push('\n');
    }
    s
}

/// Parse PNM bytes (P2/P3/P5/P6).
pub fn parse_pnm(bytes: &[u8]) -> Result<Image<f32>> {
    let mut cur = Cursor { bytes, pos: 0 };
    let magic = cur.token()?;
    let (binary, channels) = match magic.as_str() {
        "P2" => (false, 1),
        "P3" => (false, 3),
        "P5" => (true, 1),
        "P6" => (true, 3),
        m => bail!("unsupported PNM magic '{m}'"),
    };
    let w: usize = cur.token()?.parse().context("width")?;
    let h: usize = cur.token()?.parse().context("height")?;
    let maxval: u32 = cur.token()?.parse().context("maxval")?;
    if w == 0 || h == 0 {
        bail!("degenerate image {w}x{h}");
    }
    if maxval == 0 || maxval > 65535 {
        bail!("bad maxval {maxval}");
    }
    let wide = maxval > 255;
    let n = w * h * channels;
    let mut vals: Vec<f32> = Vec::with_capacity(n);
    if binary {
        cur.skip_single_whitespace()?;
        let bytes_per = if wide { 2 } else { 1 };
        let need = n * bytes_per;
        let raw = cur.rest();
        if raw.len() < need {
            bail!("truncated raster: need {need} bytes, have {}", raw.len());
        }
        for i in 0..n {
            let v = if wide {
                u16::from_be_bytes([raw[2 * i], raw[2 * i + 1]]) as u32
            } else {
                raw[i] as u32
            };
            vals.push(v as f32 / maxval as f32);
        }
    } else {
        for _ in 0..n {
            let v: u32 = cur.token()?.parse().context("sample")?;
            vals.push(v as f32 / maxval as f32);
        }
    }
    // Collapse channels to luminance.
    let data: Vec<f32> = if channels == 1 {
        vals
    } else {
        vals.chunks_exact(3)
            .map(|px| 0.299 * px[0] + 0.587 * px[1] + 0.114 * px[2])
            .collect()
    };
    Ok(Image::from_vec(w, h, data))
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    /// Next whitespace-delimited token, skipping `#` comments.
    fn token(&mut self) -> Result<String> {
        loop {
            while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
                self.pos += 1;
            }
            if self.pos < self.bytes.len() && self.bytes[self.pos] == b'#' {
                while self.pos < self.bytes.len() && self.bytes[self.pos] != b'\n' {
                    self.pos += 1;
                }
                continue;
            }
            break;
        }
        let start = self.pos;
        while self.pos < self.bytes.len() && !self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
        if start == self.pos {
            return Err(anyhow!("unexpected end of header"));
        }
        Ok(String::from_utf8_lossy(&self.bytes[start..self.pos]).into_owned())
    }

    /// Exactly one whitespace byte separates the header from a binary
    /// raster.
    fn skip_single_whitespace(&mut self) -> Result<()> {
        if self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
            Ok(())
        } else {
            Err(anyhow!("missing whitespace before raster"))
        }
    }

    fn rest(&self) -> &'a [u8] {
        &self.bytes[self.pos..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::generate;

    #[test]
    fn p2_parse_with_comments() {
        let src = b"P2\n# a comment\n3 2\n255\n0 128 255\n10 20 30\n";
        let img = parse_pnm(src).unwrap();
        assert_eq!(img.width(), 3);
        assert_eq!(img.height(), 2);
        assert!((img.get(1, 0) - 128.0 / 255.0).abs() < 1e-6);
        assert!((img.get(2, 1) - 30.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn p5_round_trip_via_files() {
        let dir = std::env::temp_dir().join("tilekit_pnm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.pgm");
        let img = generate::test_scene(37, 23, 5);
        write_pgm(&path, &img).unwrap();
        let back = read_pnm(&path).unwrap();
        assert_eq!(back.width(), 37);
        assert_eq!(back.height(), 23);
        // 8-bit quantization error only
        assert!(img.max_abs_diff(&back) <= 0.5 / 255.0 + 1e-6);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn p6_luma_collapse() {
        // one pure-red and one pure-white pixel
        let mut bytes = b"P6\n2 1\n255\n".to_vec();
        bytes.extend_from_slice(&[255, 0, 0, 255, 255, 255]);
        let img = parse_pnm(&bytes).unwrap();
        assert!((img.get(0, 0) - 0.299).abs() < 1e-3);
        assert!((img.get(1, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn p3_ascii_rgb() {
        let src = b"P3\n1 1\n255\n0 255 0\n";
        let img = parse_pnm(src).unwrap();
        assert!((img.get(0, 0) - 0.587).abs() < 1e-3);
    }

    #[test]
    fn sixteen_bit_p5() {
        let mut bytes = b"P5\n1 1\n65535\n".to_vec();
        bytes.extend_from_slice(&32768u16.to_be_bytes());
        let img = parse_pnm(&bytes).unwrap();
        assert!((img.get(0, 0) - 0.5).abs() < 1e-3);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_pnm(b"P7\n1 1\n255\n\x00").is_err()); // bad magic
        assert!(parse_pnm(b"P5\n0 1\n255\n").is_err()); // zero dim
        assert!(parse_pnm(b"P5\n2 2\n255\n\x00\x00").is_err()); // truncated
        assert!(parse_pnm(b"P2\n1 1\n0\n0").is_err()); // maxval 0
        assert!(parse_pnm(b"").is_err());
    }

    #[test]
    fn ascii_writer_golden() {
        let img = Image::from_vec(2, 1, vec![0.0f32, 1.0]);
        assert_eq!(render_p2(&img), "P2\n2 1\n255\n0 255\n");
    }
}
