//! CPU reference interpolators.
//!
//! These implement exactly the paper's §II.B formulation (equations
//! (1)–(5)): the *terminal* pixel at `(x_f, y_f)` in the final image maps
//! to the *logical* pixel `(x_p, y_p) = (x_f/scale, y_f/scale)` in the
//! source; the four neighbours and the fractional offsets produce the
//! bilinear blend. The same convention (truncation to int, clamp at the
//! border) is implemented by `python/compile/kernels/ref.py` and the
//! Pallas kernels, so all three layers agree bit-for-bit up to f32
//! rounding.

use super::buffer::Image;

/// Interpolation method selector (shared with CLI / config / manifest).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Interpolator {
    Nearest,
    Bilinear,
    Bicubic,
}

impl Interpolator {
    pub fn label(self) -> &'static str {
        match self {
            Interpolator::Nearest => "nearest",
            Interpolator::Bilinear => "bilinear",
            Interpolator::Bicubic => "bicubic",
        }
    }

    pub fn parse(s: &str) -> Option<Interpolator> {
        match s.to_ascii_lowercase().as_str() {
            "nearest" | "nn" => Some(Interpolator::Nearest),
            "bilinear" | "bl" => Some(Interpolator::Bilinear),
            "bicubic" | "bc" => Some(Interpolator::Bicubic),
            _ => None,
        }
    }

    /// Run this interpolator over `src`, scaling by `scale`.
    pub fn run(self, src: &Image<f32>, scale: u32) -> Image<f32> {
        match self {
            Interpolator::Nearest => nearest(src, scale),
            Interpolator::Bilinear => bilinear(src, scale),
            Interpolator::Bicubic => bicubic(src, scale),
        }
    }
}

/// Output size for a given source and integer scale.
pub fn output_size(src_w: usize, src_h: usize, scale: u32) -> (usize, usize) {
    (src_w * scale as usize, src_h * scale as usize)
}

/// Paper eq. (1): terminal → logical coordinates.
#[inline]
fn logical(xf: usize, scale: u32) -> f32 {
    xf as f32 / scale as f32
}

/// Nearest-neighbour up-scaling.
pub fn nearest(src: &Image<f32>, scale: u32) -> Image<f32> {
    assert!(scale >= 1);
    let (w, h) = output_size(src.width(), src.height(), scale);
    let mut out = Image::new(w, h);
    for yf in 0..h {
        let yp = (logical(yf, scale) + 0.5) as isize;
        for xf in 0..w {
            let xp = (logical(xf, scale) + 0.5) as isize;
            out.set(xf, yf, src.get_clamped(xp, yp));
        }
    }
    out
}

/// Bilinear up-scaling — paper equations (1)–(5) with border clamping.
pub fn bilinear(src: &Image<f32>, scale: u32) -> Image<f32> {
    assert!(scale >= 1);
    let (w, h) = output_size(src.width(), src.height(), scale);
    let mut out = Image::new(w, h);
    for yf in 0..h {
        let yp = logical(yf, scale);
        let y1 = yp as isize; // eq. (3): int(y_p)
        let off_y = yp - y1 as f32; // eq. (4)
        for xf in 0..w {
            let xp = logical(xf, scale);
            let x1 = xp as isize; // eq. (2): int(x_p)
            let off_x = xp - x1 as f32; // eq. (4)

            // eq. (2)/(3): the four neighbours (clamped at the border)
            let f11 = src.get_clamped(x1, y1); // (x1, y1)
            let f21 = src.get_clamped(x1 + 1, y1); // (x2, y2)
            let f12 = src.get_clamped(x1, y1 + 1); // (x3, y3)
            let f22 = src.get_clamped(x1 + 1, y1 + 1); // (x4, y4)

            // eq. (5) (with the obvious correction of the final term's
            // (1-offsetY) typo to (1-offsetX); the published formula does
            // not reduce to the identity at offset 0 otherwise)
            let top = off_x * f21 + (1.0 - off_x) * f11;
            let bot = off_x * f22 + (1.0 - off_x) * f12;
            out.set(xf, yf, (1.0 - off_y) * top + off_y * bot);
        }
    }
    out
}

/// Catmull-Rom cubic weight (a = -0.5, the classic bicubic kernel).
#[inline]
fn cubic_weight(t: f32) -> f32 {
    const A: f32 = -0.5;
    let t = t.abs();
    if t <= 1.0 {
        (A + 2.0) * t * t * t - (A + 3.0) * t * t + 1.0
    } else if t < 2.0 {
        A * t * t * t - 5.0 * A * t * t + 8.0 * A * t - 4.0 * A
    } else {
        0.0
    }
}

/// Bicubic (Catmull-Rom, 16-tap) up-scaling with border clamping.
pub fn bicubic(src: &Image<f32>, scale: u32) -> Image<f32> {
    assert!(scale >= 1);
    let (w, h) = output_size(src.width(), src.height(), scale);
    let mut out = Image::new(w, h);
    for yf in 0..h {
        let yp = logical(yf, scale);
        let y1 = yp as isize;
        let fy = yp - y1 as f32;
        for xf in 0..w {
            let xp = logical(xf, scale);
            let x1 = xp as isize;
            let fx = xp - x1 as f32;
            let mut acc = 0f32;
            let mut wsum = 0f32;
            for dy in -1..=2isize {
                let wy = cubic_weight(fy - dy as f32);
                for dx in -1..=2isize {
                    let wx = cubic_weight(fx - dx as f32);
                    let wgt = wx * wy;
                    acc += wgt * src.get_clamped(x1 + dx, y1 + dy);
                    wsum += wgt;
                }
            }
            out.set(xf, yf, acc / wsum);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::generate;

    fn constant(w: usize, h: usize, v: f32) -> Image<f32> {
        Image::from_vec(w, h, vec![v; w * h])
    }

    #[test]
    fn scale_one_is_identity() {
        let src = generate::gradient(16, 12);
        for interp in [Interpolator::Nearest, Interpolator::Bilinear] {
            let out = interp.run(&src, 1);
            assert_eq!(out.width(), 16);
            assert_eq!(out.height(), 12);
            assert!(out.max_abs_diff(&src) < 1e-6, "{:?}", interp);
        }
        // bicubic at integer sample points is also the identity
        // (Catmull-Rom interpolates through its control points)
        let out = bicubic(&src, 1);
        assert!(out.max_abs_diff(&src) < 1e-5);
    }

    #[test]
    fn constant_image_stays_constant() {
        let src = constant(8, 8, 3.25);
        for interp in [
            Interpolator::Nearest,
            Interpolator::Bilinear,
            Interpolator::Bicubic,
        ] {
            let out = interp.run(&src, 4);
            assert_eq!(out.width(), 32);
            for y in 0..out.height() {
                for x in 0..out.width() {
                    assert!(
                        (out.get(x, y) - 3.25).abs() < 1e-5,
                        "{:?} at ({x},{y}) = {}",
                        interp,
                        out.get(x, y)
                    );
                }
            }
        }
    }

    #[test]
    fn bilinear_midpoint_exact() {
        // Two-pixel row [0, 1] at scale 2: output x=1 maps to x_p = 0.5 ⇒
        // exact average 0.5.
        let src = Image::from_vec(2, 1, vec![0f32, 1.0]);
        let out = bilinear(&src, 2);
        assert_eq!(out.width(), 4);
        assert!((out.get(0, 0) - 0.0).abs() < 1e-7);
        assert!((out.get(1, 0) - 0.5).abs() < 1e-7);
        assert!((out.get(2, 0) - 1.0).abs() < 1e-7);
        // x=3 → x_p=1.5, neighbour x2 clamps to border ⇒ stays 1.0
        assert!((out.get(3, 0) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn bilinear_linear_ramp_preserved() {
        // Bilinear must reproduce an affine function exactly (up to f32):
        // f(x,y) = x + 2y on a ramp image, interior points.
        let mut src = Image::new(8, 8);
        for y in 0..8 {
            for x in 0..8 {
                src.set(x, y, x as f32 + 2.0 * y as f32);
            }
        }
        let out = bilinear(&src, 4);
        for yf in 0..(7 * 4) {
            for xf in 0..(7 * 4) {
                let want = xf as f32 / 4.0 + 2.0 * (yf as f32 / 4.0);
                assert!(
                    (out.get(xf, yf) - want).abs() < 1e-4,
                    "({xf},{yf}): {} vs {want}",
                    out.get(xf, yf)
                );
            }
        }
    }

    #[test]
    fn nearest_picks_nearest() {
        // src 2x1 at scale 2 → 4x2 output. x_p = x_f/2, rounded half-up:
        // x_f=0 → 0, x_f=1 → 1 (0.5 rounds up), x_f=2,3 → 1.
        let src = Image::from_vec(2, 1, vec![10f32, 20.0]);
        let out = nearest(&src, 2);
        assert_eq!(out.width(), 4);
        assert_eq!(out.height(), 2);
        assert_eq!(
            out.to_dense(),
            vec![10.0, 20.0, 20.0, 20.0, 10.0, 20.0, 20.0, 20.0]
        );
    }

    #[test]
    fn bicubic_sharper_than_bilinear_on_edge() {
        // On a step edge, bicubic overshoots (ringing) while bilinear
        // stays within [0, 1]: a qualitative sanity check that the two
        // kernels genuinely differ.
        let mut src = Image::new(8, 1);
        for x in 4..8 {
            src.set(x, 0, 1.0);
        }
        let bl = bilinear(&src, 4);
        let bc = bicubic(&src, 4);
        let bl_max = (0..bl.width()).map(|x| bl.get(x, 0)).fold(0f32, f32::max);
        let bc_max = (0..bc.width()).map(|x| bc.get(x, 0)).fold(0f32, f32::max);
        assert!(bl_max <= 1.0 + 1e-6);
        assert!(bc_max > 1.0 + 1e-4, "bicubic should overshoot: {bc_max}");
    }

    #[test]
    fn output_sizes() {
        for s in 1..=10 {
            let (w, h) = output_size(800, 800, s);
            assert_eq!((w, h), (800 * s as usize, 800 * s as usize));
        }
    }

    #[test]
    fn parse_labels() {
        assert_eq!(Interpolator::parse("bilinear"), Some(Interpolator::Bilinear));
        assert_eq!(Interpolator::parse("NN"), Some(Interpolator::Nearest));
        assert_eq!(Interpolator::parse("bc"), Some(Interpolator::Bicubic));
        assert_eq!(Interpolator::parse("lanczos"), None);
    }
}
