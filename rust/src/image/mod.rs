//! Image substrate: pixel buffers, PNM (PGM/PPM) codecs, synthetic image
//! generators, and CPU reference interpolators.
//!
//! The CPU interpolators are the rust-side oracle: the serving path's AOT
//! Pallas artifacts are checked against [`interpolate::bilinear`] in the
//! integration tests, mirroring how the python side checks the kernel
//! against `ref.py`.

pub mod generate;
pub mod interpolate;
pub mod pnm;

mod buffer;

pub use buffer::Image;
pub use interpolate::{bicubic, bilinear, nearest, Interpolator};
