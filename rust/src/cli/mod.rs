//! A small command-line argument parser (no clap offline): subcommands,
//! `--flag value` / `--flag=value` options, boolean switches, positional
//! arguments, and generated usage text.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed arguments for one invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first non-flag token), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    opts: BTreeMap<String, String>,
    /// Bare `--switch` flags.
    switches: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

/// Parse error (unknown syntax only; semantic validation is the caller's).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgError(pub String);

impl fmt::Display for ArgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}
impl std::error::Error for ArgError {}

impl Args {
    /// Parse a token stream. `value_flags` lists flags that consume a
    /// value; any other `--flag` is a boolean switch.
    pub fn parse<I: IntoIterator<Item = String>>(
        tokens: I,
        value_flags: &[&str],
    ) -> Result<Args, ArgError> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if flag.is_empty() {
                    // `--` separator: everything after is positional
                    args.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = flag.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if value_flags.contains(&flag) {
                    let v = it
                        .next()
                        .ok_or_else(|| ArgError(format!("--{flag} needs a value")))?;
                    args.opts.insert(flag.to_string(), v);
                } else {
                    args.switches.push(flag.to_string());
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                return Err(ArgError(format!(
                    "short flags are not supported: '{tok}'"
                )));
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env(value_flags: &[&str]) -> Result<Args, ArgError> {
        Self::parse(std::env::args().skip(1), value_flags)
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    /// String option with default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Typed option (parse error surfaces the flag name).
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, ArgError>
    where
        T::Err: fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| ArgError(format!("--{key}={v}: {e}"))),
        }
    }

    /// Typed option with default.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, ArgError>
    where
        T::Err: fmt::Display,
    {
        Ok(self.get_parsed(key)?.unwrap_or(default))
    }

    /// Boolean switch present?
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.opts.contains_key(switch)
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .map(|v| {
                v.split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], vf: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), vf).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(
            &["sweep", "--device", "gtx260", "--scale=4", "--csv"],
            &["device", "scale"],
        );
        assert_eq!(a.command.as_deref(), Some("sweep"));
        assert_eq!(a.get("device"), Some("gtx260"));
        assert_eq!(a.get("scale"), Some("4"));
        assert!(a.has("csv"));
        assert!(!a.has("json"));
    }

    #[test]
    fn typed_parsing() {
        let a = parse(&["x", "--scale", "6"], &["scale"]);
        assert_eq!(a.get_parsed_or("scale", 2u32).unwrap(), 6);
        assert_eq!(a.get_parsed_or("missing", 9u32).unwrap(), 9);
        let bad = parse(&["x", "--scale", "abc"], &["scale"]);
        assert!(bad.get_parsed::<u32>("scale").is_err());
    }

    #[test]
    fn lists_and_positionals() {
        let a = parse(&["run", "in.pgm", "out.pgm", "--tiles=32x4,16x8"], &[]);
        assert_eq!(a.positional, vec!["in.pgm", "out.pgm"]);
        assert_eq!(a.get_list("tiles"), vec!["32x4", "16x8"]);
        assert!(a.get_list("none").is_empty());
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(["x".to_string(), "--device".to_string()], &["device"]);
        assert!(r.is_err());
    }

    #[test]
    fn double_dash_separator() {
        let a = parse(&["cmd", "--", "--not-a-flag"], &[]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn short_flags_rejected() {
        assert!(Args::parse(["-x".to_string()], &[]).is_err());
    }
}
