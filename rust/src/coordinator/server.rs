//! The coordinator façade: wires the admission queue, batcher thread,
//! and worker pool together; owns graceful shutdown.

use super::batcher::{Batch, BatcherState};
use super::request::{RequestKey, ResizeRequest, Ticket};
use super::router::Router;
use super::stats::{IdGen, ServingStats};
use super::worker::spawn_workers;
use crate::config::ServingConfig;
use crate::exec::{bounded, Sender, TrySendError};
use crate::image::{Image, Interpolator};
use crate::runtime::ResizeBackend;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Why a submission was not admitted.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue full — retry later (backpressure).
    Saturated,
    /// No artifact can serve this (kernel, size, scale).
    Unsupported,
    /// Coordinator is shutting down.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Saturated => write!(f, "admission queue saturated"),
            SubmitError::Unsupported => write!(f, "no artifact serves this request shape"),
            SubmitError::ShuttingDown => write!(f, "coordinator shutting down"),
        }
    }
}
impl std::error::Error for SubmitError {}

/// The running serving system.
pub struct Coordinator {
    admit_tx: Option<Sender<ResizeRequest>>,
    router: Arc<Router>,
    stats: Arc<ServingStats>,
    ids: IdGen,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the pipeline: 1 batcher thread + `cfg.workers` executor
    /// threads over `backend`.
    pub fn start(
        cfg: &ServingConfig,
        router: Router,
        backend: Arc<dyn ResizeBackend>,
    ) -> Coordinator {
        let stats = Arc::new(ServingStats::new());
        let router = Arc::new(router);
        let (admit_tx, admit_rx) = bounded::<ResizeRequest>(cfg.queue_cap);
        let (batch_tx, batch_rx) = bounded::<Batch>(cfg.queue_cap.max(4));

        // Batcher thread: drain admissions, group, flush on size/deadline.
        let deadline = Duration::from_secs_f64(cfg.batch_deadline_ms / 1e3);
        let batch_max = cfg.batch_max;
        let batcher = {
            std::thread::Builder::new()
                .name("tilekit-batcher".into())
                .spawn(move || {
                    let mut state = BatcherState::new(batch_max, deadline);
                    loop {
                        let timeout = state
                            .next_deadline(Instant::now())
                            .unwrap_or(Duration::from_millis(50));
                        match admit_rx.recv_timeout(timeout) {
                            Ok(Some(req)) => {
                                if let Some(batch) = state.push(req) {
                                    if batch_tx.send(batch).is_err() {
                                        break;
                                    }
                                }
                            }
                            Ok(None) => {} // timeout: fall through to expiry
                            Err(_) => break, // admissions closed: shutdown
                        }
                        for batch in state.flush_expired(Instant::now()) {
                            if batch_tx.send(batch).is_err() {
                                return;
                            }
                        }
                    }
                    // Shutdown: flush everything still pending.
                    for batch in state.flush_all() {
                        let _ = batch_tx.send(batch);
                    }
                })
                .expect("spawn batcher")
        };

        let workers = spawn_workers(
            cfg.workers,
            batch_rx,
            Arc::clone(&router),
            backend,
            Arc::clone(&stats),
        );

        Coordinator {
            admit_tx: Some(admit_tx),
            router,
            stats,
            ids: IdGen::default(),
            batcher: Some(batcher),
            workers,
        }
    }

    /// Serving statistics handle.
    pub fn stats(&self) -> Arc<ServingStats> {
        Arc::clone(&self.stats)
    }

    /// The routing table in use.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a resize request. Non-blocking: `Saturated` signals
    /// backpressure.
    pub fn submit(
        &self,
        kernel: Interpolator,
        image: Image<f32>,
        scale: u32,
    ) -> Result<Ticket, SubmitError> {
        let key = RequestKey::of(kernel, &image, scale);
        if !self.router.supports(&key) {
            self.stats.rejected.inc();
            return Err(SubmitError::Unsupported);
        }
        let tx = self.admit_tx.as_ref().ok_or(SubmitError::ShuttingDown)?;
        let id = self.ids.next();
        let (ticket, reply) = Ticket::new(id);
        let req = ResizeRequest {
            id,
            key,
            image,
            admitted: Instant::now(),
            reply,
        };
        match tx.try_send(req) {
            Ok(()) => {
                self.stats.admitted.inc();
                Ok(ticket)
            }
            Err(TrySendError::Full(_)) => {
                self.stats.rejected.inc();
                Err(SubmitError::Saturated)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Blocking submit: waits for queue space instead of failing.
    pub fn submit_blocking(
        &self,
        kernel: Interpolator,
        image: Image<f32>,
        scale: u32,
    ) -> Result<Ticket, SubmitError> {
        loop {
            match self.submit(kernel, image.clone(), scale) {
                Err(SubmitError::Saturated) => {
                    std::thread::sleep(Duration::from_micros(200));
                }
                other => return other,
            }
        }
    }

    /// Graceful shutdown: stop admissions, drain the pipeline, join all
    /// threads.
    pub fn shutdown(mut self) -> Arc<ServingStats> {
        self.shutdown_inner();
        Arc::clone(&self.stats)
    }

    fn shutdown_inner(&mut self) {
        self.admit_tx.take(); // closes admissions → batcher exits → workers exit
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if self.admit_tx.is_some() {
            self.shutdown_inner();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::generate;
    use crate::runtime::{Manifest, MockEngine};
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "version": 1,
              "artifacts": [
                {"name": "bl_s2_b4", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [4, 32], "path": "x"},
                {"name": "nn_s4_b2", "kernel": "nearest", "src": [16, 16],
                 "scale": 4, "batch": 2, "tile": [4, 32], "path": "x"}
              ]
            }"#,
            PathBuf::from("."),
        )
        .unwrap()
    }

    fn cfg() -> ServingConfig {
        ServingConfig {
            workers: 2,
            batch_max: 4,
            batch_deadline_ms: 2.0,
            queue_cap: 64,
            artifacts_dir: ".".into(),
        }
    }

    fn start(backend: Arc<dyn ResizeBackend>) -> Coordinator {
        let m = manifest();
        let router = Router::new(&m, super::super::TilePolicy::PortableFallback);
        Coordinator::start(&cfg(), router, backend)
    }

    #[test]
    fn end_to_end_requests_complete_correctly() {
        let co = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 9);
        let want = crate::image::bilinear(&img, 2);
        let tickets: Vec<_> = (0..20)
            .map(|_| {
                co.submit_blocking(Interpolator::Bilinear, img.clone(), 2)
                    .unwrap()
            })
            .collect();
        for t in tickets {
            let out = t.wait().unwrap();
            assert_eq!(out.width(), 32);
            assert!(out.max_abs_diff(&want) < 1e-6);
        }
        let stats = co.shutdown();
        assert_eq!(stats.completed.get(), 20);
        assert_eq!(stats.failed.get(), 0);
        assert!(stats.batches.get() <= 20);
        assert!(stats.mean_batch() >= 1.0);
    }

    #[test]
    fn unsupported_shape_rejected_fast() {
        let co = start(Arc::new(MockEngine::new()));
        let img = generate::gradient(9, 9);
        match co.submit(Interpolator::Bilinear, img, 2) {
            Err(SubmitError::Unsupported) => {}
            other => panic!("expected Unsupported, got {other:?}"),
        }
        let img16 = generate::gradient(16, 16);
        assert!(matches!(
            co.submit(Interpolator::Bicubic, img16, 2),
            Err(SubmitError::Unsupported)
        ));
    }

    #[test]
    fn mixed_kernels_route_independently() {
        let co = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 2);
        let t1 = co
            .submit_blocking(Interpolator::Bilinear, img.clone(), 2)
            .unwrap();
        let t2 = co
            .submit_blocking(Interpolator::Nearest, img.clone(), 4)
            .unwrap();
        assert_eq!(t1.wait().unwrap().width(), 32);
        assert_eq!(t2.wait().unwrap().width(), 64);
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        // One request with batch_max 4: only the deadline can flush it.
        let co = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 4);
        let t = co
            .submit(Interpolator::Bilinear, img, 2)
            .expect("admitted");
        let out = t.wait().unwrap();
        assert_eq!(out.height(), 32);
    }

    #[test]
    fn backend_failures_reported_per_request() {
        let co = start(Arc::new(MockEngine::failing_every(1)));
        let img = generate::test_scene(16, 16, 5);
        let t = co.submit_blocking(Interpolator::Bilinear, img, 2).unwrap();
        assert!(t.wait().is_err());
        let stats = co.shutdown();
        assert_eq!(stats.failed.get(), 1);
    }

    #[test]
    fn backpressure_saturates() {
        // Slow backend + tiny queue: eventually Saturated.
        let slow = MockEngine::with_delay(Duration::from_millis(30));
        let m = manifest();
        let router = Router::new(&m, super::super::TilePolicy::PortableFallback);
        let small = ServingConfig {
            workers: 1,
            batch_max: 1,
            batch_deadline_ms: 0.1,
            queue_cap: 2,
            artifacts_dir: ".".into(),
        };
        let co = Coordinator::start(&small, router, Arc::new(slow));
        let img = generate::test_scene(16, 16, 6);
        let mut saturated = false;
        let mut tickets = Vec::new();
        for _ in 0..64 {
            match co.submit(Interpolator::Bilinear, img.clone(), 2) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::Saturated) => {
                    saturated = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(saturated, "queue should saturate under a slow backend");
        for t in tickets {
            let _ = t.wait();
        }
        let stats = co.shutdown();
        assert!(stats.rejected.get() >= 1);
    }

    #[test]
    fn shutdown_drains_pending() {
        let co = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 7);
        let tickets: Vec<_> = (0..10)
            .map(|_| {
                co.submit_blocking(Interpolator::Bilinear, img.clone(), 2)
                    .unwrap()
            })
            .collect();
        let stats = co.shutdown(); // must drain, not drop
        assert_eq!(stats.completed.get() + stats.failed.get(), 10);
        for t in tickets {
            let _ = t.wait(); // all replies delivered
        }
    }
}
