//! The service façade: a fleet of device members — each with its own
//! router (tuned tile), admission queue, batcher thread, and worker
//! pool — behind one typed submit path. A [`Scheduler`] picks the member
//! per request; an [`AdmissionPolicy`] decides what a full queue means.
//!
//! Build one with [`ServiceBuilder`]:
//!
//! ```no_run
//! # use std::sync::Arc;
//! # use tilekit::config::ServingConfig;
//! # use tilekit::coordinator::{LeastLoaded, Request, ServiceBuilder, TilePolicy};
//! # use tilekit::device::find_device;
//! # use tilekit::image::{generate, Interpolator};
//! # use tilekit::runtime::{Manifest, MockEngine};
//! # let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
//! # let outcome = tilekit::autotuner::TuningSession::sim().run()?;
//! let svc = ServiceBuilder::new(&ServingConfig::default(), &manifest)
//!     .device(
//!         find_device("gtx260").unwrap(),
//!         Arc::new(MockEngine::new()),
//!         TilePolicy::PerDevice(outcome.clone()),
//!     )
//!     .device(
//!         find_device("fermi").unwrap(),
//!         Arc::new(MockEngine::new()),
//!         TilePolicy::PerDevice(outcome),
//!     )
//!     .scheduler(LeastLoaded)
//!     .build()?;
//! let ticket = svc.submit(Request::new(
//!     Interpolator::Bilinear,
//!     generate::gradient(64, 64),
//!     2,
//! ))?;
//! let _img = ticket.wait()?;
//! # Ok::<(), anyhow::Error>(())
//! ```

use super::admission::{admission_by_name, AdmissionPolicy};
use super::batcher::{Batch, BatcherState, Shed};
use super::request::{Request, RequestKey, ResizeRequest, Ticket};
use super::router::{Router, TilePolicy};
use super::scheduler::{scheduler_by_name, CostMeter, DeviceSnapshot, Scheduler};
use super::stats::{IdGen, ServingStats};
use super::worker::spawn_workers;
use crate::autotuner::{CostModel, SimCostModel};
use crate::config::ServingConfig;
use crate::device::DeviceDescriptor;
use crate::exec::{bounded, Sender};
use crate::runtime::{Manifest, ResizeBackend};
use crate::tiling::TileDim;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Cap on the batcher's poll interval while requests are pending, so
/// cancellations and expired deadlines are shed promptly even when the
/// batch deadline is long.
const SHED_POLL: Duration = Duration::from_millis(5);

/// Why a submission was not admitted.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue full (or the admission timeout elapsed) — retry
    /// later (backpressure).
    Saturated,
    /// No member's artifact set can serve this (kernel, size, scale).
    Unsupported,
    /// The request's latency budget is already spent.
    DeadlineExceeded,
    /// Service is shutting down.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Saturated => write!(f, "admission queue saturated"),
            SubmitError::Unsupported => write!(f, "no device serves this request shape"),
            SubmitError::DeadlineExceeded => write!(f, "deadline exceeded"),
            SubmitError::ShuttingDown => write!(f, "service shutting down"),
        }
    }
}
impl std::error::Error for SubmitError {}

/// One registered fleet member before startup.
struct MemberSpec {
    device: Option<DeviceDescriptor>,
    backend: Arc<dyn ResizeBackend>,
    policy: TilePolicy,
    manifest: Option<Manifest>,
}

/// A running fleet member: its own router, admission queue, batcher, and
/// worker pool.
struct Member {
    /// Shared with every ticket scheduled onto this member.
    label: Arc<str>,
    device: Option<DeviceDescriptor>,
    router: Arc<Router>,
    stats: Arc<ServingStats>,
    /// Cost-model estimate (ms/request) per supported key, for the
    /// scheduler's ETA computation. Empty for anonymous members.
    cost: HashMap<RequestKey, f64>,
    admit_tx: Option<Sender<ResizeRequest>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

/// Read-only view of one member for reporting (`tilekit serve`'s
/// per-device breakdown, tests).
pub struct MemberView<'a> {
    /// Device id, or a synthetic `devN` label for anonymous members.
    pub label: &'a str,
    /// The device descriptor, when the member has an identity.
    pub device: Option<&'a DeviceDescriptor>,
    /// The tile this member's router prefers.
    pub tile_pref: Option<TileDim>,
    /// This member's serving stats.
    pub stats: &'a Arc<ServingStats>,
    /// This member's routing table.
    pub router: &'a Router,
}

/// Builder for a [`Service`]. Register one or more members, then
/// [`build`](ServiceBuilder::build).
pub struct ServiceBuilder {
    cfg: ServingConfig,
    manifest: Manifest,
    members: Vec<MemberSpec>,
    scheduler: Option<Box<dyn Scheduler>>,
    admission: Option<Box<dyn AdmissionPolicy>>,
    cost_model: Arc<dyn CostModel + Send + Sync>,
}

impl ServiceBuilder {
    /// Start a builder over a shared artifact manifest. The config's
    /// `scheduler` / `admission` names supply the defaults (overridable
    /// with [`scheduler`](Self::scheduler) / [`admission`](Self::admission)).
    pub fn new(cfg: &ServingConfig, manifest: &Manifest) -> ServiceBuilder {
        ServiceBuilder {
            cfg: cfg.clone(),
            manifest: manifest.clone(),
            members: Vec::new(),
            scheduler: None,
            admission: None,
            cost_model: Arc::new(SimCostModel),
        }
    }

    /// Register a device member: its descriptor (identity + sim
    /// parameters), the backend executing its batches, and the tile
    /// policy its router resolves through (`TilePolicy::PerDevice`
    /// routes it to its tuned tile).
    pub fn device(
        mut self,
        device: DeviceDescriptor,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> ServiceBuilder {
        self.members.push(MemberSpec {
            device: Some(device),
            backend,
            policy,
            manifest: None,
        });
        self
    }

    /// Register a device member serving its own manifest instead of the
    /// shared one (heterogeneous artifact sets).
    pub fn device_with_manifest(
        mut self,
        device: DeviceDescriptor,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
        manifest: Manifest,
    ) -> ServiceBuilder {
        self.members.push(MemberSpec {
            device: Some(device),
            backend,
            policy,
            manifest: Some(manifest),
        });
        self
    }

    /// Register an anonymous single-backend member (no device identity;
    /// no per-device tuning or cost estimates). This is the classic
    /// one-backend deployment.
    pub fn backend(
        mut self,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> ServiceBuilder {
        self.members.push(MemberSpec {
            device: None,
            backend,
            policy,
            manifest: None,
        });
        self
    }

    /// Override the scheduler (default: the config's `scheduler` name).
    pub fn scheduler(mut self, s: impl Scheduler + 'static) -> ServiceBuilder {
        self.scheduler = Some(Box::new(s));
        self
    }

    /// Override the admission policy (default: the config's `admission`
    /// name with its `admission_timeout_ms`).
    pub fn admission(mut self, a: impl AdmissionPolicy + 'static) -> ServiceBuilder {
        self.admission = Some(Box::new(a));
        self
    }

    /// Replace the cost model behind ETA scheduling and sim-cost
    /// metering (default: the timing simulator).
    pub fn cost_model(mut self, m: impl CostModel + Send + Sync + 'static) -> ServiceBuilder {
        self.cost_model = Arc::new(m);
        self
    }

    /// Validate the config and start every member's pipeline.
    pub fn build(self) -> Result<Service> {
        self.cfg
            .validate()
            .context("invalid serving configuration")?;
        if self.members.is_empty() {
            bail!("service needs at least one device member");
        }
        let scheduler = match self.scheduler {
            Some(s) => s,
            None => scheduler_by_name(&self.cfg.scheduler)?,
        };
        let admission = match self.admission {
            Some(a) => a,
            None => admission_by_name(
                &self.cfg.admission,
                Duration::from_secs_f64(self.cfg.admission_timeout_ms / 1e3),
            )?,
        };
        let mut members = Vec::with_capacity(self.members.len());
        for (i, spec) in self.members.into_iter().enumerate() {
            let manifest = spec.manifest.as_ref().unwrap_or(&self.manifest);
            let label: Arc<str> = spec
                .device
                .as_ref()
                .map(|d| d.id.clone())
                .unwrap_or_else(|| format!("dev{i}"))
                .into();
            let device_id = spec.device.as_ref().map(|d| d.id.clone());
            let router = Arc::new(Router::for_device(
                manifest,
                spec.policy,
                device_id.as_deref(),
            ));
            let meter = spec
                .device
                .clone()
                .map(|d| Arc::new(CostMeter::new(d, Arc::clone(&self.cost_model))));
            // ETA table: the sim estimate of one request per supported
            // key, through the variant this member's router prefers.
            let mut cost = HashMap::new();
            if let Some(m) = &meter {
                for key in router.keys() {
                    if let Ok(entry) = router.route(&key, 1) {
                        let ms = m.ms_of(entry);
                        if ms.is_finite() {
                            cost.insert(key, ms);
                        }
                    }
                }
            }
            members.push(start_member(
                &self.cfg,
                label,
                spec.device,
                router,
                spec.backend,
                meter,
                cost,
            ));
        }
        Ok(Service {
            members,
            scheduler,
            admission,
            local: Arc::new(ServingStats::new()),
            ids: IdGen::default(),
        })
    }
}

/// Start one member's pipeline: admission queue → batcher thread →
/// worker pool (the old single-backend coordinator, one per device).
fn start_member(
    cfg: &ServingConfig,
    label: Arc<str>,
    device: Option<DeviceDescriptor>,
    router: Arc<Router>,
    backend: Arc<dyn ResizeBackend>,
    meter: Option<Arc<CostMeter>>,
    cost: HashMap<RequestKey, f64>,
) -> Member {
    let stats = Arc::new(ServingStats::new());
    let (admit_tx, admit_rx) = bounded::<ResizeRequest>(cfg.queue_cap);
    let (batch_tx, batch_rx) = bounded::<Batch>(cfg.queue_cap.max(4));

    // Batcher thread: drain admissions, group, shed cancelled/expired,
    // flush on size/deadline.
    let deadline = Duration::from_secs_f64(cfg.batch_deadline_ms / 1e3);
    let batch_max = cfg.batch_max;
    let batcher = {
        let stats = Arc::clone(&stats);
        std::thread::Builder::new()
            .name(format!("tilekit-batcher-{label}"))
            .spawn(move || {
                let mut state = BatcherState::new(batch_max, deadline);
                loop {
                    let timeout = match state.next_deadline(Instant::now()) {
                        // While requests are pending, poll fast enough to
                        // shed cancellations/deadlines promptly.
                        Some(d) => d.min(SHED_POLL),
                        None => Duration::from_millis(50),
                    };
                    match admit_rx.recv_timeout(timeout) {
                        Ok(Some(req)) => {
                            if let Some(batch) = state.push(req) {
                                if batch_tx.send(batch).is_err() {
                                    break;
                                }
                            }
                        }
                        Ok(None) => {} // timeout: fall through to expiry
                        Err(_) => break, // admissions closed: shutdown
                    }
                    for (req, reason) in state.sweep(Instant::now()) {
                        let (counter, msg) = match reason {
                            Shed::Cancelled => (&stats.cancelled, "cancelled"),
                            Shed::DeadlineExceeded => {
                                (&stats.shed, "deadline exceeded before execution")
                            }
                        };
                        counter.inc();
                        let _ = req
                            .reply
                            .send(Err(anyhow::anyhow!("request {} {msg}", req.id)));
                    }
                    for batch in state.flush_expired(Instant::now()) {
                        if batch_tx.send(batch).is_err() {
                            return;
                        }
                    }
                }
                // Shutdown: flush everything still pending.
                for batch in state.flush_all() {
                    let _ = batch_tx.send(batch);
                }
            })
            .expect("spawn batcher")
    };

    let workers = spawn_workers(
        cfg.workers,
        batch_rx,
        Arc::clone(&router),
        backend,
        Arc::clone(&stats),
        meter,
    );

    Member {
        label,
        device,
        router,
        stats,
        cost,
        admit_tx: Some(admit_tx),
        batcher: Some(batcher),
        workers,
    }
}

/// The running fleet-aware serving system.
pub struct Service {
    members: Vec<Member>,
    scheduler: Box<dyn Scheduler>,
    admission: Box<dyn AdmissionPolicy>,
    /// Submit-side counters (unsupported rejections, fail-fast deadline
    /// sheds) that belong to no single member.
    local: Arc<ServingStats>,
    ids: IdGen,
}

impl Service {
    /// Convenience: a single-member service over one backend (the old
    /// `Coordinator::start` deployment shape).
    pub fn single(
        cfg: &ServingConfig,
        manifest: &Manifest,
        backend: Arc<dyn ResizeBackend>,
        policy: TilePolicy,
    ) -> Result<Service> {
        ServiceBuilder::new(cfg, manifest)
            .backend(backend, policy)
            .build()
    }

    /// Submit a typed request. The scheduler picks the member, the
    /// admission policy decides what a full queue means.
    pub fn submit(&self, req: Request) -> Result<Ticket, SubmitError> {
        let key = req.key();
        let now = Instant::now();
        let snaps: Vec<DeviceSnapshot> = self
            .members
            .iter()
            .enumerate()
            .map(|(index, m)| DeviceSnapshot {
                index,
                device_id: &m.label,
                supports: m.router.supports(&key),
                // inflight() = admitted - answered, which already covers
                // requests still sitting in the admission queue.
                inflight: m.stats.inflight(),
                cost_ms: m.cost.get(&key).copied(),
            })
            .collect();
        // Unserveable beats expired: a request nobody can route is
        // Unsupported no matter what its budget says.
        if !snaps.iter().any(|s| s.supports) {
            self.local.rejected.inc();
            return Err(SubmitError::Unsupported);
        }
        let deadline = match req.deadline {
            Some(budget) if budget.is_zero() => {
                // Fail fast instead of occupying a queue slot.
                self.local.shed.inc();
                return Err(SubmitError::DeadlineExceeded);
            }
            Some(budget) => Some(now + budget),
            None => None,
        };
        let Some(index) = self.scheduler.pick(&key, &snaps) else {
            self.local.rejected.inc();
            return Err(SubmitError::Unsupported);
        };
        let member = &self.members[index];
        debug_assert!(
            member.router.supports(&key),
            "scheduler picked a member that cannot route the key"
        );
        let tx = member.admit_tx.as_ref().ok_or(SubmitError::ShuttingDown)?;
        let id = self.ids.next();
        let (ticket, reply) =
            Ticket::for_device(id, Default::default(), Some(member.label.clone()));
        let rr = ResizeRequest {
            id,
            key,
            image: req.image,
            priority: req.priority,
            deadline,
            // The ticket and the pipeline share the same token.
            cancel: ticket.cancel_token(),
            admitted: now,
            reply,
        };
        match self.admission.admit(tx, rr) {
            Ok(()) => {
                member.stats.admitted.inc();
                Ok(ticket)
            }
            Err(e) => {
                // Only backpressure counts as a member rejection; a
                // budget that ran out while blocked is a shed — recorded
                // service-side, NOT on the member, because the request
                // was never admitted and member shed/admitted counters
                // must stay balanced for inflight(). A shutdown race is
                // neither.
                match e {
                    SubmitError::Saturated => member.stats.rejected.inc(),
                    SubmitError::DeadlineExceeded => self.local.shed.inc(),
                    _ => {}
                }
                Err(e)
            }
        }
    }

    /// The union of keys any member can serve, sorted.
    pub fn keys(&self) -> Vec<RequestKey> {
        let mut ks: Vec<RequestKey> = self
            .members
            .iter()
            .flat_map(|m| m.router.keys())
            .collect();
        ks.sort();
        ks.dedup();
        ks
    }

    /// Number of fleet members.
    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    /// Read-only views of every member, for per-device reporting.
    pub fn members(&self) -> Vec<MemberView<'_>> {
        self.members
            .iter()
            .map(|m| MemberView {
                label: &m.label,
                device: m.device.as_ref(),
                tile_pref: m.router.tile_pref,
                stats: &m.stats,
                router: &m.router,
            })
            .collect()
    }

    /// The scheduler in use.
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// The admission policy in use.
    pub fn admission_name(&self) -> &'static str {
        self.admission.name()
    }

    /// Merged fleet-wide stats snapshot (counters + histograms summed
    /// over members; live stats keep updating after the call).
    pub fn stats(&self) -> ServingStats {
        let total = ServingStats::new();
        total.merge_from(&self.local);
        for m in &self.members {
            total.merge_from(&m.stats);
        }
        total
    }

    /// Reset every member's stats (e.g. after a warmup phase).
    pub fn reset_stats(&self) {
        self.local.reset();
        for m in &self.members {
            m.stats.reset();
        }
    }

    /// Graceful shutdown: stop admissions, drain every member's
    /// pipeline, join all threads. Returns the final merged stats.
    pub fn shutdown(mut self) -> ServingStats {
        self.shutdown_inner();
        self.stats()
    }

    fn shutdown_inner(&mut self) {
        for m in &mut self.members {
            m.admit_tx.take(); // closes admissions → batcher exits → workers exit
        }
        for m in &mut self.members {
            if let Some(b) = m.batcher.take() {
                let _ = b.join();
            }
            for w in m.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        if self.members.iter().any(|m| m.admit_tx.is_some()) {
            self.shutdown_inner();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::{BlockWithTimeout, RejectWhenFull};
    use crate::coordinator::request::Priority;
    use crate::coordinator::scheduler::RoundRobin;
    use crate::image::{generate, Interpolator};
    use crate::runtime::MockEngine;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "version": 1,
              "artifacts": [
                {"name": "bl_s2_b4", "kernel": "bilinear", "src": [16, 16],
                 "scale": 2, "batch": 4, "tile": [4, 32], "path": "x"},
                {"name": "nn_s4_b2", "kernel": "nearest", "src": [16, 16],
                 "scale": 4, "batch": 2, "tile": [4, 32], "path": "x"}
              ]
            }"#,
            PathBuf::from("."),
        )
        .unwrap()
    }

    fn cfg() -> ServingConfig {
        ServingConfig {
            workers: 2,
            batch_max: 4,
            batch_deadline_ms: 2.0,
            queue_cap: 64,
            ..ServingConfig::default()
        }
    }

    fn start(backend: Arc<dyn ResizeBackend>) -> Service {
        let m = manifest();
        ServiceBuilder::new(&cfg(), &m)
            .backend(backend, TilePolicy::PortableFallback)
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap()
    }

    fn req(kernel: Interpolator, img: crate::image::Image<f32>, scale: u32) -> Request {
        Request::new(kernel, img, scale)
    }

    #[test]
    fn end_to_end_requests_complete_correctly() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 9);
        let want = crate::image::bilinear(&img, 2);
        let tickets: Vec<_> = (0..20)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        for t in tickets {
            let out = t.wait().unwrap();
            assert_eq!(out.width(), 32);
            assert!(out.max_abs_diff(&want) < 1e-6);
        }
        let stats = svc.shutdown();
        assert_eq!(stats.completed.get(), 20);
        assert_eq!(stats.failed.get(), 0);
        assert!(stats.batches.get() <= 20);
        assert!(stats.mean_batch() >= 1.0);
        assert_eq!(
            stats.latency_by_class[Priority::Interactive.index()].count(),
            20
        );
    }

    #[test]
    fn unsupported_shape_rejected_fast() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::gradient(9, 9);
        match svc.submit(req(Interpolator::Bilinear, img, 2)) {
            Err(SubmitError::Unsupported) => {}
            other => panic!("expected Unsupported, got {other:?}"),
        }
        let img16 = generate::gradient(16, 16);
        assert!(matches!(
            svc.submit(req(Interpolator::Bicubic, img16, 2)),
            Err(SubmitError::Unsupported)
        ));
        let stats = svc.shutdown();
        assert_eq!(stats.rejected.get(), 2);
    }

    #[test]
    fn mixed_kernels_route_independently() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 2);
        let t1 = svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap();
        let t2 = svc.submit(req(Interpolator::Nearest, img.clone(), 4)).unwrap();
        assert_eq!(t1.wait().unwrap().width(), 32);
        assert_eq!(t2.wait().unwrap().width(), 64);
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        // One request with batch_max 4: only the deadline can flush it.
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 4);
        let t = svc.submit(req(Interpolator::Bilinear, img, 2)).expect("admitted");
        let out = t.wait().unwrap();
        assert_eq!(out.height(), 32);
    }

    #[test]
    fn zero_deadline_fails_fast() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 4);
        let r = req(Interpolator::Bilinear, img, 2).deadline(Duration::ZERO);
        assert!(matches!(
            svc.submit(r),
            Err(SubmitError::DeadlineExceeded)
        ));
        let stats = svc.shutdown();
        assert_eq!(stats.shed.get(), 1);
        assert_eq!(stats.completed.get(), 0);
    }

    #[test]
    fn backend_failures_reported_per_request() {
        let svc = start(Arc::new(MockEngine::failing_every(1)));
        let img = generate::test_scene(16, 16, 5);
        let t = svc.submit(req(Interpolator::Bilinear, img, 2)).unwrap();
        assert!(t.wait().is_err());
        let stats = svc.shutdown();
        assert_eq!(stats.failed.get(), 1);
    }

    #[test]
    fn backpressure_saturates() {
        // Slow backend + tiny queue + non-blocking admission: Saturated.
        let slow = MockEngine::with_delay(Duration::from_millis(30));
        let m = manifest();
        let small = ServingConfig {
            workers: 1,
            batch_max: 1,
            batch_deadline_ms: 0.1,
            queue_cap: 2,
            ..ServingConfig::default()
        };
        let svc = ServiceBuilder::new(&small, &m)
            .backend(Arc::new(slow), TilePolicy::PortableFallback)
            .admission(RejectWhenFull)
            .build()
            .unwrap();
        let img = generate::test_scene(16, 16, 6);
        let mut saturated = false;
        let mut tickets = Vec::new();
        for _ in 0..64 {
            match svc.submit(req(Interpolator::Bilinear, img.clone(), 2)) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::Saturated) => {
                    saturated = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(saturated, "queue should saturate under a slow backend");
        for t in tickets {
            let _ = t.wait();
        }
        let stats = svc.shutdown();
        assert!(stats.rejected.get() >= 1);
    }

    #[test]
    fn shutdown_drains_pending() {
        let svc = start(Arc::new(MockEngine::new()));
        let img = generate::test_scene(16, 16, 7);
        let tickets: Vec<_> = (0..10)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        let stats = svc.shutdown(); // must drain, not drop
        assert_eq!(stats.completed.get() + stats.failed.get(), 10);
        for t in tickets {
            let _ = t.wait(); // all replies delivered
        }
    }

    #[test]
    fn two_member_fleet_round_robin_spreads_load() {
        let m = manifest();
        let svc = ServiceBuilder::new(&cfg(), &m)
            .device(
                crate::device::find_device("gtx260").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .device(
                crate::device::find_device("fermi").unwrap(),
                Arc::new(MockEngine::new()),
                TilePolicy::PortableFallback,
            )
            .scheduler(RoundRobin::default())
            .admission(BlockWithTimeout(Duration::from_secs(10)))
            .build()
            .unwrap();
        assert_eq!(svc.member_count(), 2);
        let img = generate::test_scene(16, 16, 8);
        let tickets: Vec<_> = (0..12)
            .map(|_| svc.submit(req(Interpolator::Bilinear, img.clone(), 2)).unwrap())
            .collect();
        let mut per_dev: HashMap<String, usize> = HashMap::new();
        for t in &tickets {
            *per_dev
                .entry(t.device_id().unwrap().to_string())
                .or_default() += 1;
        }
        assert_eq!(per_dev.get("gtx260"), Some(&6));
        assert_eq!(per_dev.get("fermi"), Some(&6));
        for t in tickets {
            t.wait().unwrap();
        }
        let views_completed: u64 = svc.members().iter().map(|v| v.stats.completed.get()).sum();
        assert_eq!(views_completed, 12);
        let stats = svc.shutdown();
        assert_eq!(stats.completed.get(), 12);
        assert!(stats.sim_cost_ns.get() > 0, "named members meter sim cost");
    }

    #[test]
    fn builder_rejects_bad_config_and_empty_fleet() {
        let m = manifest();
        let bad = ServingConfig {
            workers: 0,
            ..ServingConfig::default()
        };
        let err = ServiceBuilder::new(&bad, &m)
            .backend(Arc::new(MockEngine::new()), TilePolicy::PortableFallback)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("invalid serving configuration"), "{err}");
        assert!(ServiceBuilder::new(&cfg(), &m).build().is_err(), "no members");
    }
}
